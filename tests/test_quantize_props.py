"""Property tests for the quantized-sync math (core/sync.py).

Three families of invariants:
  * `_quantize_delta` round trip: elementwise error at most half an int8
    quantization level (amax/254), all-zero deltas reconstruct EXACTLY
    (the guarded scale), and tiny deltas keep per-tensor precision;
  * the RS-domain scale rule: shard-local partial per-tensor amaxes
    (`partial_segment_amax`) folded with an elementwise max equal the
    full-tensor scales bitwise, for ARBITRARY contiguous shard splits —
    this is what lets the sharded sync compute scales with one tiny pmax
    instead of GSPMD per-element scale collectives;
  * integer-code means are order-independent: Σq over workers is exact in
    f32 under any summation order/chunking, and `wire_dtype(W)` always
    holds the sum — the foundation of every cross-layout / cross-process
    bitwise claim in tests/test_sharded.py and tests/test_multihost.py;
  * the per-hop requantizer (`--wire ring-int8`): a single hop round-trips
    within half a level of ITS scale, a K-hop chain lands within
    `ring_tolerance` of the exact running mean, zero/tiny deltas come
    through exact, and `wire_dtype(w, accum=1)` is int8 for every W (the
    ring never sums on the wire).

Requires hypothesis (skips as a module otherwise); the deadline is disabled
globally via the conftest profile.  tests/test_ring_sync.py carries
deterministic (seeded) versions of the ring properties that run even where
hypothesis is absent.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import flat as F  # noqa: E402
from repro.core.sync import (_guarded_scale, _quantize_delta,  # noqa: E402
                             partial_segment_amax, ring_codes_host,
                             ring_tolerance, wire_dtype)
from repro.kernels import ops as kops  # noqa: E402

_seed = st.integers(0, 2 ** 31 - 1)


# -------------------------------------------------------- round trip ------

@given(seed=_seed, n=st.integers(1, 300),
       log_scale=st.integers(-40, 20), zero_frac=st.floats(0.0, 1.0))
@settings(max_examples=60)
def test_roundtrip_error_at_most_half_a_level(seed, n, log_scale, zero_frac):
    """|dequant(quant(d)) - d| <= amax/254 elementwise — half the int8 grid
    step amax/127 — at every magnitude from subnormal-adjacent to huge."""
    rng = np.random.RandomState(seed)
    d = rng.randn(n).astype(np.float32) * np.float32(10.0 ** log_scale)
    d[rng.rand(n) < zero_frac] = 0.0
    dq = np.asarray(_quantize_delta({"x": jnp.asarray(d)})["x"])
    amax = float(np.max(np.abs(d)))
    if amax == 0.0:
        np.testing.assert_array_equal(dq, np.zeros_like(d))
    else:
        # the additive term covers f32-subnormal territory: for amax below
        # ~2e-43 the scale amax/127 itself rounds at the subnormal ulp
        # (~1.4e-45), and the dequant q * s' inherits up to 127 half-ulps
        err = np.abs(dq - d).max()
        assert err <= amax / 254 * (1 + 1e-6) + 127 * 1.5e-45, (err, amax)


@given(seed=_seed, n=st.integers(1, 100))
@settings(max_examples=30)
def test_all_zero_delta_reconstructs_exactly(seed, n):
    dq = np.asarray(_quantize_delta({"x": jnp.zeros(n, jnp.float32)})["x"])
    np.testing.assert_array_equal(dq, np.zeros(n, np.float32))
    # and the guard keeps the scale finite (1.0), not a denormal ratio
    assert float(_guarded_scale(jnp.float32(0.0))) == 1.0


@given(seed=_seed, amax_exp=st.integers(-44, -20))
@settings(max_examples=30)
def test_tiny_delta_keeps_per_tensor_precision(seed, amax_exp):
    """Regression family for the old `amax + 1e-12` guard, which dilated the
    grid of any tensor whose range sat below ~1e-12."""
    rng = np.random.RandomState(seed)
    amax = np.float32(2.0 ** amax_exp)
    d = (rng.uniform(-1, 1, 64).astype(np.float32) * amax)
    dq = np.asarray(_quantize_delta({"x": jnp.asarray(d)})["x"])
    a = float(np.max(np.abs(d)))
    assert np.abs(dq - d).max() <= a / 254 * (1 + 1e-6) + 127 * 1.5e-45


# ------------------------------------------- RS-domain scale rule ---------

_shapes = st.lists(st.lists(st.integers(1, 6), min_size=0, max_size=3)
                   .map(tuple), min_size=1, max_size=6)


@given(shapes=_shapes, shards=st.integers(1, 16), w=st.integers(1, 5),
       n_chunks=st.integers(1, 11), seed=_seed)
@settings(max_examples=40)
def test_partial_amax_folds_to_full_tensor_scales(shapes, shards, w,
                                                  n_chunks, seed):
    """Shard-local partial per-tensor amaxes, folded by max, equal the
    full-buffer segment_max bitwise for ARBITRARY contiguous splits — the
    correctness of computing int8 scales in the reduce-scatter domain."""
    rng = np.random.RandomState(seed)
    tree = {f"p{i}": jnp.asarray(
        (rng.randn(*shp) * 10.0 ** rng.randint(-30, 10)).astype(np.float32))
        for i, shp in enumerate(shapes)}
    spec = F.ShardedFlatSpace(tree, shards)
    bucket = "float32"
    n = spec.buffer_size(bucket)
    nseg = spec.bucket_leaves(bucket)
    seg = jnp.asarray(spec.segment_ids(bucket))
    d = jnp.asarray(rng.randn(w, n).astype(np.float32))
    # pad region must carry zero delta (as the runtime guarantees)
    if spec.pad[bucket]:
        d = d.at[:, -spec.pad[bucket]:].set(0.0)

    full = np.asarray(partial_segment_amax(d, seg, nseg))

    # arbitrary contiguous chunking of the flat dim
    cuts = sorted(set(rng.randint(0, n + 1, size=n_chunks - 1)))
    bounds = [0] + cuts + [n]
    partials = [np.asarray(partial_segment_amax(
        d[:, lo:hi], seg[lo:hi], nseg)) for lo, hi in zip(bounds, bounds[1:])
        if hi > lo]
    fold = np.maximum.reduce(partials)
    np.testing.assert_array_equal(fold, full)
    # and the guarded scales agree too
    np.testing.assert_array_equal(np.asarray(_guarded_scale(jnp.asarray(fold))),
                                  np.asarray(_guarded_scale(jnp.asarray(full))))


# --------------------------------------- integer-code mean exactness ------

@given(w=st.integers(1, 258), n=st.integers(1, 64), seed=_seed)
@settings(max_examples=40)
def test_integer_code_mean_is_order_independent(w, n, seed):
    """Σ_i q_i with q ∈ [-127, 127] is exact in f32 whatever the summation
    order (|Σ| <= 258*127 << 2^24), and wire_dtype(W) holds it exactly —
    so jnp.mean of codes == reduce_scatter of codes == gloo psum of codes."""
    rng = np.random.RandomState(seed)
    q = rng.randint(-127, 128, size=(w, n))
    exact = q.sum(axis=0)  # int64
    fwd = np.zeros(n, np.float32)
    rev = np.zeros(n, np.float32)
    for i in range(w):
        fwd += q[i].astype(np.float32)
        rev += q[w - 1 - i].astype(np.float32)
    jx = np.asarray(jnp.sum(jnp.asarray(q, jnp.float32), axis=0))
    np.testing.assert_array_equal(fwd, exact.astype(np.float32))
    np.testing.assert_array_equal(rev, exact.astype(np.float32))
    np.testing.assert_array_equal(jx, exact.astype(np.float32))
    wdt = np.dtype(wire_dtype(w))
    info = np.iinfo(wdt)
    assert info.min <= exact.min() and exact.max() <= info.max
    np.testing.assert_array_equal(q.astype(wdt).sum(axis=0, dtype=wdt),
                                  exact.astype(wdt))


# ------------------------------------------- wire_dtype boundary ----------

@pytest.mark.parametrize("w,want", [
    (1, jnp.int8),        # one worker folds one code: int8 already holds it
    (2, jnp.int16), (257, jnp.int16),
    (258, jnp.int16),     # 258 * 127 = 32766 — the last int16 worker count
    (259, jnp.int32),     # 259 * 127 = 32893 > int16 max: crossover
    (1024, jnp.int32),
])
def test_wire_dtype_boundary(w, want):
    """The int16 -> int32 crossover sits exactly at W = 258 -> 259
    (W * 127 < 2^15): wire_dtype must flip there, one worker late is an
    overflowing reduce-scatter."""
    assert wire_dtype(w) == want


@pytest.mark.parametrize("w", [258, 259])
def test_wire_dtype_exact_sum_at_extremes(w):
    """Exact-sum boundary cases at the crossover: the worst-case code sums
    Σq = ±W·127 (every worker saturating the int8 grid the same way) must
    fit wire_dtype(W) exactly, in any accumulation order — including the
    chunked partial sums a reduce_scatter produces."""
    wdt = np.dtype(wire_dtype(w))
    info = np.iinfo(wdt)
    for sign in (1, -1):
        q = np.full((w, 16), sign * 127, np.int64)
        exact = q.sum(axis=0)                       # ±w*127, int64
        assert info.min <= exact.min() and exact.max() <= info.max
        # one-shot accumulation in the wire dtype
        np.testing.assert_array_equal(
            q.astype(wdt).sum(axis=0, dtype=wdt), exact.astype(wdt))
        # arbitrary chunked partial sums (the collective's fold) stay exact
        acc = np.zeros(16, wdt)
        for lo in range(0, w, 37):
            acc = acc + q[lo:lo + 37].astype(wdt).sum(axis=0, dtype=wdt)
        np.testing.assert_array_equal(acc, exact.astype(wdt))
    # the crossover is tight: 258 is the last count whose extreme sum fits
    # int16, 259 overflows it
    assert 258 * 127 <= np.iinfo(np.int16).max < 259 * 127


@given(w=st.integers(1, 4096))
@settings(max_examples=40)
def test_wire_dtype_accum_one_is_always_int8(w):
    """The ring's wire contract: each hop carries ONE freshly quantized
    partial mean (accum=1), never a sum — int8 suffices for any W, while
    the one-shot RS default must widen with W."""
    assert wire_dtype(w, accum=1) == jnp.int8
    assert np.dtype(wire_dtype(w)).itemsize >= (2 if w > 1 else 1)


# ----------------------------------------- per-hop requantizer (ring) -----

@given(seed=_seed, n=st.integers(1, 300), log_scale=st.integers(-40, 20))
@settings(max_examples=60)
def test_ring_single_hop_roundtrip_half_level(seed, n, log_scale):
    """One requant pass round-trips within half an int8 level of its own
    (guarded) scale: |dequant(codes) - acc| <= scale/254 elementwise."""
    rng = np.random.RandomState(seed)
    acc = jnp.asarray((rng.randn(n) * 2.0 ** log_scale).astype(np.float32))
    s = _guarded_scale(jnp.max(jnp.abs(acc)))
    q = kops.ring_quantize_codes(acc, s)
    assert q.dtype == jnp.int8
    deq = np.asarray(q, np.float32) * float(s) / 127.0
    assert np.max(np.abs(deq - np.asarray(acc))) <= float(s) / 254.0 * (
        1.0 + 1e-6)


@given(seed=_seed, w=st.integers(2, 12), n=st.integers(1, 200),
       log_scale=st.integers(-30, 16))
@settings(max_examples=40)
def test_ring_chain_error_within_ring_tolerance(seed, w, n, log_scale):
    """The K-hop requant chain (ring_codes_host = the mesh ring's exact
    arithmetic) lands within ring_tolerance(W, amax, 1) of the exact worker
    mean for arbitrary deltas — the bound every executed-ring assertion in
    the repo charges per round."""
    rng = np.random.RandomState(seed)
    d = (rng.randn(w, n) * 2.0 ** log_scale).astype(np.float32)
    q, s = ring_codes_host(jnp.asarray(d))
    got = (np.asarray(q, np.float32)
           * (np.asarray(s)[:, None] / 127.0)).reshape(-1)
    pad = (-n) % w
    exact = np.pad(d, ((0, 0), (0, pad))).mean(axis=0).reshape(-1)
    err = np.max(np.abs(got - exact))
    tol = ring_tolerance(w, np.max(np.abs(d)), 1)
    assert err <= tol, (err, tol)


@given(w=st.integers(2, 12), n=st.integers(1, 200))
@settings(max_examples=20)
def test_ring_zero_delta_exact(w, n):
    """All-zero deltas survive every hop exactly: the guarded scale never
    divides by zero and the mean codes are identically zero."""
    q, s = ring_codes_host(jnp.zeros((w, n), jnp.float32))
    assert not np.any(np.asarray(q))
    assert np.all(np.isfinite(np.asarray(s)))


@given(seed=_seed, w=st.integers(2, 12), n=st.integers(1, 200),
       log_scale=st.integers(-40, -20))
@settings(max_examples=30)
def test_ring_tiny_deltas_keep_relative_precision(seed, w, n, log_scale):
    """Deltas near the subnormal floor still come through with the SAME
    relative error bound — the per-hop scale is fresh per chunk, so ring
    precision never depends on the absolute magnitude."""
    rng = np.random.RandomState(seed)
    d = (rng.randn(w, n) * 2.0 ** log_scale).astype(np.float32)
    q, s = ring_codes_host(jnp.asarray(d))
    got = (np.asarray(q, np.float32)
           * (np.asarray(s)[:, None] / 127.0)).reshape(-1)
    pad = (-n) % w
    exact = np.pad(d, ((0, 0), (0, pad))).mean(axis=0).reshape(-1)
    amax = np.max(np.abs(d))
    if amax > 0.0:
        assert np.max(np.abs(got - exact)) <= ring_tolerance(w, amax, 1)
