"""The static program auditor (src/repro/analysis/ + launch/audit.py).

Three layers under test, none of which executes a collective:
  * the declarative rule registry (analysis/rules.py) against synthetic
    lowering records — each rule must pass its contract shape and trip on
    the corresponding mutation;
  * the AST source lint (analysis/source_lint.py) and the schema-tag
    registry (analysis/schemas.py);
  * the committed audit baseline (analysis/audit_baseline.json): parses,
    carries the fingerprint schema, covers the full matrix, and records
    zero rule failures — plus the diff engine's regression semantics.

The full lower-everything matrix and the mutation self-test (which
compiles real sync/round programs) run as subprocesses of
`python -m repro.launch.audit`; the matrix half lives in the CI `static`
job, the self-test is exercised here once.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import audit as A
from repro.analysis import rules as R
from repro.analysis import schemas as S
from repro.analysis import source_lint as L

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _cli(*extra, timeout=120):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.audit", *extra],
        capture_output=True, text=True, env=env, timeout=timeout)


# ------------------------------------------------------------ source lint --

def test_lint_flags_bare_assert_and_respects_marker():
    hits = L.lint_source("def f(x):\n    assert x > 0, x\n", "a.py")
    assert [v.rule for v in hits] == ["bare-assert"]
    assert hits[0].line == 2
    assert "a.py:2" in hits[0].render()
    ok = L.lint_source(
        "def f(x):\n    assert x > 0  # lint: allow-assert\n", "a.py")
    assert ok == []


def test_lint_flags_generic_raises_only():
    bad = ("def f():\n    raise Exception('boom')\n"
           "def g():\n    raise AssertionError\n")
    assert sorted(v.line for v in L.lint_source(bad, "b.py")) == [2, 4]
    assert {v.rule for v in L.lint_source(bad, "b.py")} == {"raise-generic"}
    typed = ("from repro.errors import ConfigError\n"
             "def f():\n    raise ConfigError('bad layout')\n"
             "def g():\n    raise ValueError('fine too')\n")
    assert L.lint_source(typed, "b.py") == []


def test_lint_flags_unregistered_schema_strings():
    bad = 'REC = {"schema": "mystery_record/v3"}\n'
    hits = L.lint_source(bad, "c.py")
    assert [v.rule for v in hits] == ["unregistered-schema"]
    good = 'REC = {"schema": "controller_trace/v1"}\n'
    assert L.lint_source(good, "c.py") == []
    # non-schema-shaped strings never match
    assert L.lint_source('X = "a/b"\nY = "path/void"\n', "c.py") == []


def test_schema_registry_shapes_and_membership():
    assert S.is_registered("audit_fingerprint/v1")
    assert not S.is_registered("audit_fingerprint/v2")
    for tag in S.SCHEMAS:
        assert S.looks_like_schema(tag), tag
    assert A.SCHEMA in S.SCHEMAS


def test_lint_repo_clean():
    """The library tree itself must lint clean — the satellite conversion
    of bare asserts to typed errors is locked in here."""
    violations = L.lint_repo()
    assert violations == [], "\n".join(v.render() for v in violations)


# ------------------------------------------- rules on synthetic records ---

def _sharded_cfg(**kw):
    cfg = dict(kind="sync", layout="flat_sharded", sync="blocking",
               wire="auto", quantize=True, workers=4)
    cfg.update(kw)
    return cfg


def _sharded_rec(**kw):
    rec = dict(n_buckets=1, workers=4, n_leaves=13,
               payload_all_reduce_ops=0, reduce_scatter_ops=1,
               all_gather_ops=1, collective_permute_ops=0,
               amax_fold_ops=1, collective_counts={},
               payload_ops_by_dtype={"s16": 2},
               host_callback_lines=[], degenerate_collectives=[])
    rec.update(kw)
    return rec


def test_budget_rule_passes_clean_sharded_record():
    verdicts = R.evaluate(_sharded_cfg(), _sharded_rec())
    assert R.failed(verdicts) == []
    assert verdicts["collective-budget"]["applies"]
    assert verdicts["wire-payload-dtype"]["applies"]


def test_budget_rule_trips_on_injected_payload_all_reduce():
    verdicts = R.evaluate(_sharded_cfg(),
                          _sharded_rec(payload_all_reduce_ops=1))
    assert "collective-budget" in R.failed(verdicts)


def test_budget_rule_trips_on_missing_gather_leg():
    verdicts = R.evaluate(_sharded_cfg(), _sharded_rec(all_gather_ops=0))
    assert "collective-budget" in R.failed(verdicts)


def test_budget_rule_overlap_halves_split_rs_and_ag():
    begin = R.evaluate(_sharded_cfg(sync="begin"),
                       _sharded_rec(all_gather_ops=0))
    assert R.failed(begin) == []
    apply_ = R.evaluate(_sharded_cfg(sync="apply"),
                        _sharded_rec(reduce_scatter_ops=0, amax_fold_ops=0))
    assert R.failed(apply_) == []
    # a gather appearing in the begin half is a violation
    leaked = R.evaluate(_sharded_cfg(sync="begin"), _sharded_rec())
    assert "collective-budget" in R.failed(leaked)


def test_budget_rule_ring_wants_permute_hops_not_rs():
    cfg = _sharded_cfg(wire="ring-int8")
    rec = _sharded_rec(reduce_scatter_ops=0, all_gather_ops=0,
                       collective_permute_ops=3, amax_fold_ops=0,
                       payload_ops_by_dtype={"s8": 3})
    assert R.failed(R.evaluate(cfg, rec)) == []
    # W-1 hops per bucket is a floor: 2 hops for W=4 is a schedule bug
    short = R.evaluate(cfg, dict(rec, collective_permute_ops=2))
    assert "collective-budget" in R.failed(short)


def test_budget_rule_tree_pays_per_leaf():
    cfg = dict(kind="sync", layout="tree", sync="blocking", wire="auto",
               quantize=False, workers=4)
    ok = R.evaluate(cfg, dict(all_reduce_ops=13, n_leaves=13))
    assert R.failed(ok) == []
    fused = R.evaluate(cfg, dict(all_reduce_ops=1, n_leaves=13))
    assert "collective-budget" in R.failed(fused)


def test_budget_rule_flat_quantized_is_lower_bound():
    cfg = dict(kind="sync", layout="flat", sync="blocking", wire="auto",
               quantize=True, workers=4)
    rec = dict(n_buckets=1, payload_all_reduce_ops=2, reduce_scatter_ops=0,
               collective_permute_ops=0, collective_counts={})
    assert R.failed(R.evaluate(cfg, rec)) == []  # GSPMD scale ARs allowed
    exact = R.evaluate(dict(cfg, quantize=False), rec)
    assert "collective-budget" in R.failed(exact)  # unquantized: exactly nb


def test_wire_dtype_rule_trips_on_float_payload():
    verdicts = R.evaluate(
        _sharded_cfg(), _sharded_rec(payload_ops_by_dtype={"s16": 2,
                                                           "f32": 1}))
    assert "wire-payload-dtype" in R.failed(verdicts)
    # ring: anything but s8 — even the auto wire's s16 — is a violation
    ring = R.evaluate(_sharded_cfg(wire="ring-int8"),
                      _sharded_rec(reduce_scatter_ops=0, all_gather_ops=0,
                                   collective_permute_ops=3, amax_fold_ops=0,
                                   payload_ops_by_dtype={"s16": 3}))
    assert "wire-payload-dtype" in R.failed(ring)


def test_donation_rule_floor_and_applicability():
    cfg = dict(kind="round", donate=True)
    ok = R.evaluate(cfg, dict(donation_pairs=5, expected_alias_min=5))
    assert R.failed(ok) == []
    lost = R.evaluate(cfg, dict(donation_pairs=4, expected_alias_min=5))
    assert "donation-aliasing" in R.failed(lost)
    undonated = R.evaluate(dict(cfg, donate=False),
                           dict(donation_pairs=0, expected_alias_min=0))
    assert not undonated["donation-aliasing"]["applies"]


def test_cache_rule_duplicate_and_overflow():
    cfg = dict(kind="cache")
    ok = R.evaluate(cfg, dict(program_keys=[[1, 8], [2, 8]],
                              program_limit=4))
    assert R.failed(ok) == []
    dup = R.evaluate(cfg, dict(program_keys=[[1, 8], [1, 8]],
                               program_limit=4))
    assert "compile-cache-bound" in R.failed(dup)
    over = R.evaluate(cfg, dict(program_keys=[[h, 8] for h in range(9)],
                                program_limit=4))
    assert "compile-cache-bound" in R.failed(over)


def test_hygiene_rules_pass_through_detector_lines():
    cfg = dict(kind="round", donate=False)
    rec = dict(host_callback_lines=["%cc = custom-call ... callback"],
               degenerate_collectives=["%x = all-reduce ... {{0}}"],
               donation_pairs=0, expected_alias_min=0)
    failed = R.failed(R.evaluate(cfg, rec))
    assert "no-host-callback" in failed
    assert "no-degenerate-replica-group" in failed


# ----------------------------------- cache enumeration vs the real engine --

def test_cache_enumeration_stays_within_program_bound():
    """The compile-cache-bound rule over the REAL key enumeration of a
    3000-step QSR schedule — statically, zero compiles (core/engine
    enumerate_program_keys mirrors RoundEngine._program's key)."""
    m = A.matrix()
    for key in ("cache:blocking:w8", "cache:partial:w8", "cache:overlap:d2:w8"):
        cfg = m[key]
        rec = A._enumerate_cache(cfg)
        verdicts = R.evaluate(cfg, rec)
        assert R.failed(verdicts) == [], (key, verdicts)
        assert 0 < rec["program_count"] <= rec["program_limit"]
    # overlap gets exactly one extra slot (the pending-free first round)
    blocking = A._enumerate_cache(m["cache:blocking:w8"])
    overlap = A._enumerate_cache(m["cache:overlap:d0:w8"])
    assert overlap["program_limit"] == blocking["program_limit"] + 1


# -------------------------------------------------- baseline + diff logic --

def test_committed_baseline_covers_matrix_and_is_clean():
    base = A.load_baseline()
    assert base["schema"] == A.SCHEMA
    assert sorted(base["configs"]) == sorted(A.matrix())
    for key, entry in base["configs"].items():
        assert entry["rules_failed"] == [], (key, entry["rules_failed"])


def test_diff_baseline_regression_semantics():
    base = {"configs": {
        "k": {"rules": {"collective-budget": {"ok": True, "applies": True,
                                              "violations": []}},
              "bytes_on_wire": 100, "payload_ops_by_dtype": {"s16": 2},
              "donation_pairs": 5},
        "gone": {"rules": {}},
    }}
    fresh = {"configs": {
        "k": {"rules": {"collective-budget": {"ok": False, "applies": True,
                                              "violations": ["extra AR"]}},
              "bytes_on_wire": 120,
              "payload_ops_by_dtype": {"s16": 2, "f32": 1},
              "donation_pairs": 4},
        "new": {"rules": {}},
    }}
    regressions, notes = A.diff_baseline(fresh, base)
    text = "\n".join(regressions)
    assert "k: collective-budget: extra AR" in text
    assert "bytes_on_wire grew 100 -> 120" in text
    assert "new payload dtype" in text
    assert "donation_pairs fell 5 -> 4" in text
    assert "gone: config dropped" in text
    assert any("new config" in n for n in notes)
    # the improvement direction is a note, not a regression
    regressions2, notes2 = A.diff_baseline(base, base)
    assert regressions2 == [] and notes2 == []


# ----------------------------------------------------------- CLI surface ---

def test_cli_list_and_rules():
    out = _cli("--list")
    assert out.returncode == 0, out.stderr[-2000:]
    keys = out.stdout.split()
    assert "sync:dp4x2:flat_sharded:blocking:q" in keys
    assert "round:dp4x2:flat_sharded:overlap:d2:q" in keys
    assert "cache:blocking:w8" in keys
    assert len(keys) == len(A.matrix())
    rules_out = _cli("--rules")
    assert rules_out.returncode == 0
    for name in ("collective-budget", "wire-payload-dtype",
                 "donation-aliasing", "compile-cache-bound",
                 "no-host-callback", "no-degenerate-replica-group"):
        assert name in rules_out.stdout, name


def test_cli_unknown_config_is_an_error():
    out = _cli("--config", "sync:nope")
    assert out.returncode != 0
    assert "sync:nope" in (out.stdout + out.stderr)


def test_cli_lint_passes_on_repo():
    out = _cli("--lint")
    assert out.returncode == 0, out.stdout + out.stderr[-2000:]
    assert "0 violation(s)" in out.stdout


def test_cli_mutation_self_test():
    """The rules must have teeth: an injected payload all-reduce, a dropped
    donation, and a bare-assert fixture must each trip their rule (and the
    clean fixtures must pass).  Compiles one sync + two round programs."""
    out = _cli("--self-test", timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr[-3000:]
    assert "0 failure(s)" in out.stdout
