"""Model-stack invariants: decode==forward consistency, SSD==naive recurrence,
MoE dispatch conservation, RoPE shift property, masks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import registry as R
from repro.configs.base import ModelConfig
from repro.models import api, common as cm, mamba2, moe, param as pm

DECODER_ARCHS = ["starcoder2-3b", "gemma3-4b", "qwen1.5-110b",
                 "phi3-medium-14b", "dbrx-132b", "kimi-k2-1t-a32b",
                 "mamba2-130m", "zamba2-1.2b", "whisper-base"]


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_prefill_decode_matches_forward(arch):
    """prefill(S-1) + decode(1) must equal the teacher-forced forward — the
    KV-cache/SSM-state handoff is exact."""
    cfg = R.get_smoke_config(arch)
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(1))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = 0.1 * jax.random.normal(jax.random.PRNGKey(3),
                                               (b, cfg.enc_seq, cfg.d_model))
    full, _ = mod.forward(cfg, params, toks, remat=False, **kw)
    cache = mod.init_cache(cfg, b, s, dtype=jnp.float32)
    lg_pre, cache = mod.prefill(cfg, params, toks[:, :s - 1], cache, **kw)
    lg_dec, _ = mod.decode_step(cfg, params, toks[:, s - 1], cache, s - 1)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(full[:, s - 2]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full[:, s - 1]),
                               rtol=2e-4, atol=2e-4)


def test_vlm_prefix_decode_matches_forward():
    cfg = R.get_smoke_config("paligemma-3b")
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(1))
    b, s, p = 2, 12, cfg.n_img_tokens
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    img = 0.02 * jax.random.normal(jax.random.PRNGKey(3), (b, p, cfg.d_model))
    full, _ = mod.forward(cfg, params, toks, prefix_embeds=img, remat=False)
    cache = mod.init_cache(cfg, b, s + p, dtype=jnp.float32)
    lg_pre, cache = mod.prefill(cfg, params, toks[:, :s - 1], cache,
                                prefix_embeds=img)
    lg_dec, _ = mod.decode_step(cfg, params, toks[:, s - 1], cache,
                                p + s - 1, prefix_len=p)
    np.testing.assert_allclose(np.asarray(lg_pre), np.asarray(full[:, s - 2]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(lg_dec), np.asarray(full[:, s - 1]),
                               rtol=2e-4, atol=2e-4)


# -------------------------------------------------------------------- SSD --

def _naive_ssm(x, dt, A, B_, C_, D):
    """Literal per-token recurrence — the definitional oracle for SSD."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    hs = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    x, dt, B_, C_ = map(lambda a: np.asarray(a, np.float64), (x, dt, B_, C_))
    A = np.asarray(A, np.float64)
    for t in range(s):
        dec = np.exp(dt[:, t] * A[None])                      # [b,h]
        hs = hs * dec[:, :, None, None] + np.einsum(
            "bh,bhp,bn->bhpn", dt[:, t], x[:, t], B_[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", C_[:, t], hs) + x[:, t] * \
            np.asarray(D, np.float64)[None, :, None]
    return ys, hs


@given(s=st.sampled_from([8, 16, 32]), chunk=st.sampled_from([4, 8, 16]),
       h=st.sampled_from([1, 2]), n=st.sampled_from([4, 8]))
@settings(max_examples=10, deadline=None)
def test_ssd_chunked_equals_naive_recurrence(s, chunk, h, n):
    b, p = 2, 4
    ks = jax.random.split(jax.random.PRNGKey(s * 7 + chunk), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B_ = jax.random.normal(ks[3], (b, s, n))
    C_ = jax.random.normal(ks[4], (b, s, n))
    D = jnp.ones((h,))
    y, final = mamba2.ssd_chunked(x, dt, A, B_, C_, D, chunk)
    y_ref, h_ref = _naive_ssm(x, dt, A, B_, C_, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(final), h_ref, rtol=1e-3, atol=1e-3)


def test_ssd_initial_state_continuation():
    """ssd(x[:half]) then ssd(x[half:], initial_state) == ssd(x) — the
    property that makes SSM prefill->decode handoff exact."""
    b, s, h, p, n = 1, 32, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(0.3 * jax.random.normal(ks[2], (h,)))
    B_ = jax.random.normal(ks[3], (b, s, n))
    C_ = jax.random.normal(ks[4], (b, s, n))
    D = jnp.ones((h,))
    y_all, _ = mamba2.ssd_chunked(x, dt, A, B_, C_, D, 8)
    y1, st1 = mamba2.ssd_chunked(x[:, :16], dt[:, :16], A, B_[:, :16],
                                 C_[:, :16], D, 8)
    y2, _ = mamba2.ssd_chunked(x[:, 16:], dt[:, 16:], A, B_[:, 16:],
                               C_[:, 16:], D, 8, initial_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------------- MoE --

def _moe_cfg(e=4, k=2, cf=8.0):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                       n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                       n_experts=e, top_k=k, capacity_factor=cf)


def test_moe_no_drop_equals_dense_mixture():
    """With capacity high enough to drop nothing, sort-based dispatch must
    equal the dense weighted mixture of expert outputs."""
    cfg = _moe_cfg()
    defs = moe.moe_defs(cfg)
    params = pm.init_params(defs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out, aux = moe.moe_apply(cfg, params, x)

    # dense oracle
    t = x.reshape(-1, cfg.d_model)
    logits = t @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    tp, ti = jax.lax.top_k(probs, cfg.top_k)
    tp = tp / tp.sum(-1, keepdims=True)
    expert_out = jnp.einsum(
        "td,edf->tef", t, params["wi"]) * jax.nn.silu(
        jnp.einsum("td,edf->tef", t, params["wg"]))
    expert_out = jnp.einsum("tef,efd->ted", expert_out, params["wo"])
    want = jnp.zeros_like(t)
    for kk in range(cfg.top_k):
        want = want + tp[:, kk, None] * jnp.take_along_axis(
            expert_out, ti[:, kk, None, None].repeat(cfg.d_model, -1),
            axis=1)[:, 0]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(want), rtol=2e-3, atol=2e-3)
    assert float(aux) > 0.0


def test_moe_capacity_drops_are_bounded():
    """With tight capacity, output norm shrinks but stays finite; dispatch
    never mixes tokens across experts (verified via conservation)."""
    cfg = _moe_cfg(cf=0.5)
    params = pm.init_params(moe.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, _ = moe.moe_apply(cfg, params, x)
    assert np.isfinite(np.asarray(out)).all()


@given(e=st.sampled_from([2, 4, 8]), k=st.sampled_from([1, 2]),
       t=st.sampled_from([16, 64]))
@settings(max_examples=8, deadline=None)
def test_moe_router_probs_renormalized(e, k, t):
    cfg = _moe_cfg(e=e, k=k)
    params = pm.init_params(moe.moe_defs(cfg), jax.random.PRNGKey(e * k))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, t, cfg.d_model))
    out, aux = moe.moe_apply(cfg, params, x)
    assert out.shape == x.shape and np.isfinite(np.asarray(out)).all()
    assert np.isfinite(float(aux))


# ------------------------------------------------------------------- RoPE --

def test_rope_relative_shift_invariance():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    d = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
    def dot(i, j):
        qr = cm.apply_rope(q, jnp.array([i]), 10_000.0)
        kr = cm.apply_rope(k, jnp.array([j]), 10_000.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot(5, 3) - dot(105, 103)) < 1e-3
    assert abs(dot(7, 0) - dot(507, 500)) < 1e-3


def test_gemma3_window_pattern():
    cfg = R.get_config("gemma3-4b")
    wins = [cfg.layer_window(i) for i in range(cfg.n_layers)]
    # every 6th layer global (window 0), the rest local
    assert all(w == 0 for i, w in enumerate(wins) if (i + 1) % 6 == 0)
    assert all(w == 1024 for i, w in enumerate(wins) if (i + 1) % 6 != 0)
    n_global = sum(w == 0 for w in wins)
    assert n_global == cfg.n_layers // 6  # 5:1 local:global
