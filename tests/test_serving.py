"""Live-endpoint serving: hot weight swap + the serve-path regressions.

The tentpole proof: a server whose weights are swapped mid-sequence emits
post-swap tokens BITWISE-equal to a server restarted from that checkpoint
(the "refresh" replay policy, launch/batching.py `maybe_swap`), with every
emitted token stamped with its swap epoch.  Plus the end-to-end form — a
RoundEngine training run publishing checkpoints through an AsyncObserver
while the server decodes — and the three serve-path bugfix regressions
(stale slot recycle, VLM cache overflow, off-by-one retire).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import engine as E
from repro.core import observer as OBS
from repro.core import schedules
from repro.launch import weights as W
from repro.launch.batching import ContinuousBatcher, Request
from repro.launch.serve import generate, run_service
from repro.models import api, param as pm
from repro.optim.lr import make_lr_fn


def _params(cfg, seed=0):
    mod = api.get_module(cfg)
    return pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(seed),
                          jnp.float32)


def _prompt(cfg, seed, n):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,), 0,
                                         cfg.vocab), np.int32)


# ------------------------------------------------------- ServingWeights --

def test_serving_weights_flat_roundtrip_and_audit():
    """Flat-bucket round-trip is bitwise, swap() replaces the buckets and
    appends the audit row."""
    cfg = R.get_smoke_config("gemma3-4b")
    p0, p1 = _params(cfg, 0), _params(cfg, 7)
    sw = W.ServingWeights(cfg, p0, step=3, source="init")
    for a, b in zip(jax.tree.leaves(sw.as_tree()), jax.tree.leaves(p0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ep = sw.swap(p1, step=11, source="publish", tokens_before=5)
    assert (sw.epoch, sw.step) == (1, 11)
    assert (ep.index, ep.step, ep.tokens_before) == (1, 11, 5)
    for a, b in zip(jax.tree.leaves(sw.as_tree()), jax.tree.leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rows = sw.audit()
    assert [r["index"] for r in rows] == [0, 1]
    assert [r["step"] for r in rows] == [3, 11]


def test_weight_subscriber_latest_wins():
    cfg = R.get_smoke_config("gemma3-4b")
    sub = W.WeightSubscriber()
    sub.publish(1, _params(cfg, 1))
    sub.publish(3, _params(cfg, 3))
    sub.publish(2, _params(cfg, 2))     # older than queued: dropped
    step, source, _ = sub.take()
    assert (step, source) == (3, "publish")
    assert sub.superseded == 1
    assert sub.take() is None


# ----------------------------------------------- hot swap: bitwise proof --

def test_hot_swap_matches_restart_from_checkpoint():
    """The tentpole: publish new weights mid-sequence; post-swap tokens must
    be bitwise what a fresh server restarted from those weights emits given
    the same known token stream, and the epoch stamps must split the stream
    exactly at the swap."""
    cfg = R.get_smoke_config("gemma3-4b")
    w0, w1 = _params(cfg, 0), _params(cfg, 7)
    prompt = _prompt(cfg, 1, 5)

    sub = W.WeightSubscriber()
    batcher = ContinuousBatcher(cfg, w0, slots=2, max_len=48, subscriber=sub)
    req = Request(rid=0, prompt=prompt, max_new=8)
    batcher.submit(req)
    # 5-token prompt: 4 slot-local prefill steps, then one token per step
    while len(req.out) < 3:
        batcher.step()
    sub.publish(1, w1)
    batcher.run()

    assert req.done and len(req.out) == 8
    assert batcher.swaps == 1
    assert req.epochs == [0] * 3 + [1] * 5
    swap_row = batcher.weights.epochs[-1]
    assert (swap_row.index, swap_row.step, swap_row.tokens_before) == (1, 1, 3)

    # restart reference: a fresh server on w1, fed prompt + the 3 tokens
    # the old weights emitted, must continue with the same 5 tokens
    ref = ContinuousBatcher(cfg, w1, slots=2, max_len=48)
    prompt2 = np.concatenate([prompt, np.asarray(req.out[:3], np.int32)])
    rref = Request(rid=0, prompt=prompt2, max_new=5)
    ref.submit(rref)
    ref.run()
    assert rref.out == req.out[3:]


def test_hot_swap_e2e_training_publishes_while_serving():
    """End-to-end: a QSR training run publishes its consensus params through
    an AsyncObserver (via `fanout`) into a watch dir; the serving loop polls
    it up mid-sequence, swaps, and the post-swap tail is bitwise equal to a
    server restarted from the restored checkpoint."""
    import tempfile
    cfg = R.get_smoke_config("starcoder2-3b")
    run = RunConfig(schedule="qsr", optimizer="adamw", total_steps=8,
                    peak_lr=3e-3, end_lr=1e-6, warmup_steps=2, h_base=2,
                    alpha=0.001, remat=False, weight_decay=0.01)
    lr_fn = make_lr_fn(run)
    watch = tempfile.mkdtemp(prefix="repro-test-watch-")

    p0 = _params(cfg, 0)
    prompt = _prompt(cfg, 2, 6)
    sub = W.WeightSubscriber(watch_dir=watch, like=W.params_like(cfg))
    batcher = ContinuousBatcher(cfg, p0, slots=1, max_len=64, subscriber=sub)
    req = Request(rid=0, prompt=prompt, max_new=7)
    batcher.submit(req)
    while len(req.out) < 2:        # emit 2 tokens under the initial weights
        batcher.step()

    published = []
    obs = OBS.AsyncObserver(OBS.fanout(
        lambda step, snap: W.publish_weights(watch, snap, step=step),
        lambda step, snap: published.append(step)))
    eng = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16)
    state = eng.init_state(p0)
    for t, h in schedules.rounds(run, lr_fn):
        state, _ = eng.run_round(state, t, h, lr_fn)
        obs.submit(t + h, eng.params_single(eng.synced_view(state)))
    obs.close()
    # latest-wins may drop intermediate submits but never the final one
    assert published[-1] == run.total_steps
    assert published == sorted(published)

    batcher.run()                  # first step polls, swaps, replays
    assert req.done and len(req.out) == 7
    assert batcher.swaps == 1
    assert batcher.weights.step == run.total_steps
    assert req.epochs == [0] * 2 + [1] * 5
    assert batcher.weights.epochs[-1].source == f"watch:{watch}"

    # restart-from-the-checkpoint reference, restored from disk
    tree, got_step, extra = W.load_weights(watch, W.params_like(cfg))
    assert got_step == run.total_steps
    assert extra["kind"] == W.WEIGHTS_KIND
    ref = ContinuousBatcher(cfg, tree, slots=1, max_len=64)
    prompt2 = np.concatenate([prompt, np.asarray(req.out[:2], np.int32)])
    rref = Request(rid=0, prompt=prompt2, max_new=5)
    ref.submit(rref)
    ref.run()
    assert rref.out == req.out[2:]


def test_run_service_audit_and_swap_hook():
    """run_service drives mixed-length requests to completion and the audit
    carries per-token epoch attribution across a mid-run swap."""
    cfg = R.get_smoke_config("gemma3-4b")
    w0, w1 = _params(cfg, 0), _params(cfg, 7)
    sub = W.WeightSubscriber()
    prompts = [_prompt(cfg, i, n) for i, n in enumerate((4, 6, 5))]
    hooks = [(6, lambda b: sub.publish(1, w1))]
    reqs, audit = run_service(cfg, W.ServingWeights(cfg, w0), prompts,
                              slots=2, max_new=4, subscriber=sub, hooks=hooks)
    assert all(r.done and len(r.out) == 4 for r in reqs)
    assert audit["swaps"] == 1
    assert audit["tokens_emitted"] == 12
    assert [row["index"] for row in audit["swap_epochs"]] == [0, 1]
    flat = [e for r in audit["requests"] for e in r["epochs"]]
    assert set(flat) == {0, 1}      # tokens attributed on both sides


# -------------------------------------------- regression: slot recycle ---

def test_slot_recycle_clears_stateful_cache():
    """A recycled slot's cache lane must be zeroed on admit: mamba2's SSM /
    conv state otherwise leaks the previous request into the new one (the
    KV families mask it positionally, recurrent families do not)."""
    cfg = R.get_smoke_config("mamba2-130m")
    params = _params(cfg, 0)
    pa, pb = _prompt(cfg, 1, 6), _prompt(cfg, 2, 5)

    batcher = ContinuousBatcher(cfg, params, slots=1, max_len=32)
    r1 = Request(rid=0, prompt=pa, max_new=4)
    r2 = Request(rid=1, prompt=pb, max_new=4)
    batcher.submit(r1)
    batcher.submit(r2)
    batcher.run()
    assert r1.done and r2.done

    fresh = ContinuousBatcher(cfg, params, slots=1, max_len=32)
    ref = Request(rid=1, prompt=pb, max_new=4)
    fresh.submit(ref)
    fresh.run()
    assert r2.out == ref.out, "recycled slot leaked SSM state"


# ------------------------------------------ regression: VLM cache bound --

def test_vlm_default_max_len_counts_image_prefix():
    """`generate`'s default cache length must include the bidirectional
    image prefix: a gen_len crossing the old (plen+gen_len) bound silently
    corrupted the cache tail via clamped dynamic_update_slice."""
    cfg = R.get_smoke_config("paligemma-3b")
    params = _params(cfg, 0)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    extra = {"prefix_embeds": 0.02 * jax.random.normal(
        jax.random.PRNGKey(2), (2, cfg.n_img_tokens, cfg.d_model))}
    gen = cfg.n_img_tokens + 10     # crosses the un-fixed default bound
    want = generate(cfg, params, prompts, gen_len=gen, max_len=96,
                    extra=extra)
    got = generate(cfg, params, prompts, gen_len=gen, extra=extra)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_generate_raises_on_cache_overflow():
    cfg = R.get_smoke_config("paligemma-3b")
    params = _params(cfg, 0)
    prompts = jnp.asarray([_prompt(cfg, 1, 4)])
    extra = {"prefix_embeds": 0.02 * jax.random.normal(
        jax.random.PRNGKey(2), (1, cfg.n_img_tokens, cfg.d_model))}
    short = 4 + cfg.n_img_tokens + 5 - 1      # one position too small
    with pytest.raises(ValueError, match="exceed the KV cache"):
        generate(cfg, params, prompts, gen_len=5, max_len=short, extra=extra)


# -------------------------------------------- regression: retire bound ---

def test_retire_uses_last_cache_position():
    """A slot's last legal cache write is position max_len-1, whose decode
    yields one more token: a 6-token prompt in a 16-slot lane must emit
    16-6+1 = 11 tokens, not 10 (the old off-by-one)."""
    cfg = R.get_smoke_config("gemma3-4b")
    params = _params(cfg, 0)
    prompt = _prompt(cfg, 1, 6)
    batcher = ContinuousBatcher(cfg, params, slots=1, max_len=16)
    req = Request(rid=0, prompt=prompt, max_new=100)
    batcher.submit(req)
    batcher.run()
    assert req.done
    assert len(req.out) == 11
    # and they are the true greedy continuation, not junk from a wrapped lane
    want = generate(cfg, params, jnp.asarray(prompt)[None], gen_len=11,
                    max_len=17)
    assert req.out == np.asarray(want[0, 6:]).tolist()


def test_submit_rejects_overlong_prompt():
    cfg = R.get_smoke_config("gemma3-4b")
    batcher = ContinuousBatcher(cfg, _params(cfg, 0), slots=1, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        batcher.submit(Request(rid=0, prompt=_prompt(cfg, 1, 9), max_new=2))


# --------------------------------------------------- sampling paths ------

def test_generate_temperature_deterministic_under_seed():
    cfg = R.get_smoke_config("gemma3-4b")
    params = _params(cfg, 0)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, cfg.vocab)
    a = generate(cfg, params, prompts, gen_len=8, temperature=1.0, seed=3)
    b = generate(cfg, params, prompts, gen_len=8, temperature=1.0, seed=3)
    c = generate(cfg, params, prompts, gen_len=8, temperature=1.0, seed=4)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_batcher_sampling_is_per_request_deterministic():
    """Token t of request r is a pure function of (seed, rid, t): the same
    requests sampled under different slot counts — different co-scheduling,
    different batch indices — must produce identical streams."""
    cfg = R.get_smoke_config("gemma3-4b")
    params = _params(cfg, 0)
    prompts = [_prompt(cfg, i, n) for i, n in enumerate((5, 7, 6))]

    def serve(slots):
        b = ContinuousBatcher(cfg, params, slots=slots, max_len=32,
                              temperature=1.0, seed=11)
        rs = [Request(rid=i, prompt=p, max_new=5)
              for i, p in enumerate(prompts)]
        for r in rs:
            b.submit(r)
        b.run()
        return [r.out for r in rs]

    solo = serve(1)
    packed = serve(3)
    assert solo == packed
    assert any(len(set(o)) > 1 for o in solo)   # actually sampling


def test_batcher_sampling_survives_hot_swap_replay():
    """Post-swap replay rejoins the same per-request sample stream: the
    restart reference must match even at temperature > 0 (fold_in keys are
    indexed by emitted count, not decode step)."""
    cfg = R.get_smoke_config("gemma3-4b")
    w0, w1 = _params(cfg, 0), _params(cfg, 7)
    prompt = _prompt(cfg, 1, 5)
    sub = W.WeightSubscriber()
    batcher = ContinuousBatcher(cfg, w0, slots=1, max_len=48,
                                temperature=1.0, seed=5, subscriber=sub)
    req = Request(rid=0, prompt=prompt, max_new=7)
    batcher.submit(req)
    while len(req.out) < 3:
        batcher.step()
    sub.publish(1, w1)
    batcher.run()
    assert req.done and batcher.swaps == 1

    # restart reference restores the request's in-flight state (same rid,
    # pre-swap tokens as `out`), so its sample keys continue at count 3
    ref = ContinuousBatcher(cfg, w1, slots=1, max_len=48,
                            temperature=1.0, seed=5)
    rref = Request(rid=0, prompt=prompt, max_new=7, out=list(req.out[:3]))
    ref.submit(rref)
    ref.run()
    assert rref.out[3:] == req.out[3:]
