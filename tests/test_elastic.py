"""Elastic rounds: partial-participation sync + round-boundary membership.

The contract under test (core/sync.py §Partial participation,
core/engine.py §sync="partial" / MembershipEpoch):

  * `make_sync_partial` with an all-ones mask is BITWISE the blocking sync
    for power-of-two W, on every layout — the partial path is the blocking
    path with a mask, not a reimplementation;
  * a masked (quantized) sync equals a W'=|P| run over just the participant
    rows, bitwise — Σ_{i∈P} q_i / |P| is the same integer sum whether the
    absent lanes contribute zero codes or don't exist.  |P|=3 is deliberate:
    non-power-of-two divisors are where f32 mean-vs-division tricks break,
    and the integer-code domain doesn't care;
  * the exact apply broadcasts consensus to ALL W lanes — a masked lane
    re-anchors at the same boundary (the rejoin rule);
  * `membership_epoch()` is the only legal mutation point for the worker
    set: masks change without recompiling (traced argument), resizes re-pad
    the W axis through the tree layout and park — not evict — the old-W
    compile-cache entries, and every change appends a MembershipEpoch;
  * `restore_elastic` accepts a checkpoint written under ANY worker count:
    surviving lanes restore bitwise, joining lanes clone lane 0 (params AND
    moments — the consensus replica a rejoining worker re-anchors to).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import engine as E
from repro.core import flat as F
from repro.core import schedules
from repro.core.sync import make_sync, make_sync_begin, make_sync_partial
from repro.optim.lr import make_lr_fn


# ------------------------------------------------ sync-level (no engine) --

def _demo_params(seed=0):
    rng = np.random.RandomState(seed)
    mk = lambda *s: jnp.asarray(rng.randn(*s).astype(np.float32))
    return {"w_in": mk(13, 24), "bias": mk(17), "gate": mk(3, 5, 7),
            "h_bf16": mk(9, 11).astype(jnp.bfloat16)}


def _flat_state(spec, params, w, quantize, momentum):
    stacked = {k: jnp.broadcast_to(v[None], (w,) + v.shape)
               for k, v in params.items()}
    st = {"params": spec.flatten(stacked, lead=1)}
    if quantize or momentum > 0.0:
        st["anchor"] = spec.flatten(params)
    if momentum > 0.0:
        st["outer_mu"] = {b: jnp.zeros(spec.buffer_size(b), jnp.float32)
                          for b in spec.buckets}
    return st


def _perturb(st, spec, noise):
    nb = spec.flatten({k: jnp.asarray(v) for k, v in noise.items()}, lead=1)
    return dict(st, params={b: st["params"][b] + nb[b].astype(
        st["params"][b].dtype) for b in st["params"]})


@pytest.mark.parametrize("quantize,momentum", [
    (False, 0.0), (True, 0.0), (True, 0.9),
])
def test_partial_all_ones_bitwise_blocking_sync(quantize, momentum):
    """All-ones partial == blocking, bitwise, for power-of-two W (Σ/W as
    true IEEE division matches jnp.mean's reciprocal multiply exactly iff
    the divisor is a power of two)."""
    w, rounds = 4, 3
    params = _demo_params()
    run_cfg = RunConfig(sync_quantize=quantize, outer_momentum=momentum)
    spec = F.ShardedFlatSpace(params, w)
    part = jax.jit(make_sync_partial(run_cfg, spec))
    # blocking reference through the composed halves (the fused flat kernel
    # is proven equal to them in tests/test_flat.py)
    begin = jax.jit(make_sync_begin(run_cfg, spec))
    from repro.core.sync import make_sync_apply
    apply_ = jax.jit(make_sync_apply(run_cfg, spec))
    ones = jnp.ones(w, jnp.float32)
    sa = sb = _flat_state(spec, params, w, quantize, momentum)
    rng = np.random.RandomState(1)
    for _ in range(rounds):
        noise = {k: (rng.randn(w, *v.shape) * 0.01).astype(np.float32)
                 for k, v in params.items()}
        sa = part(_perturb(sa, spec, noise), ones)
        st = _perturb(sb, spec, noise)
        sb = apply_(st, begin(st))
    for k in sa:
        for b in sa[k]:
            np.testing.assert_array_equal(np.asarray(sa[k][b]),
                                          np.asarray(sb[k][b]))


def test_partial_masked_quantized_equals_participant_run():
    """The elastic exactness claim: mask [1,1,0,1] over W=4 produces
    bitwise the consensus of a 3-worker run over the participant rows
    (|P|=3 — a NON-power-of-two divisor; exact because the mean runs in
    the integer-code domain), and the masked lane re-anchors to it."""
    w, rows, rounds = 4, [0, 1, 3], 3
    params = _demo_params()
    run_cfg = RunConfig(sync_quantize=True)
    spec4 = F.ShardedFlatSpace(params, w)
    spec3 = F.ShardedFlatSpace(params, len(rows))
    part4 = jax.jit(make_sync_partial(run_cfg, spec4))
    part3 = jax.jit(make_sync_partial(run_cfg, spec3))
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    ones = jnp.ones(len(rows), jnp.float32)
    s4 = _flat_state(spec4, params, w, True, 0.0)
    s3 = _flat_state(spec3, params, len(rows), True, 0.0)
    rng = np.random.RandomState(2)
    for _ in range(rounds):
        noise = {k: (rng.randn(w, *v.shape) * 0.01).astype(np.float32)
                 for k, v in params.items()}
        s4 = part4(_perturb(s4, spec4, noise), mask)
        s3 = part3(_perturb(
            s3, spec3, {k: v[rows] for k, v in noise.items()}), ones)
    full = spec4.unflatten(s4["params"], lead=1)
    part = spec3.unflatten(s3["params"], lead=1)
    for k in full:
        # consensus over participants == the |P|-run's consensus, bitwise
        np.testing.assert_array_equal(np.asarray(full[k][0]),
                                      np.asarray(part[k][0]))
        # the masked lane was broadcast the same consensus: re-anchored
        np.testing.assert_array_equal(np.asarray(full[k][2]),
                                      np.asarray(full[k][0]))


def test_partial_scales_come_from_participants_only():
    """An absent lane with a huge delta must not inflate the quantization
    scales: its delta is zeroed BEFORE the amax statistic."""
    params = {"w": jnp.zeros((8, 8), jnp.float32)}
    run_cfg = RunConfig(sync_quantize=True)
    spec = F.ShardedFlatSpace(params, 2)
    st = _flat_state(spec, params, 2, True, 0.0)
    # lane 1 (masked) runs away; lane 0 moves by exactly 0.5 everywhere
    noise = {"w": np.stack([np.full((8, 8), 0.5, np.float32),
                            np.full((8, 8), 1e6, np.float32)])}
    out = make_sync_partial(run_cfg, spec)(
        _perturb(st, spec, noise), jnp.asarray([1.0, 0.0]))
    got = spec.unflatten(out["params"], lead=1)["w"]
    # participant amax = 0.5 -> codes ±127 exact -> consensus == +0.5.
    # had lane 1 leaked into the scale (1e6), 0.5 would quantize to 0.
    np.testing.assert_array_equal(np.asarray(got[0]),
                                  np.full((8, 8), 0.5, np.float32))


def test_partial_does_not_compose_with_ring_wire():
    run_cfg = RunConfig(sync_quantize=True, sync_wire="ring-int8")
    spec = F.ShardedFlatSpace(_demo_params(), 4)
    with pytest.raises(ValueError, match="partial"):
        make_sync_begin(run_cfg, spec, partial=True)


# ------------------------------------------------------- engine level -----

def _mk_engine(sync="partial", layout="flat_sharded", workers=4, steps=8,
               quantize=True, momentum=0.0, **kw):
    cfg = R.get_smoke_config("starcoder2-3b")
    run = RunConfig(schedule="constant", optimizer="adamw",
                    total_steps=steps, peak_lr=3e-3, warmup_steps=1,
                    h_base=2, remat=False, weight_decay=0.01,
                    sync_quantize=quantize, outer_momentum=momentum)
    eng = E.RoundEngine(cfg, run, workers=workers, b_loc=2, seq=16,
                        data="device", layout=layout, sync=sync, **kw)
    return eng, make_lr_fn(run)


@pytest.mark.parametrize("layout", ["tree", "flat", "flat_sharded"])
def test_engine_partial_all_ones_bitwise_blocking(layout):
    """A sync="partial" engine with default (all-ones) membership runs
    bitwise the blocking engine — same programs, same rounds, W=4."""
    ep, lr_fn = _mk_engine(sync="partial", layout=layout)
    eb, _ = _mk_engine(sync="blocking", layout=layout)
    sp, sb = ep.init_state(), eb.init_state()
    for t in (0, 2, 4):
        sp, mp = ep.run_round(sp, t, 2, lr_fn)
        sb, mb = eb.run_round(sb, t, 2, lr_fn)
        assert float(mp["loss"]) == float(mb["loss"])
    la, lb = jax.tree.leaves(sp), jax.tree.leaves(sb)
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_membership_mask_changes_without_recompile():
    """A membership change is a traced argument: masking lane 2 out and
    back in reuses the same (Hp, W) program — zero new compiles — and
    every change lands in the epoch audit trail."""
    eng, lr_fn = _mk_engine()
    st = eng.init_state()
    st, _ = eng.run_round(st, 0, 2, lr_fn)
    n = eng.compiles
    eng.membership_epoch([1, 1, 0, 1])
    st, _ = eng.run_round(st, 2, 2, lr_fn)
    eng.membership_epoch([1, 1, 1, 1])
    st, _ = eng.run_round(st, 4, 2, lr_fn)
    assert eng.compiles == n, "mask changes must not recompile"
    assert [e.membership for e in eng.epochs] == [
        (1.0, 1.0, 0.0, 1.0), (1.0, 1.0, 1.0, 1.0)]
    assert not any(e.resized for e in eng.epochs)


def test_engine_masked_lane_reanchors_to_consensus():
    """After a partial round, the masked lane's params equal lane 0's (the
    consensus broadcast) — the rejoin rule at the state level."""
    eng, lr_fn = _mk_engine(layout="tree")
    st = eng.init_state()
    eng.membership_epoch([1, 1, 0, 1])
    st, _ = eng.run_round(st, 0, 2, lr_fn)
    for leaf in jax.tree.leaves(st["params"]):
        np.testing.assert_array_equal(np.asarray(leaf[2]),
                                      np.asarray(leaf[0]))


def test_membership_epoch_guards():
    eng, lr_fn = _mk_engine()
    st = eng.init_state()
    with pytest.raises(E.MembershipError, match="at least one participant"):
        eng.membership_epoch([0, 0, 0, 0])
    with pytest.raises(E.MembershipError, match="must be"):
        eng.membership_epoch([1, 1, 1])
    with pytest.raises(E.MembershipError, match="needs the run state"):
        eng.membership_epoch(keep_lanes=(0, 1))
    with pytest.raises(E.MembershipError, match="out of range"):
        eng.membership_epoch(state=st, keep_lanes=(0, 9))
    with pytest.raises(E.MembershipError, match="does not grow"):
        eng.membership_epoch(state=st, grow_to=4)
    # a pending overlap sync blocks ANY membership change
    eo, lr_fn = _mk_engine(sync="overlap", mode="bucketed")
    so = eo.init_state()
    so, _ = eo.run_round(so, 0, 2, lr_fn)
    with pytest.raises(E.MembershipError, match="round boundary"):
        eo.membership_epoch([1, 1, 0, 1])


def test_membership_resize_refused_under_mesh():
    """Mesh-backed engines resize via checkpoint + respawn, never in place
    (jax.distributed cannot shrink a live process group)."""
    jmesh = jax.make_mesh((1, 1), ("data", "model"))
    eng, _ = _mk_engine(workers=1, mesh=jmesh, policy="dp")
    st = eng.init_state()
    with pytest.raises(E.MembershipError, match="respawn"):
        eng.membership_epoch(state=st, keep_lanes=(0,))


def test_engine_resize_shrink_then_grow_clones_consensus():
    """keep_lanes shrinks the W axis (kept lanes bitwise); grow_to clones
    lane 0's params AND moments into the joined lane; the old-W compile
    cache entries are parked, not evicted, and the epoch trail records
    both resizes."""
    eng, lr_fn = _mk_engine(workers=4)
    st = eng.init_state()
    st, _ = eng.run_round(st, 0, 2, lr_fn)
    before = jax.tree.map(np.asarray, F.to_tree_state(eng.spec, st))
    st = eng.membership_epoch(state=st, keep_lanes=(0, 1, 3))
    assert eng.workers == 3
    shrunk = F.to_tree_state(eng.spec, st)
    la = jax.tree.leaves(before["params"])
    lb = jax.tree.leaves(shrunk["params"])
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(a[[0, 1, 3]], np.asarray(b))
    st, _ = eng.run_round(st, 2, 2, lr_fn)          # runs at W=3
    assert (2, 3) in eng._programs and (2, 4) in eng._programs
    st = eng.membership_epoch(state=st, grow_to=4)
    assert eng.workers == 4
    grown = F.to_tree_state(eng.spec, st)
    for leaf in jax.tree.leaves(grown["params"]):
        np.testing.assert_array_equal(np.asarray(leaf[3]),
                                      np.asarray(leaf[0]))
    for k in ("m", "v"):
        for leaf in jax.tree.leaves(grown["opt"][k]):
            np.testing.assert_array_equal(np.asarray(leaf[3]),
                                          np.asarray(leaf[0]))
    resizes = [e for e in eng.epochs if e.resized]
    assert [e.workers for e in resizes] == [3, 4]
    # the W=4 programs were parked by the shrink and reused by the regrow
    assert any(k[-1] == 4 for k in resizes[0].parked)
    n = eng.compiles
    st, _ = eng.run_round(st, 4, 2, lr_fn)
    assert eng.compiles == n, "regrow to a parked W must not recompile"


@pytest.mark.parametrize("restore_layout", ["tree", "flat", "flat_sharded"])
def test_restore_elastic_across_worker_counts(tmp_path, restore_layout):
    """A checkpoint written at W=4 restores under W=3 (surviving lanes
    bitwise) and W=5 (the joined lane cloning lane 0 = consensus), into
    any layout."""
    src, lr_fn = _mk_engine(workers=4)
    st = src.init_state()
    st, _ = src.run_round(st, 0, 2, lr_fn)
    path = str(tmp_path / "ck")
    src.save(path, st, step=2)
    src_tree = jax.tree.map(np.asarray, F.to_tree_state(src.spec, st))

    for w in (3, 5):
        dst, _ = _mk_engine(workers=w, layout=restore_layout)
        got, step = dst.restore_elastic(path, dst.init_state())
        assert step == 2
        tree = (got if restore_layout == "tree"
                else F.to_tree_state(dst.spec, got))
        la = jax.tree.leaves(src_tree["params"])
        lb = jax.tree.leaves(tree["params"])
        for a, b in zip(la, lb):
            b = np.asarray(b)
            np.testing.assert_array_equal(a[:min(w, 4)], b[:min(w, 4)])
            if w == 5:
                np.testing.assert_array_equal(b[4], a[0])
        assert dst.h_trace == [(0, 2)]
        assert np.all(dst.membership == 1.0)
