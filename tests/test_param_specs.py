"""Property tests for the declarative param/sharding-spec system."""
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import registry as R
from repro.models import api, param as pm
from repro.models.param import ParamDef


class _FakeMesh:
    def __init__(self, sizes):
        import numpy as np
        self.axis_names = tuple(sizes)
        self.devices = np.zeros(tuple(sizes.values()))


MESH1 = _FakeMesh({"data": 16, "model": 16})
MESH2 = _FakeMesh({"pod": 2, "data": 16, "model": 16})


@given(dim=st.integers(1, 4096), policy=st.sampled_from(["dp", "fsdp"]))
@settings(max_examples=40, deadline=None)
def test_specs_only_shard_divisible_dims(dim, policy):
    d = ParamDef((dim, dim), ("embed", "mlp"))
    spec = pm.spec_for(d.axes, d.shape, policy, MESH1)
    for entry, size in zip(spec, d.shape):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= dict(zip(MESH1.axis_names, MESH1.devices.shape))[a]
        assert size % total == 0


@pytest.mark.parametrize("arch", list(R.ARCHS))
@pytest.mark.parametrize("mesh", [MESH1, MESH2])
def test_full_config_specs_all_divisible(arch, mesh):
    """Every FULL-size parameter of every assigned arch gets a legal spec
    under its default policy on both production meshes."""
    cfg = R.get_config(arch)
    policy = R.get_policy(arch)
    defs = api.get_module(cfg).param_defs(cfg)
    specs = pm.param_specs(defs, policy, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for d, s in zip(jax.tree.leaves(defs, is_leaf=pm.is_def),
                    jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        for dim, entry in zip(d.shape, tuple(s)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = 1
            for a in axes:
                total *= sizes[a]
            assert dim % total == 0, (arch, d.shape, s)


def test_no_mesh_axis_claimed_twice_per_tensor():
    d = ParamDef((256, 256, 256), ("experts", "embed", "mlp"))
    spec = pm.spec_for(d.axes, d.shape, "fsdp", MESH2)
    used = []
    for entry in spec:
        if entry is None:
            continue
        used += list(entry) if isinstance(entry, tuple) else [entry]
    assert len(used) == len(set(used))


def test_worker_counts():
    assert pm.worker_count("dp", MESH1) == 16
    assert pm.worker_count("dp", MESH2) == 32
    assert pm.worker_count("fsdp", MESH1) == 1
    assert pm.worker_count("fsdp", MESH2) == 2
