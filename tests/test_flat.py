"""FlatParamSpace and the flat hot paths (core/flat.py).

The contract under test:
  * flatten/unflatten is an exact round trip for any pytree (ragged shapes,
    mixed dtypes, leading worker axes) — pure layout ops;
  * a full bucketed multi-round run under layout="flat" produces *bitwise*
    the params/optimizer state of layout="tree", for both paper algorithms
    (Alg. 2 local rounds and the Alg. 1 parallel schedule) and with the
    beyond-paper sync options (int8 quantize, outer Nesterov) on and off;
  * the quantization scale guard: an all-zero delta round-trips to exact
    zeros and tiny deltas keep per-tensor precision (the old +1e-12
    additive guard dilated the quantization grid by up to ~100x);
  * the lowering claim (subprocess, sharded host mesh): the flat sync
    compiles to one all-reduce per dtype bucket vs one per leaf for tree.
"""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import engine as E
from repro.core import flat as F
from repro.core import schedules
from repro.core.sync import _quantize_delta
from repro.optim.lr import make_lr_fn

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _tree_of(shapes_dtypes, seed=0):
    rng = np.random.RandomState(seed)
    return {f"p{i}": jnp.asarray(rng.randn(*shp).astype(np.float32)).astype(dt)
            for i, (shp, dt) in enumerate(shapes_dtypes)}


# ------------------------------------------------------------ round trip --

def test_flatten_unflatten_mixed_dtypes_and_lead_axis():
    tree = _tree_of([((3, 5), jnp.float32), ((7,), jnp.bfloat16),
                     ((2, 2, 2), jnp.float32), ((1,), jnp.bfloat16)])
    spec = F.FlatParamSpace(tree)
    assert spec.buckets == ("bfloat16", "float32")
    assert spec.sizes == {"bfloat16": 8, "float32": 23}
    back = spec.unflatten(spec.flatten(tree))
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(tree[k], np.float32))
    # leading worker axis
    stacked = jax.tree.map(lambda x: jnp.stack([x, x + 1]), tree)
    bufs = spec.flatten(stacked, lead=1)
    assert all(b.shape == (2, spec.sizes[k]) for k, b in bufs.items())
    back2 = spec.unflatten(bufs, lead=1)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back2[k], np.float32),
                                      np.asarray(stacked[k], np.float32))


def test_segment_max_equals_per_leaf_max():
    tree = _tree_of([((4, 3), jnp.float32), ((11,), jnp.float32),
                     ((2, 5), jnp.float32)], seed=3)
    spec = F.FlatParamSpace(tree)
    buf = spec.flatten(tree)["float32"]
    per_leaf = spec.segment_max("float32", jnp.abs(buf))
    want = [float(jnp.max(jnp.abs(tree[k]))) for k in ("p0", "p1", "p2")]
    np.testing.assert_array_equal(np.asarray(per_leaf), np.asarray(want))
    # spread() puts each leaf's statistic on each of its elements
    spread = np.asarray(spec.spread("float32", per_leaf))
    seg = spec.segment_ids("float32")
    np.testing.assert_array_equal(spread, np.asarray(want)[seg])


def test_state_conversion_round_trip():
    cfg = R.get_smoke_config("starcoder2-3b")
    run = RunConfig(optimizer="adamw", remat=False, sync_quantize=True,
                    outer_momentum=0.9)
    from repro.core import local_update as LU
    from repro.models import api, param as pm
    params = pm.init_params(api.get_module(cfg).param_defs(cfg),
                            jax.random.PRNGKey(0))
    state = LU.init_state(cfg, run, params, 2)
    spec = F.FlatParamSpace(params)
    back = F.to_tree_state(spec, F.to_flat_state(spec, state))
    la, lb = jax.tree.flatten(state), jax.tree.flatten(back)
    assert la[1] == lb[1]
    for a, b in zip(la[0], lb[0]):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# -------------------------------------------------- hypothesis property ---

try:
    import hypothesis  # noqa: F401
    _HYP = True
except ImportError:
    _HYP = False

if _HYP:
    from hypothesis import given, settings, strategies as st

    _shape = st.lists(st.integers(1, 7), min_size=0, max_size=3).map(tuple)
    _dtype = st.sampled_from([jnp.float32, jnp.bfloat16, jnp.int32])
    _leaves = st.lists(st.tuples(_shape, _dtype), min_size=1, max_size=8)

    @given(leaves=_leaves, lead=st.integers(0, 1), seed=st.integers(0, 99))
    @settings(max_examples=40, deadline=None)
    def test_round_trip_property(leaves, lead, seed):
        rng = np.random.RandomState(seed)
        tree = {}
        for i, (shp, dt) in enumerate(leaves):
            full = ((2,) * lead) + shp
            x = rng.randn(*full) * 100 if full else rng.randn() * 100
            tree[f"p{i}"] = jnp.asarray(np.asarray(x, np.float32)).astype(dt)
        single = (jax.tree.map(lambda x: x[0], tree) if lead else tree)
        spec = F.FlatParamSpace(single)
        assert sum(spec.sizes.values()) == sum(
            int(np.prod(s, dtype=np.int64)) if s else 1 for s, _ in leaves)
        back = spec.unflatten(spec.flatten(tree, lead=lead), lead=lead)
        la, _ = jax.tree.flatten(tree)
        lb, tb = jax.tree.flatten(back)
        assert tb == spec.treedef if not lead else True
        for a, b in zip(la, lb):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


# ---------------------------------------------- quantization scale guard --

def test_quantize_all_zero_delta_is_exactly_zero():
    out = _quantize_delta({"a": jnp.zeros((3, 17), jnp.float32)})["a"]
    np.testing.assert_array_equal(np.asarray(out),
                                  np.zeros((3, 17), np.float32))


def test_quantize_tiny_delta_keeps_per_tensor_precision():
    """Regression: the old `amax + 1e-12` scale dilated the int8 grid to
    ~1e-12/127 regardless of the tensor's actual range, so a delta with
    amax=1e-14 quantized with ~20% error; the guarded scale keeps the error
    within half a quantization level (amax/254)."""
    amax = 1e-14
    d = (jnp.linspace(-1.0, 1.0, 64).astype(jnp.float32) * amax)
    dq = _quantize_delta({"x": d})["x"]
    err = np.abs(np.asarray(dq) - np.asarray(d)).max()
    assert err <= amax / 254 + 1e-30, err


def test_quantized_sync_error_still_bounded():
    """The guard must not loosen the normal-range error bound."""
    rng = np.random.RandomState(0)
    d = jnp.asarray(rng.randn(4, 100).astype(np.float32))
    dq = _quantize_delta({"x": d})["x"]
    amax = float(jnp.max(jnp.abs(d)))
    assert np.abs(np.asarray(dq) - np.asarray(d)).max() <= amax / 254 * 1.01


# ------------------------------------------- fused sync kernel vs oracle --
# Lives here (not test_kernels.py) so it runs without hypothesis installed.

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("w,n", [(2, 300), (4, 70_000), (8, 1111)])
@pytest.mark.parametrize("quantize,momentum", [(False, 0.0), (True, 0.0),
                                               (False, 0.9), (True, 0.9)])
def test_sync_flat_update_matches_oracle(w, n, dtype, quantize, momentum):
    from functools import partial

    from repro.kernels import ref
    from repro.kernels.sync_update import sync_flat_update

    rng = np.random.RandomState(n + w)
    p = jnp.asarray(rng.randn(w, n), dtype)
    anchor = jnp.asarray(rng.randn(n), dtype)
    scale = (jnp.asarray(np.abs(rng.randn(n)) + 0.1, jnp.float32)
             if quantize else None)
    mu = jnp.asarray(rng.randn(n), jnp.float32) if momentum else None
    got = sync_flat_update(p, anchor, scale=scale, mu=mu, momentum=momentum,
                           interpret=True)
    # jit the oracle too: eager-vs-jit already differs at ulp level (XLA
    # contracts mul+add to FMA), which is not what this test measures
    want = jax.jit(partial(ref.sync_flat_update, momentum=momentum))(
        p, anchor, scale=scale, mu=mu)
    for g, w_ in zip(got, want):
        if w_ is None:
            assert g is None
            continue
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w_, np.float32),
                                   rtol=TOL[dtype], atol=TOL[dtype])


@pytest.mark.parametrize("n", [300, 70_000])
@pytest.mark.parametrize("quantize,momentum", [(False, 0.0), (True, 0.0),
                                               (False, 0.9), (True, 0.9)])
def test_sync_apply_update_matches_oracle(n, quantize, momentum):
    """The gather-leg kernel (dequant + Nesterov + anchor in one pass) vs
    its jnp oracle — the fused half `--sync overlap` defers."""
    from functools import partial

    from repro.kernels import ref
    from repro.kernels.sync_update import sync_apply_update

    rng = np.random.RandomState(n)
    step_in = (jnp.asarray(rng.randint(-127, 128, n), jnp.float32) / 2
               if quantize else jnp.asarray(rng.randn(n), jnp.float32))
    anchor = jnp.asarray(rng.randn(n), jnp.float32)
    scale = (jnp.asarray(np.abs(rng.randn(n)) + 0.1, jnp.float32)
             if quantize else None)
    mu = jnp.asarray(rng.randn(n), jnp.float32) if momentum else None
    got = sync_apply_update(step_in, anchor, scale=scale, mu=mu,
                            momentum=momentum, interpret=True)
    want = jax.jit(partial(ref.sync_apply_update, momentum=momentum))(
        step_in, anchor, scale=scale, mu=mu)
    for g, w_ in zip(got, want):
        if w_ is None:
            assert g is None
            continue
        np.testing.assert_allclose(np.asarray(g), np.asarray(w_),
                                   rtol=2e-5, atol=2e-5)


# ------------------------------------------------ flat == tree (bitwise) --

def _bitwise_case(schedule, optimizer, quantize, momentum, steps=8):
    cfg = R.get_smoke_config("starcoder2-3b")
    run = RunConfig(schedule=schedule, optimizer=optimizer,
                    total_steps=steps, peak_lr=3e-3, end_lr=1e-6,
                    warmup_steps=2, h_base=2, alpha=0.001, remat=False,
                    weight_decay=0.01, sync_quantize=quantize,
                    outer_momentum=momentum)
    lr_fn = make_lr_fn(run)
    trace = list(schedules.rounds(run, lr_fn))
    et = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16, data="host",
                       layout="tree")
    ef = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16, data="host",
                       layout="flat")
    st_, sf = et.init_state(), ef.init_state()
    for t, h in trace:
        st_, mt = et.run_round(st_, t, h, lr_fn)
        sf, mf = ef.run_round(sf, t, h, lr_fn)
        np.testing.assert_allclose(float(mt["loss"]), float(mf["loss"]),
                                   rtol=1e-6)
    return et, st_, ef, sf


@pytest.mark.parametrize("schedule,optimizer,quantize,momentum", [
    ("qsr", "adamw", False, 0.0),        # paper Alg. 2, plain mean sync
    ("qsr", "adamw", True, 0.9),         # both beyond-paper options on
    ("parallel", "sgd", False, 0.0),     # paper Alg. 1 (H=1 every round)
    ("qsr", "sgd", True, 0.0),           # int8 sync alone
])
def test_flat_run_bitwise_matches_tree(schedule, optimizer, quantize,
                                       momentum):
    """The acceptance identity: a full bucketed run under layout="flat" ends
    in *bitwise* the same params and optimizer state as layout="tree"."""
    et, st_, ef, sf = _bitwise_case(schedule, optimizer, quantize, momentum)
    sf_tree = F.to_tree_state(ef.spec, sf)
    la, ta = jax.tree.flatten(st_)
    lb, tb = jax.tree.flatten(sf_tree)
    assert ta == tb
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # same trajectory, fewer state leaves: buckets instead of tensors
    assert len(jax.tree.leaves(sf["params"])) == len(ef.spec.buckets)
    # params_single agrees across layouts
    pa, pb = et.params_single(st_), ef.params_single(sf)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cross_layout_checkpoint_restore():
    """A flat run can resume a tree checkpoint (and vice versa) exactly —
    flatten/unflatten are layout ops, not numerics."""
    et, st_, ef, sf = _bitwise_case("qsr", "adamw", False, 0.0, steps=4)
    with tempfile.TemporaryDirectory() as d:
        et.save(d, st_, step=4)                    # tree checkpoint...
        restored, step = ef.restore(d, ef.init_state())   # ...flat engine
        assert step == 4 and ef.h_trace == et.h_trace
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(sf)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with tempfile.TemporaryDirectory() as d:
        ef.save(d, sf, step=4)                     # flat checkpoint...
        restored, step = et.restore(d, et.init_state())   # ...tree engine
        assert step == 4
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(st_)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- lowering proof (HLO) ---

def test_flat_sync_lowers_to_one_all_reduce_per_bucket():
    """Acceptance: under a sharded debug mesh the flat sync compiles to
    <= #dtype-buckets all-reduces; the tree sync pays one per leaf.
    Subprocess: the host device count must be pinned before jax init."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.sync_compare",
         "--arch", "starcoder2-3b", "--mesh", "4x2"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    import json
    rec = json.loads(out.stdout)
    tree, flat = rec["tree"], rec["flat"]
    assert flat["all_reduce_ops"] <= flat["n_buckets"]
    assert tree["all_reduce_ops"] >= tree["n_leaves"]
    assert flat["n_buckets"] < tree["n_leaves"]
    # every collective the flat sync issues is one of the bucket means
    assert sum(flat["collective_counts"].values()) == flat["all_reduce_ops"]
