"""AsyncObserver (core/observer.py) + the async eval/checkpoint pipeline.

The contract under test:
  * submit() is non-blocking for the round loop: a slow handler never
    stalls the submitting thread, and the double buffer drops superseded
    snapshots latest-wins (with the merge hook folding must-keep flags);
  * handler errors are never swallowed — they re-raise at drain()/close();
  * the end-to-end pipeline: an overlap-mode engine observed through
    synced_view + AsyncObserver writes checkpoints that are bitwise the
    blocking trajectory's round-boundary states (a mid-overlap
    pre-consensus state is impossible to observe), while the training
    stream itself is never flushed;
  * train()'s --async-observer path produces the same history and a
    restorable checkpoint.
"""
import tempfile
import threading
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import engine as E
from repro.core import schedules
from repro.core.observer import AsyncObserver
from repro.optim.lr import make_lr_fn


# ------------------------------------------------------------- unit -------

def test_observer_processes_in_order_and_drains():
    got = []
    obs = AsyncObserver(lambda step, snap: got.append((step, snap)),
                        stage=lambda x: x)
    for i in range(3):
        obs.submit(i, {"v": i})
        obs.drain()
    obs.close()
    assert got == [(0, {"v": 0}), (1, {"v": 1}), (2, {"v": 2})]
    assert obs.stats() == {"submitted": 3, "processed": 3, "dropped": 0}


def test_observer_submit_never_blocks_and_drops_latest_wins():
    """A handler much slower than the submit cadence: every submit returns
    immediately, the queue slot holds only the newest snapshot, and the
    last submitted snapshot is always processed."""
    started = threading.Event()
    release = threading.Event()
    got = []

    def slow(step, snap):
        started.set()
        release.wait(10.0)
        got.append(step)

    obs = AsyncObserver(slow, stage=lambda x: x)
    obs.submit(0, 0)
    assert started.wait(5.0), "worker never started"
    t0 = time.perf_counter()
    for i in range(1, 8):
        obs.submit(i, i)
    submit_time = time.perf_counter() - t0
    assert submit_time < 1.0, "submit() must not wait for the handler"
    release.set()
    obs.drain()
    obs.close()
    # snapshot 0 is in flight; of 1..7 only the latest queued survives the
    # double buffer
    assert got == [0, 7]
    assert obs.dropped == 6
    assert obs.processed == 2


def test_observer_merge_hook_folds_superseded_flags():
    """The train() checkpoint contract: a superseded snapshot's save flag
    rides the newer snapshot instead of being dropped."""
    started = threading.Event()
    release = threading.Event()
    got = []

    def slow(step, snap):
        started.set()
        release.wait(10.0)
        got.append((step, snap["save"]))

    obs = AsyncObserver(
        slow, stage=lambda x: x,
        merge=lambda old, new: ({**new, "save": True} if old["save"]
                                else new))
    obs.submit(0, {"save": False})          # in flight
    assert started.wait(5.0), "worker never started"
    obs.submit(1, {"save": True})           # queued...
    obs.submit(2, {"save": False})          # ...superseded: save must ride
    release.set()
    obs.drain()
    obs.close()
    assert got == [(0, False), (2, True)]


def test_observer_handler_errors_surface_at_drain():
    def boom(step, snap):
        raise RuntimeError("observer exploded")

    obs = AsyncObserver(boom, stage=lambda x: x)
    obs.submit(0, None)
    with pytest.raises(RuntimeError, match="observer exploded"):
        obs.drain()


def test_observer_default_stage_is_device_get():
    got = []
    obs = AsyncObserver(lambda step, snap: got.append(snap))
    obs.submit(0, {"x": jax.numpy.arange(4.0)})
    obs.drain()
    obs.close()
    assert isinstance(got[0]["x"], np.ndarray)
    np.testing.assert_array_equal(got[0]["x"], np.arange(4.0, dtype=np.float32))


# ------------------------------------------- end-to-end pipeline ----------

def _engines(steps=8):
    cfg = R.get_smoke_config("starcoder2-3b")
    run = RunConfig(schedule="qsr", optimizer="adamw", total_steps=steps,
                    peak_lr=3e-3, end_lr=1e-6, warmup_steps=2, h_base=2,
                    alpha=0.001, remat=False, weight_decay=0.01,
                    sync_quantize=True)
    lr_fn = make_lr_fn(run)
    trace = list(schedules.rounds(run, lr_fn))
    mk = lambda **k: E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16,
                                   data="host", layout="flat_sharded",
                                   shards=13, **k)
    return mk, trace, lr_fn


def test_async_checkpoints_only_ever_hold_blocking_consensus():
    """The impossible-to-observe claim, end to end: every checkpoint an
    AsyncObserver writes from synced_view snapshots of an overlap run is
    bitwise a blocking-run round boundary — while the overlap pipeline is
    never flushed mid-run."""
    mk, trace, lr_fn = _engines()
    eb = mk()
    eo = mk(sync="overlap")
    sb, so = eb.init_state(), eo.init_state()
    blocking_at = {}
    with tempfile.TemporaryDirectory() as root:
        dirs = {}

        def handle(step, snap):
            d = f"{root}/{step}"
            dirs[step] = d
            ckpt_io.save(d, snap["state"], step=step, extra=snap["extra"])

        obs = AsyncObserver(handle)
        for t, h in trace:
            sb, _ = eb.run_round(sb, t, h, lr_fn)
            so, _ = eo.run_round(so, t, h, lr_fn)
            blocking_at[t + h] = jax.tree.map(np.asarray, sb)
            obs.submit(t + h, {"state": eo.synced_view(so),
                               "extra": eo.checkpoint_extra()})
            obs.drain()     # keep every snapshot (no drops) for the matrix
            assert eo._pending is not None, "pipeline must stay in flight"
        obs.close()
        for step, d in dirs.items():
            er = mk()
            restored, got_step = er.restore(d, er.init_state())
            assert got_step == step
            for a, b in zip(jax.tree.leaves(restored),
                            jax.tree.leaves(blocking_at[step])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_async_observer_matches_inline_history():
    """launch/train.py --async-observer: identical loss history to the
    inline driver, eval snapshots observed at every round boundary, and the
    written checkpoint restores at the final step."""
    from repro.launch.train import train

    cfg = R.get_smoke_config("starcoder2-3b")
    run = RunConfig(schedule="constant", optimizer="adamw", total_steps=8,
                    h_base=2, peak_lr=3e-3, warmup_steps=1, remat=False)
    kw = dict(workers=2, b_loc=2, seq=16, layout="flat_sharded",
              sync="overlap", log_every=0)
    seen = []
    with tempfile.TemporaryDirectory() as d:
        _, hist_async = train(cfg, run, ckpt_dir=d,
                              eval_fn=lambda t, s: seen.append(t),
                              async_observer=True, **kw)
        eng = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16,
                            layout="flat_sharded", sync="overlap")
        restored, step = eng.restore(d, eng.init_state())
        assert step == run.total_steps
    _, hist_inline = train(cfg, run, **kw)
    assert [r[:3] for r in hist_async] == [r[:3] for r in hist_inline]
    # the observer sees round boundaries in order; intermediate snapshots
    # may be superseded (latest-wins), the final one never is
    boundaries = [t for t, _, _, _ in hist_async]
    assert seen == sorted(set(seen))
    assert set(seen) <= set(boundaries)
    assert seen[-1] == boundaries[-1]
