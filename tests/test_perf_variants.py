"""Correctness of the beyond-paper perf variants (§Perf): every optimization
must be semantics-preserving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.configs.base import ModelConfig, RunConfig
from repro.core import local_update as LU
from repro.models import api, moe, param as pm


def test_sharded_moe_dispatch_equals_global():
    """Shard-local dispatch (expert-parallel all-to-all form) == global
    argsort dispatch when capacity doesn't bind."""
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                      n_experts=4, top_k=2, capacity_factor=8.0,
                      n_shared_experts=1)
    params = pm.init_params(moe.moe_defs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    o1, a1 = moe.moe_apply(cfg, params, x)
    try:
        moe.set_dispatch_shards(4)
        o2, a2 = moe.moe_apply(cfg, params, x)
    finally:
        moe.set_dispatch_shards(1)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def _loss_with(run_cfg, arch="starcoder2-3b"):
    cfg = R.get_smoke_config(arch)
    loss_fn = LU.make_loss(cfg, run_cfg)
    params = pm.init_params(api.get_module(cfg).param_defs(cfg),
                            jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    return float(jax.jit(loss_fn)(params, {"tokens": toks, "labels": toks}))


def test_remat_policies_equal_loss():
    base = RunConfig(remat=True)
    sc = RunConfig(remat=True, remat_policy="save_collectives")
    off = RunConfig(remat=False)
    l0, l1, l2 = (_loss_with(r) for r in (base, sc, off))
    assert abs(l0 - l1) < 1e-5 and abs(l0 - l2) < 1e-5


def test_seq_shard_constraint_is_noop_on_cpu():
    base = RunConfig(remat=False)
    seq = RunConfig(remat=False, seq_shard_activations=True)
    assert abs(_loss_with(base) - _loss_with(seq)) < 1e-6


def test_moe_dispatch_shards_via_runtime():
    run1 = RunConfig(remat=False, moe_dispatch_shards=1)
    run2 = RunConfig(remat=False, moe_dispatch_shards=2)
    l1 = _loss_with(run1, "dbrx-132b")
    l2 = _loss_with(run2, "dbrx-132b")
    moe.set_dispatch_shards(1)
    assert abs(l1 - l2) < 1e-5
