"""Per-architecture smoke tests: instantiate the REDUCED variant of each
assigned architecture's family, run one forward + one train step on CPU,
assert output shapes and no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import local_update as LU
from repro.models import api, param as pm

ARCHS = list(R.ARCHS)


def _batch(cfg, rng, b=2, s=32, lead=()):
    if cfg.family == "vision":
        return {"images": jax.random.normal(rng, lead + (b, 32, 32, 3)),
                "labels": jnp.zeros(lead + (b,), jnp.int32)}
    out = {"tokens": jax.random.randint(rng, lead + (b, s), 0, cfg.vocab),
           "labels": jax.random.randint(rng, lead + (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        out["prefix_embeds"] = 0.02 * jax.random.normal(
            rng, lead + (b, cfg.n_img_tokens, cfg.d_model))
    if cfg.family == "audio":
        out["frames"] = 0.1 * jax.random.normal(
            rng, lead + (b, cfg.enc_seq, cfg.d_model))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = R.get_smoke_config(arch)
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    b, s = 2, 32
    batch = _batch(cfg, rng, b, s)
    if cfg.family == "vision":
        logits = mod.forward(cfg, params, batch["images"], remat=False)
        assert logits.shape == (b, cfg.n_classes)
    elif cfg.family == "audio":
        logits, _ = mod.forward(cfg, params, batch["tokens"],
                                frames=batch["frames"], remat=False)
        assert logits.shape == (b, s, cfg.vocab)
    elif cfg.family == "vlm":
        logits, _ = mod.forward(cfg, params, batch["tokens"],
                                prefix_embeds=batch["prefix_embeds"],
                                remat=False)
        assert logits.shape == (b, s, cfg.vocab)
    else:
        logits, _ = mod.forward(cfg, params, batch["tokens"], remat=False)
        assert logits.shape == (b, s, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_one_local_train_step(arch):
    cfg = R.get_smoke_config(arch)
    run = RunConfig(optimizer="adamw", remat=False, total_steps=4,
                    peak_lr=1e-3, weight_decay=0.01)
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0))
    w = 2
    state = LU.init_state(cfg, run, params, w)
    step = jax.jit(LU.make_local_step(cfg, run))
    batch = _batch(cfg, jax.random.PRNGKey(2), b=2, s=16, lead=(w,))
    new_state, loss = step(state, batch, 1e-3)
    assert np.isfinite(float(loss))
    # params actually changed, and no NaNs appeared anywhere
    changed = 0
    for old, new in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])):
        assert np.isfinite(np.asarray(new)).all()
        changed += int(not np.allclose(old, new))
    assert changed > 0
