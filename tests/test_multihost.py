"""Real multi-process execution of the sharded sync (launch/multihost.py).

The contract under test:
  * `spawn_workers` launches N real `jax.distributed` CPU processes (gloo
    collectives), each owning 1/N of the global mesh's devices; the
    flat_sharded sync's explicit reduce_scatter / all_gather legs then cross
    true process boundaries;
  * the quantized sharded sync is BITWISE identical however the mesh is
    executed — every process's addressable shards equal the process-local
    host-path reference, and the per-shard hashes of an N-process run equal
    those of the single-process 8-simulated-device run of the same program
    (the RS-domain integer-code rule, core/sync.py: Σq is exact in any
    collective order).  Unquantized f32 means are asserted bitwise only on
    2-worker meshes (one addition has one order);
  * the overlap seam (`--sync overlap`'s begin/apply split) carries its
    pending int16 code-sums across a program boundary between processes;
  * full RoundEngine rounds (local transformer steps + sharded sync) run
    across processes, with every process observing the identical SPMD loss;
  * `assert_production_topology` raises a real error (not a bare `assert`
    stripped under `python -O`).

All spawn tests carry the `multiproc` marker and skip gracefully when the
distributed CPU backend is unavailable (probed once per session).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.launch import multihost

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_avail: dict = {}


def _multiproc_ok():
    """Probe the distributed CPU backend once: 2 processes, one psum."""
    if "ok" not in _avail:
        try:
            res = multihost.spawn_workers(
                2, total_devices=2, extra=("--mode", "probe"), timeout=300)
            _avail["ok"] = all(rc == 0 for rc, _, _ in res) and all(
                json.loads(so.strip().splitlines()[-1])["ok"]
                for _, so, _ in res)
            _avail["why"] = "" if _avail["ok"] else \
                "probe failed: " + (res[0][2] or res[0][1])[-500:]
        except Exception as e:  # no sockets, no gloo, ancient jax...
            _avail["ok"], _avail["why"] = False, repr(e)
    return _avail["ok"]


def _require_multiproc():
    if not _multiproc_ok():
        pytest.skip(f"multi-process jax backend unavailable: {_avail['why']}")


def _spawn(nproc, *extra, total_devices=8, timeout=900):
    res = multihost.spawn_workers(nproc, total_devices=total_devices,
                                  extra=tuple(extra), timeout=timeout)
    outs = []
    for rc, so, se in res:
        assert rc == 0, f"worker failed:\n{so[-1500:]}\n{se[-3000:]}"
        outs.append(json.loads(so.strip().splitlines()[-1]))
    return outs


def _run_single(*extra, total_devices=8, timeout=900):
    """The same module, single process, `total_devices` simulated devices —
    the comparison run the multi-process digests must reproduce."""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep +
               os.environ.get("PYTHONPATH", ""))
    env.pop("REPRO_COORDINATOR", None)
    env.pop("XLA_FLAGS", None)  # main() pins the device count itself
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.multihost",
         "--total-devices", str(total_devices), *extra],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ------------------------------------------------------------- unit -------

def test_assert_production_topology_raises_real_error(monkeypatch):
    """Bare `assert` is stripped under `python -O`; the topology check must
    survive optimized mode, so it raises TopologyError (a RuntimeError)."""
    import jax
    monkeypatch.setattr(jax, "devices", lambda: list(range(7)))
    with pytest.raises(multihost.TopologyError, match="expected 256"):
        multihost.assert_production_topology(multi_pod=False)
    with pytest.raises(RuntimeError, match="expected 512"):
        multihost.assert_production_topology(multi_pod=True)
    monkeypatch.setattr(jax, "devices", lambda: list(range(256)))
    multihost.assert_production_topology(multi_pod=False)  # no raise


def test_topology_check_survives_python_O():
    """Run the check under `python -O` in a subprocess: still raises."""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep +
               os.environ.get("PYTHONPATH", ""))
    code = ("from repro.launch import multihost\n"
            "try:\n"
            "    multihost.assert_production_topology(multi_pod=False)\n"
            "except multihost.TopologyError:\n"
            "    print('RAISED')\n")
    out = subprocess.run([sys.executable, "-O", "-c", code],
                         capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RAISED" in out.stdout


# ------------------------------------------------------- multi-process ----

@pytest.mark.multiproc
@pytest.mark.parametrize("nproc,mesh,policy,flags", [
    (2, "2x2x2", "fsdp", ("--quantize",)),          # int16 wire, W=2
    (2, "2x2x2", "fsdp", ()),                       # plain f32: W=2 is the
                                                    # order-free mean
    (2, "4x2", "dp", ("--quantize", "--momentum", "0.9")),
    (4, "2x2x2", "fsdp", ("--quantize",)),          # 4 real processes
])
def test_multiproc_sync_bitwise_vs_single_process(nproc, mesh, policy,
                                                  flags):
    """The acceptance harness: N real processes run the sharded sync
    end-to-end; every worker's shards match its host-path reference
    bitwise, and the run is bitwise the single-process 8-simulated-device
    run (digests + per-shard hashes)."""
    _require_multiproc()
    args = ("--mode", "sync", "--mesh", mesh, "--policy", policy, *flags)
    single = _run_single(*args)
    assert single["ok"] and single["max_abs_diff"] == 0.0
    outs = _spawn(nproc, *args)
    merged = {}
    for d in outs:
        assert d["ok"], d
        assert d["max_abs_diff"] == 0.0
        assert d["process_count"] == nproc
        assert d["digest"] == single["digest"]
        merged.update(d["shard_hashes"])
    # the union of the workers' shard hashes is exactly the single-process
    # run's — same global arrays, bit for bit, shard for shard
    assert merged == single["shard_hashes"]


@pytest.mark.multiproc
def test_multiproc_overlap_split_carries_pending_across_processes():
    """The --sync overlap seam under real processes: the reduce's pending
    int16 code-sums are produced in one program, held on (distributed)
    devices across the round boundary, and gathered+applied in the next —
    still bitwise the host reference and the single-process run."""
    _require_multiproc()
    args = ("--mode", "sync", "--mesh", "2x2x2", "--policy", "fsdp",
            "--quantize", "--overlap")
    single = _run_single(*args)
    outs = _spawn(2, *args)
    for d in outs:
        assert d["ok"] and d["max_abs_diff"] == 0.0
        assert d["overlap"] and d["wire_dtype"] == "int16"
        assert d["digest"] == single["digest"]


@pytest.mark.multiproc
@pytest.mark.parametrize("nproc,mesh,policy,flags", [
    (2, "2x2x2", "fsdp", ("--quantize",)),   # quantized, pod-worker mesh
    (2, "2x4", "dp", ()),                    # plain f32, W=2 dp mesh
    (4, "2x2x2", "fsdp", ("--quantize",)),   # 4 real processes
    (4, "4x2", "dp", ("--quantize",)),       # 4 procs, dp W=4
])
def test_multiproc_engine_overlap_bitwise_matches_blocking(nproc, mesh,
                                                           policy, flags):
    """Full OVERLAPPED RoundEngine rounds under a real mesh across real
    processes: the pending reduce is threaded through run_round across
    program boundaries, its worker-sharded payload living on distributed
    devices between rounds.  At depth 0 the flushed overlap state must be
    BITWISE the blocking engine's, shard for shard (the in-process
    reference each worker runs alongside), every process must observe the
    identical SPMD loss trajectory, and the single-process run of the same
    mesh must agree on the losses."""
    _require_multiproc()
    args = ("--mode", "engine", "--sync", "overlap", "--mesh", mesh,
            "--policy", policy, "--rounds", "2", *flags)
    outs = _spawn(nproc, *args, timeout=1200)
    for d in outs:
        assert d["ok"], d
        assert d["sync"] == "overlap" and d["overlap_depth"] == 0
        assert d["overlap_matches_blocking"], d["max_abs_diff_vs_blocking"]
        assert d["losses"] == d["blocking_losses"]
        assert all(np.isfinite(d["losses"]))
        assert d["process_count"] == nproc
    losses = [d["losses"] for d in outs]
    assert all(l == losses[0] for l in losses), \
        "processes observed different losses"
    # the single-process run of the same overlapped program agrees (the
    # sync is exact either way; fsdp local-step psums are allclose across
    # backends, hence not asserted bitwise — see test_multiproc_engine_rounds)
    single = _run_single(*args, timeout=1200)
    assert single["ok"] and single["overlap_matches_blocking"]
    np.testing.assert_allclose(losses[0], single["losses"], rtol=1e-4)


@pytest.mark.multiproc
def test_multiproc_engine_overlap_depth1_correction_form():
    """Depth > 0 under real processes: workers run a stale step before the
    deferred gather applies (correction form) — finite, close to blocking,
    and the blocking comparison is reported, not asserted bitwise."""
    _require_multiproc()
    args = ("--mode", "engine", "--sync", "overlap", "--overlap-depth", "1",
            "--mesh", "2x2x2", "--policy", "fsdp", "--quantize",
            "--rounds", "2")
    outs = _spawn(2, *args, timeout=1200)
    for d in outs:
        assert d["ok"], d
        assert all(np.isfinite(d["losses"]))
        assert d["max_abs_diff_vs_blocking"] < 5e-2


@pytest.mark.multiproc
def test_multiproc_engine_rounds():
    """Full RoundEngine communication rounds across 2 real processes: the
    same engine/mesh build as single-process (engine mesh= path), local
    steps + quantized sharded sync, every process observing the identical
    SPMD loss trajectory."""
    _require_multiproc()
    args = ("--mode", "engine", "--mesh", "2x2x2", "--policy", "fsdp",
            "--quantize", "--rounds", "2")
    outs = _spawn(2, *args, timeout=1200)
    assert all(d["ok"] for d in outs)
    losses = [d["losses"] for d in outs]
    assert losses[0] == losses[1], "processes observed different losses"
    assert all(np.isfinite(losses[0]))
    # and the single-process run of the same mesh tracks it closely (the
    # fsdp local steps psum f32 partial matmuls, so cross-backend — gloo vs
    # in-process — agreement is allclose, not bitwise; the sync itself is
    # exact either way)
    single = _run_single(*args, timeout=1200)
    np.testing.assert_allclose(losses[0], single["losses"], rtol=1e-4)
