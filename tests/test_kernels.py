"""Per-kernel oracle tests: sweep shapes/dtypes, run the Pallas kernel body
in interpret mode (CPU), assert_allclose against the ref.py pure-jnp oracle
(deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.adamw_update import adamw_update
from repro.kernels.flash_attention import flash_attention, flash_decode
from repro.kernels.rmsnorm import rms_norm
from repro.kernels.swiglu import swiglu

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------- rmsnorm --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 128), (2, 33, 256), (1, 7, 512),
                                   (128, 1024), (5, 384)])
def test_rmsnorm_matches_oracle(shape, dtype):
    rng = jax.random.PRNGKey(hash(shape) % 2**31)
    x = jax.random.normal(rng, shape, dtype)
    sc = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(1), shape[-1:])
    got = rms_norm(x, sc, interpret=True)
    want = ref.rms_norm(x, sc)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=TOL[dtype], atol=TOL[dtype])


# ------------------------------------------------------------------ adamw --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n", [64, 1000, 70_000])
@pytest.mark.parametrize("step", [1.0, 100.0])
def test_adamw_matches_oracle(n, dtype, step):
    k = jax.random.PRNGKey(n)
    p = jax.random.normal(k, (n,), dtype)
    m = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (n,))
    v = jnp.abs(0.1 * jax.random.normal(jax.random.PRNGKey(2), (n,)))
    g = jax.random.normal(jax.random.PRNGKey(3), (n,), dtype)
    kw = dict(lr=1e-3, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1,
              step=step)
    got = adamw_update(p, m, v, g, interpret=True, **kw)
    want = ref.adamw_update(p, m, v, g, **kw)
    for gx, wx in zip(got, want):
        np.testing.assert_allclose(np.asarray(gx, np.float32),
                                   np.asarray(wx, np.float32),
                                   rtol=TOL[dtype], atol=TOL[dtype])


# -------------------------------------------------------- flash attention --

CASES = [
    # (sq, sk, hq, hkv, d, causal, window, prefix)
    (128, 128, 4, 4, 64, True, 0, 0),
    (256, 256, 8, 2, 64, True, 0, 0),      # GQA 4:1
    (256, 256, 8, 1, 128, True, 0, 0),     # MQA
    (256, 256, 4, 4, 64, True, 100, 0),    # sliding window
    (192, 192, 4, 2, 64, True, 64, 48),    # window + prefix-LM
    (64, 320, 4, 4, 64, True, 0, 0),       # kv longer than q (decode-ish)
    (1, 257, 8, 2, 64, True, 0, 0),        # single-token decode, ragged kv
    (100, 200, 4, 4, 32, False, 0, 0),     # non-causal (encoder)
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", CASES)
def test_flash_attention_matches_oracle(case, dtype):
    sq, sk, hq, hkv, d, causal, window, prefix = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case)), 3)
    q = jax.random.normal(ks[0], (2, sq, hq, d), dtype)
    k = jax.random.normal(ks[1], (2, sk, hkv, d), dtype)
    v = jax.random.normal(ks[2], (2, sk, hkv, d), dtype)
    qoff = sk - sq if sq < sk else 0
    got = flash_attention(q, k, v, causal=causal, window=window,
                          prefix_len=prefix, q_offset=qoff, interpret=True)
    want = ref.attention(q, k, v, causal=causal, window=window,
                         prefix_len=prefix, q_offset=qoff)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5 * TOL[dtype], atol=5 * TOL[dtype])


@given(sq=st.integers(1, 96), extra_k=st.integers(0, 64),
       hkv=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2, 4]),
       window=st.integers(0, 64))
@settings(max_examples=15, deadline=None)
def test_flash_attention_property_sweep(sq, extra_k, hkv, g, window):
    sk = sq + extra_k
    d = 32
    hq = hkv * g
    ks = jax.random.split(jax.random.PRNGKey(sq * 131 + extra_k), 3)
    q = jax.random.normal(ks[0], (1, sq, hq, d))
    k = jax.random.normal(ks[1], (1, sk, hkv, d))
    v = jax.random.normal(ks[2], (1, sk, hkv, d))
    got = flash_attention(q, k, v, causal=True, window=window,
                          q_offset=extra_k, interpret=True,
                          block_q=32, block_k=32)
    want = ref.attention(q, k, v, causal=True, window=window, q_offset=extra_k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ring_buffer_mask_equals_dense_window():
    """The ring-buffer decode path (k_positions) must equal attention over
    the dense window — validates the long_500k serving path."""
    d, h = 32, 2
    ln, pos = 8, 13  # ring shorter than the stream
    key = jax.random.PRNGKey(0)
    # build a ring cache: positions pos-7..pos stored at idx (p % ln)
    ks = jax.random.normal(key, (1, pos + 1, h, d))
    vs = jax.random.normal(jax.random.PRNGKey(1), (1, pos + 1, h, d))
    ring_k = jnp.zeros((1, ln, h, d))
    ring_v = jnp.zeros((1, ln, h, d))
    for p in range(pos + 1):
        ring_k = ring_k.at[:, p % ln].set(ks[:, p])
        ring_v = ring_v.at[:, p % ln].set(vs[:, p])
    write = pos % ln
    base = pos - write
    idx = jnp.arange(ln)
    k_positions = jnp.where(idx <= write, base + idx, base - ln + idx)
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 1, h, d))
    got = ref.attention(q, ring_k, ring_v, causal=True, q_offset=pos,
                        k_positions=k_positions)
    want = ref.attention(q, ks[:, pos + 1 - ln:], vs[:, pos + 1 - ln:],
                         causal=True, q_offset=ln - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------- single-query decode ----

DECODE_CASES = [
    # (sk, hq, hkv, d, window, prefix)
    (64, 4, 4, 64, 0, 0),          # dense causal
    (257, 8, 2, 64, 0, 0),         # GQA 4:1, ragged kv length
    (128, 8, 1, 128, 0, 0),        # MQA
    (200, 4, 4, 64, 48, 0),        # sliding window
    (160, 4, 2, 64, 64, 16),       # window + prefix-LM (VLM serving)
    (96, 4, 4, 32, 0, 24),         # prefix only
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", DECODE_CASES)
def test_flash_decode_matches_oracle(case, dtype):
    """The serving kernel vs the jnp reference, with window and q_offset
    passed TRACED (the model scan feeds per-layer windows as scan xs)."""
    sk, hq, hkv, d, window, prefix = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case)), 3)
    q = jax.random.normal(ks[0], (2, 1, hq, d), dtype)
    k = jax.random.normal(ks[1], (2, sk, hkv, d), dtype)
    v = jax.random.normal(ks[2], (2, sk, hkv, d), dtype)
    qoff = jnp.asarray(sk - 1, jnp.int32)          # decoding the last position
    win = jnp.asarray(window, jnp.int32)           # traced, not specialized
    got = flash_decode(q, k, v, causal=True, window=win, prefix_len=prefix,
                       q_offset=qoff, interpret=True)
    want = ref.attention(q, k, v, causal=True, window=win, prefix_len=prefix,
                         q_offset=qoff)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5 * TOL[dtype], atol=5 * TOL[dtype])


def test_flash_decode_ragged_offsets():
    """Per-slot [B] q_offsets (continuous batching): each row attends only
    up to its own position, whatever garbage sits beyond it in the cache."""
    b, sk, hq, hkv, d = 4, 96, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d))
    k = jax.random.normal(ks[1], (b, sk, hkv, d))
    v = jax.random.normal(ks[2], (b, sk, hkv, d))
    qoff = jnp.asarray([3, 95, 40, 0], jnp.int32)
    got = flash_decode(q, k, v, causal=True, q_offset=qoff, interpret=True,
                       block_k=32)
    want = ref.attention(q, k, v, causal=True, q_offset=qoff)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_ring_positions():
    """The ring-buffer cache: k_positions carry absolute stream positions
    (-1 = empty); the kernel must equal the dense-window reference."""
    d, h = 32, 2
    ln, pos = 8, 13
    ks = jax.random.normal(jax.random.PRNGKey(0), (1, pos + 1, h, d))
    vs = jax.random.normal(jax.random.PRNGKey(1), (1, pos + 1, h, d))
    ring_k = jnp.zeros((1, ln, h, d))
    ring_v = jnp.zeros((1, ln, h, d))
    for p in range(pos + 1):
        ring_k = ring_k.at[:, p % ln].set(ks[:, p])
        ring_v = ring_v.at[:, p % ln].set(vs[:, p])
    write = pos % ln
    base = pos - write
    idx = jnp.arange(ln)
    k_positions = jnp.where(idx <= write, base + idx, base - ln + idx)
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 1, h, d))
    got = flash_decode(q, ring_k, ring_v, causal=True, q_offset=pos,
                       k_positions=k_positions, interpret=True, block_k=4)
    want = ref.attention(q, ks[:, pos + 1 - ln:], vs[:, pos + 1 - ln:],
                         causal=True, q_offset=ln - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(sk=st.integers(1, 160), hkv=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 4]), window=st.integers(0, 64),
       qpos_frac=st.floats(0.0, 1.0))
@settings(max_examples=15, deadline=None)
def test_flash_decode_property_sweep(sk, hkv, g, window, qpos_frac):
    d = 32
    hq = hkv * g
    qpos = min(sk - 1, int(qpos_frac * sk))
    ks = jax.random.split(jax.random.PRNGKey(sk * 131 + window), 3)
    q = jax.random.normal(ks[0], (1, 1, hq, d))
    k = jax.random.normal(ks[1], (1, sk, hkv, d))
    v = jax.random.normal(ks[2], (1, sk, hkv, d))
    got = flash_decode(q, k, v, causal=True, window=jnp.asarray(window),
                       q_offset=qpos, interpret=True, block_k=32)
    want = ref.attention(q, k, v, causal=True, window=window, q_offset=qpos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ops_dispatch_routes_decode_to_pallas(monkeypatch):
    """On the interpret/pallas backends every sq==1 causal call — including
    the ragged and ring shapes that previously fell back to jnp — must hit
    flash_decode and still match the oracle."""
    from repro.kernels import ops as kops
    monkeypatch.setattr(kops, "_BACKEND", "interpret")
    calls = []
    from repro.kernels import flash_attention as fa
    orig = fa.flash_decode
    monkeypatch.setattr(fa, "flash_decode",
                        lambda *a, **kw: calls.append(1) or orig(*a, **kw))
    b, sk, h, d = 2, 40, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    k = jax.random.normal(ks[1], (b, sk, h, d))
    v = jax.random.normal(ks[2], (b, sk, h, d))
    qoff = jnp.asarray([5, 17], jnp.int32)
    got = kops.flash_attention(q, k, v, causal=True, q_offset=qoff)
    want = ref.attention(q, k, v, causal=True, q_offset=qoff)
    assert calls, "ragged decode did not route to flash_decode"
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------- swiglu --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 32, 128, 256), (2, 100, 64, 384),
                                   (1, 7, 256, 512)])
def test_swiglu_matches_oracle(shape, dtype):
    b, n, d, f = shape
    ks = jax.random.split(jax.random.PRNGKey(sum(shape)), 3)
    x = jax.random.normal(ks[0], (b, n, d), dtype)
    wg = (jax.random.normal(ks[1], (d, f)) / jnp.sqrt(d)).astype(dtype)
    wi = (jax.random.normal(ks[2], (d, f)) / jnp.sqrt(d)).astype(dtype)
    got = swiglu(x, wg, wi, interpret=True, block_r=64, block_f=128)
    want = ref.swiglu(x, wg, wi)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5 * TOL[dtype], atol=5 * TOL[dtype])
