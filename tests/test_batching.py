"""Continuous-batching serving scheduler: ragged per-slot positions must
reproduce per-sequence greedy decoding exactly, slots must be recycled."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.launch.batching import ContinuousBatcher, Request
from repro.launch.serve import generate
from repro.models import api, param as pm


def test_continuous_batching_matches_sequential_greedy():
    cfg = R.get_smoke_config("gemma3-4b")
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0))
    rng = jax.random.PRNGKey(1)
    prompts = [np.asarray(jax.random.randint(k, (pl,), 0, cfg.vocab))
               for k, pl in zip(jax.random.split(rng, 3), (5, 9, 7))]

    # reference: one-at-a-time greedy generation
    want = []
    for pr in prompts:
        toks = generate(cfg, params, jnp.asarray(pr)[None], gen_len=4,
                        max_len=32)
        want.append(np.asarray(toks[0, len(pr):]).tolist())

    # continuous batching with 2 slots over 3 requests (forces recycling)
    batcher = ContinuousBatcher(cfg, params, slots=2, max_len=32)
    reqs = [Request(rid=i, prompt=pr, max_new=4)
            for i, pr in enumerate(prompts)]
    for r in reqs:
        batcher.submit(r)
    batcher.run()
    assert all(r.done for r in reqs)
    for r, w in zip(reqs, want):
        assert r.out == w, (r.rid, r.out, w)


def test_batcher_keeps_slots_full():
    cfg = R.get_smoke_config("mamba2-130m")
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0))
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(i), (4,), 0,
                                             cfg.vocab)) for i in range(4)]
    batcher = ContinuousBatcher(cfg, params, slots=2, max_len=16)
    for i, pr in enumerate(prompts):
        batcher.submit(Request(rid=i, prompt=pr, max_new=3))
    counts = []
    while True:
        n = batcher.step()
        if n == 0 and not batcher.queue:
            break
        counts.append(n)
    assert max(counts) == 2  # both slots active at peak
