# NOTE: deliberately NO xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device (see system DESIGN.md §6).  Distributed
# tests spawn subprocesses that set the flag themselves.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Hypothesis suites run with the deadline disabled everywhere (CI machines
# jit-compile inside test bodies; wall-clock deadlines only add flakes).
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("repro", deadline=None)
    _hyp_settings.load_profile("repro")
except ImportError:
    pass
