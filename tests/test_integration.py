"""Integration tests: end-to-end training improves the loss, checkpoints
round-trip, the serve driver generates, and the distributed dry-run lowers
on a real (host-device) mesh via subprocess."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt_io
from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import local_update as LU
from repro.launch.train import train
from repro.models import api, param as pm

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_training_reduces_loss_qsr():
    cfg = R.get_smoke_config("starcoder2-3b")
    run = RunConfig(schedule="qsr", optimizer="adamw", total_steps=40,
                    peak_lr=3e-3, alpha=0.0008, h_base=2, warmup_steps=4,
                    remat=False, weight_decay=0.01)
    # data="host": the numpy stream the 0.3-drop threshold was tuned on —
    # bitwise the seed trajectory.  The on-device synthesis path is covered
    # by tests/test_engine.py.
    state, hist = train(cfg, run, workers=2, b_loc=4, seq=32, log_every=0,
                        data="host")
    losses = [l for _, _, l, _ in hist]
    assert losses[-1] < losses[0] - 0.3, losses
    assert sum(h for _, h, _, _ in hist) == 40


def test_checkpoint_roundtrip_and_resume():
    cfg = R.get_smoke_config("mamba2-130m")
    run = RunConfig(optimizer="adamw", remat=False, total_steps=8,
                    peak_lr=1e-3)
    params = pm.init_params(api.get_module(cfg).param_defs(cfg),
                            jax.random.PRNGKey(0))
    state = LU.init_state(cfg, run, params, 2)
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        ckpt_io.save(d, state, step=5)
        restored, step = ckpt_io.restore(d, state)
        assert step == 5
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_generate_all_decoder_families():
    from repro.launch.serve import generate
    for arch in ["gemma3-4b", "mamba2-130m", "zamba2-1.2b"]:
        cfg = R.get_smoke_config(arch)
        mod = api.get_module(cfg)
        params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                     cfg.vocab)
        toks = generate(cfg, params, prompts, gen_len=4)
        assert toks.shape == (2, 12)
        assert (np.asarray(toks) >= 0).all()
        assert (np.asarray(toks) < cfg.vocab).all()


def test_ring_window_generation_matches_full_cache_within_window():
    """Greedy generation with a ring cache >= context must equal full-cache
    generation (the window never truncates anything)."""
    from repro.launch.serve import generate
    cfg = R.get_smoke_config("qwen1.5-110b")
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    full = generate(cfg, params, prompts, gen_len=6, max_len=64)
    ring = generate(cfg, params, prompts, gen_len=6, max_len=64,
                    window_override=32)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(ring))


@pytest.mark.slow
def test_dryrun_smoke_mesh_subprocess():
    """Lower+compile train_round and decode on an 8-device host mesh (the
    multi-pod dry-run path, reduced): proves sharded lowering end-to-end."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import jax.numpy as jnp
from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import local_update as LU
from repro.models import api, param as pm
from repro.launch.shapes import _state_specs, _batch_specs, _ns
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = R.get_smoke_config("starcoder2-3b")
run = RunConfig(optimizer="adamw", remat=False)
mod = api.get_module(cfg)
params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0))
w = 4  # pod*data
state = LU.init_state(cfg, run, params, w)
sspec = _state_specs(cfg, run, "dp", mesh)
bspec = _batch_specs(cfg, 1, ("pod", "data"), None)
h, b, s = 2, 2, 32
toks = jax.random.randint(jax.random.PRNGKey(1), (h, w, b, s), 0, cfg.vocab)
batch = {"tokens": toks, "labels": toks}
lrs = jnp.full((h,), 1e-3, jnp.float32)
rf = LU.make_train_round(cfg, run)
with mesh:
    jf = jax.jit(rf, in_shardings=(_ns(mesh, sspec), _ns(mesh, bspec),
                                   NamedSharding(mesh, P())),
                 out_shardings=(_ns(mesh, sspec), NamedSharding(mesh, P())))
    compiled = jf.lower(state, batch, lrs).compile()
    out_state, loss = jf(state, batch, lrs)  # actually EXECUTE sharded
hlo = compiled.as_text()
assert "all-reduce" in hlo  # the sync collective exists
import numpy as np
ps = jax.device_get(out_state["params"])
for x in jax.tree.leaves(ps):
    assert np.isfinite(np.asarray(x)).all()
    for k in range(1, w):  # post-sync consensus across the worker axis
        np.testing.assert_allclose(np.asarray(x)[0], np.asarray(x)[k],
                                   rtol=2e-2, atol=2e-2)
print("OK", float(loss))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


@pytest.mark.slow
def test_dryrun_overlap_lowering_subprocess():
    """ROADMAP's overlap-aware dryrun item: the pending-threaded overlap
    round (`fn(state, pending, ...) -> (state, new_pending, metrics)`)
    lowers + compiles on the production 16x16 mesh through the dryrun
    driver, with the pending's shardings taken from sync.pending_specs —
    exactly the steady-state program the RoundEngine runs under
    `--sync overlap` on a mesh."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "starcoder2-3b", "--shape", "train_4k",
         "--engine", "bucketed", "--param-layout", "flat_sharded",
         "--sync", "overlap", "--overlap-depth", "1"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "train_round_overlap" in out.stdout
    assert "1 ok, 0 failed" in out.stdout


@pytest.mark.slow
def test_fsdp_moe_shard_map_subprocess():
    """fsdp policy + explicit shard_map MoE dispatch EXECUTES correctly on an
    8-device host mesh (the kimi-k2 §Perf configuration, reduced)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import local_update as LU
from repro.models import api, moe, param as pm
from repro.launch.shapes import _state_specs, _batch_specs, _ns

import dataclasses
mesh = jax.make_mesh((4, 2), ("data", "model"))
# aux load-balance loss uses per-shard statistics under expert parallelism
# (a different, equally valid estimator) -> disable it for exact comparison
cfg = dataclasses.replace(R.get_smoke_config("kimi-k2-1t-a32b"),
                          router_aux_coef=0.0)
run = RunConfig(sharding="fsdp", remat=False, moe_dispatch="shard_map",
                microbatch=2)
moe.set_dispatch("shard_map", mesh)
mod = api.get_module(cfg)
params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0))
w = 1
state = LU.init_state(cfg, run, params, w)
sspec = _state_specs(cfg, run, "fsdp", mesh)
b, s = 8, 32
toks = jax.random.randint(jax.random.PRNGKey(1), (w, b, s), 0, cfg.vocab)
batch = {"tokens": toks, "labels": toks}
bspec = _batch_specs(cfg, 0, None, "data")
step = LU.make_local_step(cfg, run)
with mesh:
    jf = jax.jit(step, in_shardings=(_ns(mesh, sspec), _ns(mesh, bspec), None),
                 out_shardings=(_ns(mesh, sspec), NamedSharding(mesh, P())))
    new_state, loss = jf(state, batch, 1e-3)
hlo = jf.lower(state, batch, 1e-3).compile().as_text()
assert "all-to-all" in hlo  # the explicit expert-parallel dispatch
assert np.isfinite(float(loss))
# compare against the unsharded global-dispatch reference
moe.set_dispatch("auto", None)
run0 = RunConfig(sharding="fsdp", remat=False)
step0 = jax.jit(LU.make_local_step(cfg, run0))
ref_state, ref_loss = step0(state, batch, 1e-3)
assert abs(float(loss) - float(ref_loss)) < 1e-4, (loss, ref_loss)
print("OK", float(loss))
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
