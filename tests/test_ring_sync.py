"""The `--wire ring-int8` sync (core/sync.py): W-hop re-quantizing ppermute
ring over the worker axes, int8 payload on every wire.

The contract under test:
  * lowering proof (subprocess, 8-device host mesh, dp AND fsdp policies):
    every payload-sized collective in the compiled ring sync carries s8 —
    zero int16/int32 payloads, zero payload all-reduces, zero
    reduce_scatters — with >= (W-1) collective-permutes per bucket, and the
    ring moves >= 2x fewer bytes than the exact int-codes wire;
  * executed on the mesh, the ring trajectory stays within the analytic
    `ring_tolerance` of the mesh-less host reference (tolerance, NOT
    bitwise: per-hop requantization is chunking-dependent — the deliberate
    exception to the repo's bitwise rule, README §Wire modes);
  * `ring_codes_host` / the per-hop kernels satisfy the schedule and error
    bounds for non-power-of-two worker counts: chunk c seeds at worker
    (c+1) mod W, folds every worker exactly once, and lands within
    `ring_tolerance` of the exact mean; zero deltas come through exact;
  * the RoundEngine overlap seam (sync="overlap", depth 0) stays within the
    per-round tolerance of the blocking ring trajectory (the auto wire's
    depth-0 seam is bitwise; the ring's is not, because XLA refusion may
    flip requant codes across the begin/apply split).

These are the deterministic (seeded) versions of the hypothesis properties
in tests/test_quantize_props.py — they run even where hypothesis is absent.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import schedules
from repro.core.sync import (check_wire, ring_codes_host, ring_tolerance,
                             wire_dtype)
from repro.kernels import ops as kops
from repro.optim.lr import make_lr_fn

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ------------------------------------------------- lowering proof (HLO) ---

def _sync_compare(*extra):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.sync_compare",
         "--arch", "starcoder2-3b", "--wire", "ring-int8", *extra],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout)


def _assert_all_s8(r, w):
    """The acceptance predicate — int8 payload on EVERY wire — asserted
    through the shared rule registry (repro.analysis.rules): s8-only
    payloads via wire-payload-dtype, zero RS / payload all-reduces and
    >= (W-1) permute hops per bucket via collective-budget."""
    assert r["workers"] == w
    for rule in ("collective-budget", "wire-payload-dtype"):
        verdict = r["rules"][rule]
        assert verdict["applies"], f"rule {rule} did not apply"
        assert verdict["ok"], (rule, verdict["violations"])


def test_ring_lowers_all_int8_on_dp_mesh_and_exec_within_tol():
    """dp 4x2 (W=4): s8-only payloads, W-1 permute hops per bucket, and the
    executed multi-round mesh trajectory within ring_tolerance of the host
    reference (never bitwise — chunking-dependent requantization).

    The wire claim is flat_sharded-only: the mesh-less flat layout runs the
    host ring, which GSPMD re-parallelizes with f32 collectives of its own
    choosing — numerically identical (the exec check below covers it) but
    not wire-optimal."""
    rec = _sync_compare("--mesh", "4x2", "--exec", "--exec-rounds", "2")
    _assert_all_s8(rec["flat_sharded"], w=4)
    ex = rec["exec"]
    assert ex["ring_tol"] > 0.0
    for layout in ("flat", "flat_sharded"):
        assert ex[layout]["within_tol"], (layout, ex)


def test_ring_lowers_all_int8_on_fsdp_pod_mesh():
    """fsdp 2x2x2 (pods as workers, W=2): the ring still puts nothing but
    s8 payloads on the wire when buckets chunk over (data, model)."""
    rec = _sync_compare("--mesh", "2x2x2", "--policy", "fsdp",
                        "--param-layout", "flat_sharded")
    _assert_all_s8(rec["flat_sharded"], w=2)


def test_ring_beats_int_codes_bytes_2x_on_dp_mesh():
    """>= 2x bytes-on-wire reduction vs the exact int-codes RS wire (the
    PR acceptance floor; the committed trajectory point in
    benchmarks/bench_sync_baseline.json records the same ratio)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.sync_compare",
         "--arch", "starcoder2-3b", "--mesh", "4x2", "--quantize",
         "--param-layout", "flat_sharded"],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    auto = json.loads(out.stdout)["flat_sharded"]
    ring = _sync_compare("--mesh", "4x2",
                         "--param-layout", "flat_sharded")["flat_sharded"]
    assert ring["bytes_on_wire"] * 2 <= auto["bytes_on_wire"], \
        (ring["bytes_on_wire"], auto["bytes_on_wire"])


# ------------------------------------------ host ring schedule + bounds ---

@pytest.mark.parametrize("w", [3, 5, 6, 7])
def test_ring_schedule_folds_every_worker_once_non_pow2(w):
    """Schedule correctness for non-power-of-two W: constant-per-worker
    deltas quantize exactly at every hop (each partial is constant, so its
    amax IS the value and the codes saturate at +-127), so the final mean
    detects any worker visited twice or skipped."""
    n = 4 * w + 3                      # non-divisible: exercises the pad
    vals = np.arange(1, w + 1, dtype=np.float32)      # worker j holds j+1
    d = jnp.asarray(np.repeat(vals[:, None], n, axis=1))
    q, s = ring_codes_host(d)
    assert q.dtype == jnp.int8 and s.shape == (w,)
    mean = (np.asarray(q, np.float32)
            * (np.asarray(s)[:, None] / 127.0)).reshape(-1)[:n]
    want = vals.mean()                 # every worker exactly once
    # partial means fold in f32: allow a few ulps, far below one int8 level
    np.testing.assert_allclose(mean, want, rtol=1e-5)


@pytest.mark.parametrize("w", [2, 3, 5, 7, 8])
def test_ring_codes_error_within_tolerance(w):
    """K-hop requantization error vs the exact worker mean stays within
    ring_tolerance(W, amax, 1) for random deltas at wild scales."""
    rng = np.random.RandomState(w)
    for log_scale in (-20, 0, 12):
        d = (rng.randn(w, 257) * 2.0 ** log_scale).astype(np.float32)
        q, s = ring_codes_host(jnp.asarray(d))
        got = (np.asarray(q, np.float32)
               * (np.asarray(s)[:, None] / 127.0)).reshape(-1)
        pad = (-257) % w
        exact = np.pad(d, ((0, 0), (0, pad))).mean(axis=0)
        exact = exact.reshape(w, -1).reshape(-1)
        err = np.max(np.abs(got - exact))
        tol = ring_tolerance(w, np.max(np.abs(d)), 1)
        assert err <= tol, (err, tol, log_scale)


def test_ring_zero_delta_exact():
    """All-zero deltas come through the ring exact: guarded scales never
    divide by zero and the codes are identically zero."""
    q, s = ring_codes_host(jnp.zeros((5, 64), jnp.float32))
    assert not np.any(np.asarray(q))
    assert np.all(np.isfinite(np.asarray(s)))


def test_ring_single_hop_roundtrip_half_level():
    """One requant pass: |dequant(codes) - acc| <= scale/254 elementwise
    (half an int8 grid step) — the per-hop bound ring_tolerance sums."""
    rng = np.random.RandomState(0)
    acc = jnp.asarray(rng.randn(513).astype(np.float32))
    s = jnp.max(jnp.abs(acc))
    q = kops.ring_quantize_codes(acc, s)
    deq = np.asarray(q, np.float32) * float(s) / 127.0
    assert np.max(np.abs(deq - np.asarray(acc))) <= float(s) / 254.0 * (
        1.0 + 1e-6)


def test_ring_combine_matches_running_mean():
    """ring_combine's fold IS the running mean: (k*deq + x)/(k+1), and its
    magnitude never exceeds the largest contributor (the int8-always-fits
    invariant)."""
    rng = np.random.RandomState(3)
    xs = [jnp.asarray(rng.randn(100).astype(np.float32)) for _ in range(6)]
    acc = xs[0]
    s = jnp.max(jnp.abs(acc))
    q = kops.ring_quantize_codes(acc, s)
    for k in range(1, 6):
        acc, amax = kops.ring_combine(q, s, xs[k], k)
        deq = np.asarray(q, np.float32) * float(s) / 127.0
        want = (k * deq + np.asarray(xs[k])) / (k + 1)
        np.testing.assert_allclose(np.asarray(acc), want, rtol=1e-6,
                                   atol=1e-7)
        contrib_max = max(float(jnp.max(jnp.abs(x))) for x in xs[:k + 1])
        assert float(amax) <= contrib_max * (1.0 + 1e-5)
        s = jnp.float32(amax)
        q = kops.ring_quantize_codes(acc, s)


# ------------------------------------------------------ wire validation ---

def test_wire_dtype_accum_param():
    """accum=1 (the ring's never-sum-on-the-wire contract) is int8 for any
    worker count; the one-shot default still widens with W."""
    for w in (1, 2, 258, 259, 4096):
        assert wire_dtype(w, accum=1) == jnp.int8
    assert wire_dtype(258) == jnp.int16
    assert wire_dtype(259) == jnp.int32


def test_check_wire_requires_quantize():
    assert check_wire(RunConfig(sync_quantize=True,
                                sync_wire="ring-int8")) == "ring-int8"
    with pytest.raises(ValueError, match="requires sync_quantize"):
        check_wire(RunConfig(sync_wire="ring-int8"))
    with pytest.raises(ValueError, match="unknown sync_wire"):
        check_wire(RunConfig(sync_quantize=True, sync_wire="ring-int4"))


# --------------------------------------------------- engine overlap seam --

def test_engine_ring_overlap_depth0_within_tolerance():
    """sync="overlap" at depth 0 under the ring wire tracks the blocking
    trajectory within the per-round requant tolerance (NOT bitwise: the
    begin/apply split lets XLA refuse the requant chain differently).
    Mirrors tests/test_sharded.py's depth-0 exactness test, with the
    tolerance the multihost harness uses."""
    cfg = R.get_smoke_config("starcoder2-3b")
    rounds, h = 3, 4
    run_cfg = RunConfig(schedule="constant", h_base=h,
                        total_steps=rounds * h, remat=False,
                        sync_quantize=True, sync_wire="ring-int8")
    lr_fn = make_lr_fn(run_cfg)

    def train(sync):
        from repro.core.engine import RoundEngine
        eng = RoundEngine(cfg, run_cfg, workers=2, b_loc=2, seq=32, seed=0,
                          layout="flat_sharded", sync=sync, overlap_depth=0)
        state, t = eng.init_state(), 0
        for _ in range(rounds):
            hh = schedules.get_h(run_cfg, t, lr_fn)
            state, m = eng.run_round(state, t, hh, lr_fn)
            assert np.isfinite(float(m["loss"]))
            t += hh
        return eng.flush(state)

    blk, ovl = train("blocking"), train("overlap")
    tol = ring_tolerance(2, 4.0 * h * run_cfg.peak_lr, rounds)
    excess = 0.0
    for b in blk["params"]:
        a = np.asarray(blk["params"][b], np.float32)
        g = np.asarray(ovl["params"][b], np.float32)
        if not a.size:
            continue
        # one output-dtype quantum per round of cast allowance (the
        # multihost comparison rule: anchor casts may straddle a boundary)
        eps = (2.0 ** -7 if "bfloat16" in b else 2.0 ** -23) * rounds
        excess = max(excess, float(np.max(np.abs(a - g)
                                          - np.abs(a) * eps)))
    assert excess <= tol, (excess, tol)
