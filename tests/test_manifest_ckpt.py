"""Sharded manifest checkpoints + checkpoint durability (checkpoint/io.py).

The contract under test:

  * every checkpoint file lands via tmp + fsync + os.replace + dir fsync —
    no tmp litter, previous version intact on any failure;
  * torn/truncated/mis-shaped checkpoints raise CheckpointError — a real
    exception that survives `python -O` (the CI smoke leg), never a bare
    `assert` or a silent mis-restore;
  * `save_sharded` writes per-process shard files + a manifest naming them;
    `restore_sharded` re-stitches the full state under any process count,
    shard-for-shard bitwise vs the monolithic `save` of the same state;
  * a writer killed between the shard files and the manifest (the
    kill-during-save window) leaves the PREVIOUS checkpoint fully readable
    — step-stamped shard filenames mean new files never clobber the ones
    the old manifest names;
  * the engine's manifest matrix: a manifest written by a 4-lane engine
    restores into tree/flat/flat_sharded engines bitwise-equal to the
    monolithic twin checkpoint;
  * `_choose_coordinator_port` walks past a pre-bound port instead of
    failing the spawn (the free-port probe races with the bind).

The multi-process half of the matrix (write under --spawn 4, restore under
1/2/4 processes) carries the `multiproc` marker and the usual probe-skip.
"""
import json
import os
import socket

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import io as CK
from repro.checkpoint.io import CheckpointError
from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import engine as E
from repro.launch import multihost
from repro.optim.lr import make_lr_fn


def _tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "a": jnp.asarray(rng.randn(7, 5).astype(np.float32)),
        "b": {"c": jnp.asarray(rng.randn(11).astype(np.float32)
                               ).astype(jnp.bfloat16),
              "d": np.arange(6, dtype=np.int32)},
        "step": 42,
    }


# ----------------------------------------------------------- durability ---

def test_write_atomic_replaces_and_leaves_no_tmp(tmp_path):
    d = str(tmp_path)
    CK._write_atomic(d, "f.bin", b"one")
    CK._write_atomic(d, "f.bin", b"two")
    assert open(os.path.join(d, "f.bin"), "rb").read() == b"two"
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_torn_checkpoint_raises_checkpoint_error(tmp_path):
    """Garbage and truncated payloads both surface as CheckpointError, not
    msgpack's zoo of exception types (or worse, a silent partial tree)."""
    path = str(tmp_path / "ck")
    like = _tree()
    CK.save(path, like, step=2)
    # torn: garbage bytes
    with open(os.path.join(path, "state.msgpack"), "wb") as f:
        f.write(b"\x00\xffnot-msgpack")
    with pytest.raises(CheckpointError, match="torn or corrupt"):
        CK.restore_with_meta(path, like)
    # truncated: half of a valid payload
    CK.save(path, like, step=2)
    full = open(os.path.join(path, "state.msgpack"), "rb").read()
    with open(os.path.join(path, "state.msgpack"), "wb") as f:
        f.write(full[:len(full) // 2])
    with pytest.raises(CheckpointError):
        CK.restore_with_meta(path, like)


def test_mismatch_raises_checkpoint_error_not_assert(tmp_path):
    """Leaf-count and shape mismatches are real errors (python -O strips
    asserts; the CI -O smoke leg restores through this path)."""
    path = str(tmp_path / "ck")
    CK.save(path, _tree(), step=0)
    with pytest.raises(CheckpointError, match="leaves"):
        CK.restore_with_meta(path, {"only": jnp.zeros(3)})
    wrong = _tree()
    wrong["a"] = jnp.zeros((7, 6), jnp.float32)
    with pytest.raises(CheckpointError, match="shape"):
        CK.restore_with_meta(path, wrong)


def test_checkpoint_guards_survive_python_O(tmp_path):
    """The -O subprocess proof: a torn checkpoint still raises under
    stripped asserts."""
    import subprocess
    import sys
    path = str(tmp_path / "ck")
    CK.save(path, {"x": jnp.arange(4.0)}, step=0)
    with open(os.path.join(path, "state.msgpack"), "wb") as f:
        f.write(b"torn")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ, PYTHONPATH=src + os.pathsep +
               os.environ.get("PYTHONPATH", ""))
    code = (
        "import jax.numpy as jnp\n"
        "from repro.checkpoint import io as CK\n"
        "try:\n"
        f"    CK.restore_with_meta({path!r}, {{'x': jnp.arange(4.0)}})\n"
        "except CK.CheckpointError:\n"
        "    print('RAISED')\n")
    out = subprocess.run([sys.executable, "-O", "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RAISED" in out.stdout


# ------------------------------------------------------------- manifest ---

def _assert_trees_equal(a, b):
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    assert ta == tb
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_manifest_bitwise_vs_monolithic_single_process(tmp_path):
    """save_sharded degenerates gracefully single-process and restores
    bitwise what save wrote — same tree, same step, same extra."""
    tree = _tree()
    mono, man = str(tmp_path / "mono"), str(tmp_path / "man")
    CK.save(mono, tree, step=4, extra={"k": "v"})
    CK.save_sharded(man, tree, step=4, extra={"k": "v"})
    assert CK.is_manifest(man) and not CK.is_manifest(mono)
    assert CK.read_manifest_meta(man) == (4, {"k": "v"})
    got_m, step_m, _ = CK.restore_with_meta(mono, tree)
    got_s, step_s, extra_s = CK.restore_sharded(man, tree)
    assert step_m == step_s == 4 and extra_s == {"k": "v"}
    _assert_trees_equal(got_m, got_s)


def test_manifest_kill_during_save_leaves_previous_readable(tmp_path):
    """A writer killed after its shard files but before the manifest (the
    barrier raises, standing in for the kill) leaves the step-2 checkpoint
    fully readable — step-stamped shard filenames never clobber the files
    the old manifest names."""
    path = str(tmp_path / "ck")
    t2, t4 = _tree(seed=2), _tree(seed=4)
    CK.save_sharded(path, t2, step=2)

    def die():
        raise RuntimeError("killed mid-save")

    with pytest.raises(RuntimeError, match="killed"):
        CK.save_sharded(path, t4, step=4, barrier=die)
    got, step, _ = CK.restore_sharded(path, t2)
    assert step == 2
    _assert_trees_equal(got, t2)
    # ...and a completed retry supersedes it, cleaning the orphans
    CK.save_sharded(path, t4, step=4)
    got, step, _ = CK.restore_sharded(path, t4)
    assert step == 4
    _assert_trees_equal(got, t4)
    names = [f for f in os.listdir(path) if f.startswith("shards-")]
    assert names and all(f.startswith("shards-00000004-")
                         for f in names), names


def test_manifest_missing_shard_file_raises(tmp_path):
    path = str(tmp_path / "ck")
    tree = _tree()
    CK.save_sharded(path, tree, step=0)
    for f in os.listdir(path):
        if f.startswith("shards-"):
            os.unlink(os.path.join(path, f))
    with pytest.raises(CheckpointError, match="missing shard file"):
        CK.restore_sharded(path, tree)


def test_manifest_leaf_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck")
    tree = _tree()
    CK.save_sharded(path, tree, step=0)
    with pytest.raises(CheckpointError, match="leaves"):
        CK.restore_sharded(path, {"only": jnp.zeros(3)})
    wrong = dict(tree, a=jnp.zeros((7, 6), jnp.float32))
    with pytest.raises(CheckpointError, match="shape"):
        CK.restore_sharded(path, wrong)


# ----------------------------------------- engine-level manifest matrix ---

def _mk_engine(layout="flat_sharded", workers=4):
    cfg = R.get_smoke_config("starcoder2-3b")
    run = RunConfig(schedule="constant", optimizer="adamw", total_steps=8,
                    peak_lr=3e-3, warmup_steps=1, h_base=2, remat=False,
                    weight_decay=0.01, sync_quantize=True)
    eng = E.RoundEngine(cfg, run, workers=workers, b_loc=2, seq=16,
                        data="device", layout=layout, sync="partial")
    return eng, make_lr_fn(run)


@pytest.mark.parametrize("restore_layout", ["tree", "flat", "flat_sharded"])
def test_engine_manifest_matrix_bitwise_vs_monolithic(tmp_path,
                                                      restore_layout):
    """The in-process half of the ISSUE's matrix: one engine writes both
    the manifest and the monolithic checkpoint; engines of every layout
    restore the manifest via restore_elastic bitwise-equal to the
    monolithic restore."""
    src, lr_fn = _mk_engine()
    st = src.init_state()
    st, _ = src.run_round(st, 0, 2, lr_fn)
    man, mono = str(tmp_path / "man"), str(tmp_path / "mono")
    src.save_sharded(man, st, step=2)
    src.save(mono, st, step=2)

    dst, _ = _mk_engine(layout=restore_layout)
    like = dst.init_state()
    got_man, step_man = dst.restore_elastic(man, like)
    dst2, _ = _mk_engine(layout=restore_layout)
    got_mono, step_mono = dst2.restore_elastic(mono, dst2.init_state())
    assert step_man == step_mono == 2
    _assert_trees_equal(got_man, got_mono)
    assert dst.h_trace == [(0, 2)]


# ------------------------------------------------- port-collision retry ---

def test_choose_coordinator_port_walks_past_prebound_port():
    """Satellite: the free-port probe races with the bind — a pre-bound
    candidate must cost one retry, not the whole spawn."""
    with socket.socket() as s:
        s.bind(("localhost", 0))
        taken = s.getsockname()[1]
        port = multihost._choose_coordinator_port(candidates=[taken])
        assert port != taken
        assert multihost._port_bindable(port)


def test_choose_coordinator_port_exhausts_to_oserror():
    with socket.socket() as s:
        s.bind(("localhost", 0))
        taken = s.getsockname()[1]
        with pytest.raises(OSError, match="no bindable coordinator port"):
            multihost._choose_coordinator_port(
                attempts=3, backoff=0.0, candidates=[taken] * 3)


# -------------------------------------------------- multi-process matrix --

_avail: dict = {}


def _require_multiproc():
    if "ok" not in _avail:
        try:
            res = multihost.spawn_workers(
                2, total_devices=2, extra=("--mode", "probe"), timeout=300)
            _avail["ok"] = all(rc == 0 for rc, _, _ in res) and all(
                json.loads(so.strip().splitlines()[-1])["ok"]
                for _, so, _ in res)
            _avail["why"] = "" if _avail["ok"] else \
                "probe failed: " + (res[0][2] or res[0][1])[-500:]
        except Exception as e:
            _avail["ok"], _avail["why"] = False, repr(e)
    if not _avail["ok"]:
        pytest.skip(f"multi-process jax backend unavailable: {_avail['why']}")


def _elastic(nproc, workdir, *, rounds=2, start=0, lanes=4, timeout=900):
    ex = ("--mode", "elastic", "--rounds", str(rounds),
          "--start-round", str(start), "--workdir", workdir, "--quantize")
    res = multihost.spawn_workers(nproc, total_devices=lanes, extra=ex,
                                  timeout=timeout)
    outs, hashes = [], {}
    for rc, so, se in res:
        assert rc == 0, f"worker failed:\n{so[-1500:]}\n{se[-3000:]}"
        rec = json.loads(so.strip().splitlines()[-1])
        assert rec["ok"], rec
        outs.append(rec)
        hashes.update(rec.get("shard_hashes") or {})
    return outs, hashes


@pytest.mark.multiproc
def test_manifest_matrix_across_process_counts(tmp_path):
    """The ISSUE's matrix, multi-process half: a manifest written under
    --spawn 4 restores under 4, 2, and 1 processes with identical
    full-state shard hashes (restore re-stitches under ANY process count
    — host-side and deterministic, so bitwise is the right bar), and
    in-process engines of all three layouts restore it bitwise-equal to a
    monolithic re-save of the same state (shard-for-shard vs monolithic
    under a different process count)."""
    _require_multiproc()
    wd4 = str(tmp_path / "w4")
    os.makedirs(wd4)
    # writer: 4 processes, 4 lanes, 2 rounds -> wd4/ckpt manifest (step 4)
    _elastic(4, wd4)
    # restore probes (zero rounds, start == rounds): 4, 2, and 1 processes
    # must re-stitch the identical state, shard for shard
    probes = {}
    for nproc in (4, 2, 1):
        _, probes[nproc] = _elastic(nproc, wd4, start=2)
    assert probes[4] and probes[4] == probes[2] == probes[1]
    # in-process matrix: restore the 4-proc manifest into host engines of
    # every layout, re-save one monolithically, and prove every layout's
    # manifest restore bitwise-equal to its monolithic restore
    man = os.path.join(wd4, "ckpt")
    mono = str(tmp_path / "mono")
    src, _ = _mk_engine()
    st, step = src.restore_elastic(man, src.init_state())
    assert step == 4
    src.save(mono, st, step=4)
    for layout in ("tree", "flat", "flat_sharded"):
        da, _ = _mk_engine(layout=layout)
        ga, sa = da.restore_elastic(man, da.init_state())
        db, _ = _mk_engine(layout=layout)
        gb, sb = db.restore_elastic(mono, db.init_state())
        assert sa == sb == 4
        _assert_trees_equal(ga, gb)
