"""RoundEngine invariants: power-of-two bucketing semantics (masked rounds
bitwise-match the legacy per-H path), the compile-count budget, schedule
invariants for every kind, on-device batch synthesis, and the engine's
checkpoint H-trace."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import engine as E
from repro.core import schedules
from repro.data.synthetic import TokenStream, device_batch_fn
from repro.optim.lr import make_lr_fn


def _run_cfg(**kw):
    base = dict(schedule="qsr", optimizer="adamw", total_steps=24,
                peak_lr=3e-3, end_lr=1e-6, warmup_steps=2, h_base=2,
                alpha=0.001, remat=False, weight_decay=0.01)
    base.update(kw)
    return RunConfig(**base)


# ---------------------------------------------------------------- buckets --

def test_bucket_pow2():
    assert [E.bucket_pow2(h) for h in (1, 2, 3, 4, 5, 7, 8, 9, 1000)] == \
        [1, 2, 4, 4, 8, 8, 8, 16, 1024]


def test_compile_budget_is_log_of_hmax():
    """A full QSR schedule visits many distinct H but at most
    ceil(log2 Hmax)+1 buckets — the acceptance bound for the engine."""
    run = RunConfig(schedule="qsr", total_steps=93_838, peak_lr=0.008,
                    end_lr=1e-6, warmup_steps=10_000, h_base=4, alpha=0.0175)
    lr = make_lr_fn(run)
    distinct = {h for _, h in schedules.rounds(run, lr)}
    buckets = E.schedule_buckets(run, lr)
    assert len(buckets) <= E.max_programs(run, lr)
    assert len(buckets) < len(distinct) / 5  # the whole point of the engine


# -------------------------------------------------- schedule invariants ---

@pytest.mark.parametrize("kind", schedules.SCHEDULE_KINDS)
def test_every_schedule_partitions_the_run(kind):
    run = _run_cfg(schedule=kind, total_steps=500, warmup_steps=50, h_base=3)
    lr = make_lr_fn(run)
    rs = list(schedules.rounds(run, lr))
    assert sum(h for _, h in rs) == run.total_steps
    assert all(h >= 1 for _, h in rs)
    t = 0
    for ts, h in rs:
        assert ts == t
        t += h


@pytest.mark.parametrize("kind", schedules.SCHEDULE_KINDS)
def test_every_schedule_pins_h_during_warmup(kind):
    """Paper §2: during warmup, H is the value of the first post-warmup
    round — for eta-dependent AND t-dependent schedules."""
    run = _run_cfg(schedule=kind, total_steps=1000, warmup_steps=200,
                   h_base=3)
    lr = make_lr_fn(run)
    pinned = schedules.get_h(run, run.warmup_steps, lr)
    for t in (0, 50, 199):
        assert schedules.get_h(run, t, lr) == pinned, (kind, t)


def test_adaptive_kind_registered_and_boundary_only():
    """The "adaptive" kind rides every SCHEDULE_KINDS-parametrized
    invariant above (partition, warmup pin) because open-loop it IS the
    QSR prior; its run-time knobs move only through round-boundary audit
    records — BatchEpoch for the traced batch lane count, the compile-key
    depth axis for overlap — never mid-round (run_round is atomic)."""
    assert "adaptive" in schedules.SCHEDULE_KINDS
    ra = _run_cfg(schedule="adaptive", total_steps=500, warmup_steps=50)
    rq = _run_cfg(schedule="qsr", total_steps=500, warmup_steps=50)
    lr = make_lr_fn(ra)
    assert schedules.h_trace(ra, lr) == schedules.h_trace(rq, lr)

    cfg = R.get_smoke_config("starcoder2-3b")
    run = _run_cfg(schedule="adaptive")
    eng = E.RoundEngine(cfg, run, workers=2, b_loc=4, seq=16,
                        mode="bucketed", data="device", adaptive_batch=True)
    lr_fn = make_lr_fn(run)
    state = eng.init_state()
    state, _ = eng.run_round(state, 0, 2, lr_fn)
    eng.batch_epoch(2)                    # at a round boundary: legal
    ep = eng.batch_epochs[-1]
    assert (ep.round_index, ep.lanes, ep.b_loc) == (1, 2, 4)
    state, m = eng.run_round(state, 2, 2, lr_fn)
    assert np.isfinite(float(m["loss"]))


# ------------------------------------------------- bucketed == legacy -----

def test_bucketed_rounds_bitwise_match_legacy():
    """The acceptance identity: driving a full smoke run through the
    bucketed engine (padded scans, masked steps) produces *bitwise* the same
    state as the legacy per-H path on the same host batches, while compiling
    only one program per power-of-two bucket."""
    cfg = R.get_smoke_config("starcoder2-3b")
    run = _run_cfg()
    lr_fn = make_lr_fn(run)
    trace = list(schedules.rounds(run, lr_fn))
    assert any(E.bucket_pow2(h) != h for _, h in trace), \
        "config must exercise a padded round"

    eb = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16,
                       mode="bucketed", data="host")
    el = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16,
                       mode="legacy", data="host")
    sb, sl = eb.init_state(), el.init_state()
    for t, h in trace:
        sb, mb = eb.run_round(sb, t, h, lr_fn)
        sl, ml = el.run_round(sl, t, h, lr_fn)
        # loss to float32 tolerance (summation order differs over the pad)
        np.testing.assert_allclose(float(mb["loss"]), float(ml["loss"]),
                                   rtol=1e-6)
    for a, b in zip(jax.tree.leaves(sb), jax.tree.leaves(sl)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    assert eb.h_trace == trace == el.h_trace
    n_buckets = len({E.bucket_pow2(h) for _, h in trace})
    assert eb.compiles == len(eb.compile_stats()["programs"]) == n_buckets
    # legacy compiled one program per distinct H
    assert el.compiles == len({h for _, h in trace})


def test_round_metrics_are_finite_and_divergence_positive():
    cfg = R.get_smoke_config("starcoder2-3b")
    run = _run_cfg(total_steps=4)
    eng = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16, data="host")
    state, m = eng.run_round(eng.init_state(), 0, 3, make_lr_fn(run))
    for k in ("loss", "grad_norm", "divergence"):
        assert np.isfinite(float(m[k])), k
    # divergence is measured pre-sync: workers saw different data, so > 0
    assert float(m["divergence"]) > 0


# ------------------------------------------------- device data path -------

def test_device_batch_synthesis_deterministic_and_shifted():
    cfg = R.get_smoke_config("starcoder2-3b")
    stream = TokenStream(vocab=max(cfg.vocab, 2), seed=3)
    synth = jax.jit(device_batch_fn(cfg, stream, w=2, b_loc=3, seq=8))
    a, b = synth(5), synth(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = synth(6)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
    # next-token labels: labels[t] is the symbol that follows tokens[t]
    np.testing.assert_array_equal(np.asarray(a["tokens"])[..., 1:],
                                  np.asarray(a["labels"])[..., :-1])
    assert a["tokens"].shape == (2, 3, 8)
    assert (np.asarray(a["tokens"]) >= 0).all()
    assert (np.asarray(a["tokens"]) < cfg.vocab).all()


def test_device_data_trains_and_caches_like_host():
    """The in-graph data path runs the same Markov language: a few rounds
    reduce the loss and reuse the bucketed compile cache."""
    cfg = R.get_smoke_config("starcoder2-3b")
    run = _run_cfg(schedule="constant", h_base=2, total_steps=16,
                   warmup_steps=1)
    lr_fn = make_lr_fn(run)
    eng = E.RoundEngine(cfg, run, workers=2, b_loc=4, seq=16, data="device")
    state = eng.init_state()
    losses = []
    for t, h in schedules.rounds(run, lr_fn):
        state, m = eng.run_round(state, t, h, lr_fn)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert eng.compiles == 1 and eng.cache_hits == len(losses) - 1


# ------------------------------------------------- checkpoint h-trace -----

def test_engine_checkpoint_roundtrip_carries_h_trace():
    cfg = R.get_smoke_config("starcoder2-3b")
    run = _run_cfg(total_steps=8, warmup_steps=1)
    lr_fn = make_lr_fn(run)
    eng = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16, data="host")
    state = eng.init_state()
    t = 0
    while t < run.total_steps:
        h = schedules.get_h(run, t, lr_fn)
        state, _ = eng.run_round(state, t, h, lr_fn)
        t += h
    with tempfile.TemporaryDirectory() as d:
        eng.save(d, state, step=t)
        eng2 = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16,
                             data="host")
        restored, step = eng2.restore(d, eng2.init_state())
        assert step == t
        assert eng2.h_trace == eng.h_trace
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_rejects_truncated_trace():
    cfg = R.get_smoke_config("starcoder2-3b")
    run = _run_cfg(total_steps=4, warmup_steps=1)
    eng = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16, data="host")
    state = eng.init_state()
    eng.h_trace = [(0, 2)]  # claims 2 steps done
    with tempfile.TemporaryDirectory() as d:
        eng.save(d, state, step=3)  # ...but the step says 3: not a boundary
        with pytest.raises(ValueError, match="round boundary"):
            eng.restore(d, eng.init_state())


# ------------------------------------------------- schedule-domain clamp --

def test_padded_lr_queries_clamped_to_schedule_domain():
    """run_round pads H up to the pow2 bucket; the padded lanes' lr queries
    must never leave the schedule's domain [0, total_steps) — a decay
    schedule queried past it can return negative/undefined values (or
    raise).  Regression: the truncated final round used to evaluate
    lr_fn(t + i) for all hp padded steps, walking past total_steps."""
    cfg = R.get_smoke_config("starcoder2-3b")
    run = _run_cfg(schedule="constant", total_steps=6, h_base=3,
                   warmup_steps=1)
    lr_fn = make_lr_fn(run)

    def strict_lr(t):
        if t >= run.total_steps:
            raise ValueError(f"schedule queried past its domain: step {t}")
        return lr_fn(t)

    trace = list(schedules.rounds(run, strict_lr))
    assert any(E.bucket_pow2(h) != h for _, h in trace), \
        "config must exercise a padded round"
    eng = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16, data="host")
    ref = E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16, data="host")
    st, sr = eng.init_state(), ref.init_state()
    for t, h in trace:
        st, _ = eng.run_round(st, t, h, strict_lr)   # must not raise
        sr, _ = ref.run_round(sr, t, h, lr_fn)
    # the clamp pads with the last valid step's lr — masked lanes never
    # apply one, so the trajectory is bitwise that of the permissive lr_fn
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(sr)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
