"""Unit tests for the HLO collective-bytes parser and pod-crossing (DCI)
classification — the §Roofline measurement layer."""
from repro.launch import hlo_analysis as H


def test_collective_bytes_basic():
    hlo = """
  %x = f32[16,1024]{1,0} all-reduce(%a), replica_groups=[16,16]<=[256], to_apply=%add
  %y = bf16[8,256]{1,0} all-gather(%b), replica_groups=[16,16]<=[256]
  %z = f32[4]{0} add(%c, %d)
"""
    out = H.collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 1024 * 4
    assert out["all-gather"] == 8 * 256 * 2
    assert out["all-to-all"] == 0


def test_start_done_not_double_counted():
    hlo = """
  %s = f32[10]{0} all-reduce-start(%a), replica_groups=[2,2]<=[4]
  %d = f32[10]{0} all-reduce-done(%s)
"""
    out = H.collective_bytes(hlo)
    assert out["all-reduce"] == 40


def test_tuple_all_reduce_sums_all_results():
    hlo = ("%t = (f32[8]{0}, f32[8]{0}) all-reduce(%a, %b), "
           "replica_groups=[4,4]<=[16]\n")
    assert H.collective_bytes(hlo)["all-reduce"] == 64


def test_dci_classification_consecutive_groups():
    # [2,256]<=[512]: groups {0..255}, {256..511} -> intra-pod
    intra = ("%x = f32[100]{0} all-reduce(%a), replica_groups=[2,256]<=[512], "
             "to_apply=%add\n")
    out = H.collective_bytes(intra, pod_size=256)
    assert out["dci"] == 0
    # [256,2]<=[2,256]T(1,0): groups {i, i+256} -> every group crosses pods
    cross = ("%x = f32[100]{0} all-reduce(%a), "
             "replica_groups=[256,2]<=[2,256]T(1,0), to_apply=%add\n")
    out = H.collective_bytes(cross, pod_size=256)
    assert out["dci"] == 400


def test_dci_explicit_list_groups():
    cross = "%x = f32[10]{0} collective-permute(%a), replica_groups={{0,300},{1,301}}\n"
    out = H.collective_bytes(cross, pod_size=256)
    assert out["dci"] == 40
    intra = "%x = f32[10]{0} collective-permute(%a), replica_groups={{0,3},{1,2}}\n"
    out = H.collective_bytes(intra, pod_size=256)
    assert out["dci"] == 0
