"""Unit tests for the HLO collective-bytes parser and pod-crossing (DCI)
classification — the §Roofline measurement layer."""
from repro.launch import hlo_analysis as H


def test_collective_bytes_basic():
    hlo = """
  %x = f32[16,1024]{1,0} all-reduce(%a), replica_groups=[16,16]<=[256], to_apply=%add
  %y = bf16[8,256]{1,0} all-gather(%b), replica_groups=[16,16]<=[256]
  %z = f32[4]{0} add(%c, %d)
"""
    out = H.collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 1024 * 4
    assert out["all-gather"] == 8 * 256 * 2
    assert out["all-to-all"] == 0


def test_start_done_not_double_counted():
    hlo = """
  %s = f32[10]{0} all-reduce-start(%a), replica_groups=[2,2]<=[4]
  %d = f32[10]{0} all-reduce-done(%s)
"""
    out = H.collective_bytes(hlo)
    assert out["all-reduce"] == 40


def test_tuple_all_reduce_sums_all_results():
    hlo = ("%t = (f32[8]{0}, f32[8]{0}) all-reduce(%a, %b), "
           "replica_groups=[4,4]<=[16]\n")
    assert H.collective_bytes(hlo)["all-reduce"] == 64


def test_variadic_collective_counts_every_operand_dtype():
    """Regression (multi-operand byte classification): a variadic
    all-gather with mixed dtypes must report per-dtype bytes for EVERY
    operand — the old first-match-per-line dtype let an f32 tensor hide
    behind an s16 one on a quantized wire."""
    hlo = ("%t = (s16[4,8]{1,0}, f32[2]{0}) all-gather(s16[1,8] %a, "
           "f32[1] %b), replica_groups=[1,4]<=[4]\n")
    op, = H.collective_ops(hlo)
    assert op["bytes_full"] == 4 * 8 * 2 + 2 * 4  # 64 s16 + 8 f32 = 72
    assert op["dtypes"] == {"s16": 64, "f32": 8}
    assert H.collective_bytes(hlo)["all-gather"] == 72


def test_scalar_shape_counts_element_bytes():
    hlo = "%s = f32[] all-reduce(f32[] %a), replica_groups={{0,1}}, to_apply=%add\n"
    assert H.collective_bytes(hlo)["all-reduce"] == 4


def test_async_gather_scatter_start_tuple_not_double_counted():
    """all-gather-start / reduce-scatter-start results are
    (operand..., result...) tuples; only the result half is the landing
    payload."""
    ag = ("%ag = (f32[4]{0}, f32[16]{0}) all-gather-start(f32[4] %p), "
          "replica_groups=[1,4]<=[4]\n"
          "%agd = f32[16]{0} all-gather-done(%ag)\n")
    assert H.collective_bytes(ag)["all-gather"] == 64
    assert H.collective_result_bytes(ag)["all-gather"] == 64
    rs = ("%rs = (f32[16]{0}, f32[4]{0}) reduce-scatter-start(f32[16] %p), "
          "replica_groups=[1,4]<=[4]\n")
    op, = H.collective_ops(rs)
    assert op["bytes_full"] == 64      # the full pre-scatter tensor
    assert op["bytes_result"] == 16    # the owned chunk


def test_payload_profile_classifies_fold_vs_payload_per_dtype():
    """payload_profile: ops at most fold_limit(n_leaves) bytes are scale
    folds; bigger ops split per-dtype — a mixed tuple's f32 half above the
    limit appears as its own payload dtype."""
    n_leaves = 2   # fold_limit = 72
    hlo = (
        "%f = f32[2]{0} all-reduce(f32[2] %s), replica_groups=[1,4]<=[4], "
        "to_apply=%max\n"                       # 8 bytes: the amax fold
        "%q = (s16[100]{0}, f32[50]{0}) all-gather(s16[25] %a, f32[13] %b), "
        "replica_groups=[1,4]<=[4]\n")          # 200 s16 + 200 f32 payload
    prof = H.payload_profile(hlo, n_leaves=n_leaves)
    assert prof["amax_fold_ops"] == 1 and prof["amax_fold_bytes"] == 8
    assert prof["payload_all_reduce_ops"] == 0
    assert prof["payload_ops_by_dtype"] == {"s16": 1, "f32": 1}
    assert prof["payload_bytes_by_dtype"] == {"s16": 200, "f32": 200}


def test_donation_aliases_parsed_from_header():
    hdr = ("HloModule jit_round, input_output_alias={ {0}: (0, {}, "
           "may-alias), {1}: (1, {0}, may-alias) }, "
           "entry_computation_layout={(f32[8])->f32[8]}\n")
    assert H.donation_aliases(hdr) == [((0,), 0, ()), ((1,), 1, (0,))]
    assert H.donation_aliases("HloModule plain\n") == []


def test_degenerate_replica_groups_detected():
    bad = ("%x = f32[8]{0} all-reduce(f32[8] %a), "
           "replica_groups={{0},{1},{2},{3}}, to_apply=%add\n")
    assert len(H.degenerate_collectives(bad)) == 1
    good = ("%x = f32[8]{0} all-reduce(f32[8] %a), "
            "replica_groups={{0,1},{2,3}}, to_apply=%add\n")
    assert H.degenerate_collectives(good) == []


def test_host_callback_lines_detected():
    hlo = ('%cc = f32[2]{0} custom-call(f32[2] %a), '
           'custom_call_target="xla_python_cpu_callback"\n'
           '%ok = f32[2]{0} custom-call(f32[2] %a), '
           'custom_call_target="Sharding"\n')
    lines = H.host_callbacks(hlo)
    assert len(lines) == 1 and "callback" in lines[0]


def test_dci_classification_consecutive_groups():
    # [2,256]<=[512]: groups {0..255}, {256..511} -> intra-pod
    intra = ("%x = f32[100]{0} all-reduce(%a), replica_groups=[2,256]<=[512], "
             "to_apply=%add\n")
    out = H.collective_bytes(intra, pod_size=256)
    assert out["dci"] == 0
    # [256,2]<=[2,256]T(1,0): groups {i, i+256} -> every group crosses pods
    cross = ("%x = f32[100]{0} all-reduce(%a), "
             "replica_groups=[256,2]<=[2,256]T(1,0), to_apply=%add\n")
    out = H.collective_bytes(cross, pod_size=256)
    assert out["dci"] == 400


def test_dci_explicit_list_groups():
    cross = "%x = f32[10]{0} collective-permute(%a), replica_groups={{0,300},{1,301}}\n"
    out = H.collective_bytes(cross, pod_size=256)
    assert out["dci"] == 40
    intra = "%x = f32[10]{0} collective-permute(%a), replica_groups={{0,3},{1,2}}\n"
    out = H.collective_bytes(intra, pod_size=256)
    assert out["dci"] == 0
