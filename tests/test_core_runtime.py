"""Local-gradient runtime semantics: the paper's algebraic identities and
system invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import local_update as LU
from repro.core.sync import worker_mean
from repro.models import api, param as pm


def _setup(arch="phi3-medium-14b", optimizer="sgd", **kw):
    cfg = R.get_smoke_config(arch)
    run = RunConfig(optimizer=optimizer, remat=False, total_steps=16,
                    peak_lr=0.05, weight_decay=0.0, **kw)
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0))
    return cfg, run, mod, params


def _tok_batches(cfg, n, w, b, s, seed=7):
    return jax.random.randint(jax.random.PRNGKey(seed), (n, w, b, s), 0,
                              cfg.vocab)


def test_local_h1_equals_parallel_sgd():
    """Paper §3 footnote: Local SGD with H=1 is mathematically equivalent to
    parallel SGD (linearity of the SGD+momentum update)."""
    cfg, run, mod, params = _setup(optimizer="sgd")
    w, b, s = 4, 2, 16
    toks = _tok_batches(cfg, 6, w, b, s)

    state = LU.init_state(cfg, run, params, w)
    round_fn = jax.jit(LU.make_train_round(cfg, run))
    pstate = LU.init_parallel_state(cfg, run, params)
    pstep = jax.jit(LU.make_parallel_step(cfg, run))
    for t in range(6):
        bt = {"tokens": toks[t][None], "labels": toks[t][None]}
        state, _ = round_fn(state, bt, jnp.array([0.05]))
        flat = toks[t].reshape(w * b, s)
        pstate, _ = pstep(pstate, {"tokens": flat, "labels": flat}, 0.05)
    local = jax.tree.map(lambda x: x[0], state["params"])
    for a, b_ in zip(jax.tree.leaves(local), jax.tree.leaves(pstate["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5)


def test_replicas_diverge_then_sync_restores_consensus():
    """Between syncs workers diverge (different data); after sync all replicas
    are exactly equal — Alg. 2's averaging step."""
    cfg, run, mod, params = _setup(optimizer="adamw")
    w = 4
    state = LU.init_state(cfg, run, params, w)
    step = jax.jit(LU.make_local_step(cfg, run))
    toks = _tok_batches(cfg, 3, w, 2, 16)
    for t in range(3):
        state, _ = step(state, {"tokens": toks[t], "labels": toks[t]}, 1e-3)
    # diverged: worker 0 != worker 1 somewhere
    leaves = jax.tree.leaves(state["params"])
    assert any(not np.allclose(x[0], x[1]) for x in map(np.asarray, leaves))
    synced = worker_mean(state["params"])
    for x in map(np.asarray, jax.tree.leaves(synced)):
        for k in range(1, w):
            np.testing.assert_array_equal(x[0], x[k])


def test_sync_is_exact_mean():
    tree = {"a": jnp.arange(12.0).reshape(4, 3)}
    out = worker_mean(tree)["a"]
    want = jnp.broadcast_to(jnp.arange(12.0).reshape(4, 3).mean(0,
                            keepdims=True), (4, 3))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def test_optimizer_state_not_averaged_by_sync():
    """The paper averages parameters only; Local AdamW keeps local moments."""
    cfg, run, mod, params = _setup(optimizer="adamw")
    w = 2
    state = LU.init_state(cfg, run, params, w)
    step = jax.jit(LU.make_local_step(cfg, run))
    round_fn = jax.jit(LU.make_train_round(cfg, run))
    toks = _tok_batches(cfg, 2, w, 2, 16)
    state, _ = step(state, {"tokens": toks[0], "labels": toks[0]}, 1e-3)
    m_before = jax.tree.leaves(state["opt"]["m"])
    bt = {"tokens": toks[1][None], "labels": toks[1][None]}
    state, _ = round_fn(state, bt, jnp.array([1e-3]))
    # after the round, the per-worker m moments still differ across workers
    assert any(not np.allclose(np.asarray(x)[0], np.asarray(x)[1])
               for x in jax.tree.leaves(state["opt"]["m"]))


def test_quantized_sync_tracks_exact_sync():
    """Beyond-paper int8 sync: the quantized average stays within the int8
    quantization error of the exact average."""
    cfg, run, mod, params = _setup(optimizer="sgd")
    runq = dataclasses.replace(run, sync_quantize=True)
    w = 4
    toks = _tok_batches(cfg, 2, w, 2, 16)

    s_exact = LU.init_state(cfg, run, params, w)
    s_quant = LU.init_state(cfg, runq, params, w)
    r_exact = jax.jit(LU.make_train_round(cfg, run))
    r_quant = jax.jit(LU.make_train_round(cfg, runq))
    bt = {"tokens": toks[0][None], "labels": toks[0][None]}
    s_exact, _ = r_exact(s_exact, bt, jnp.array([0.05]))
    s_quant, _ = r_quant(s_quant, bt, jnp.array([0.05]))
    for a, b in zip(jax.tree.leaves(s_exact["params"]),
                    jax.tree.leaves(s_quant["params"])):
        a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
        # error bounded by ~ max|delta| / 127 per tensor
        assert np.abs(a - b).max() < 0.1 * max(np.abs(a).max(), 1e-6) + 1e-4


def test_outer_momentum_sync_changes_trajectory_but_stays_finite():
    cfg, run, mod, params = _setup(optimizer="sgd")
    runm = dataclasses.replace(run, outer_momentum=0.9)
    w = 2
    toks = _tok_batches(cfg, 4, w, 2, 16)
    s = LU.init_state(cfg, runm, params, w)
    r = jax.jit(LU.make_train_round(cfg, runm))
    for t in range(4):
        bt = {"tokens": toks[t][None], "labels": toks[t][None]}
        s, loss = r(s, bt, jnp.array([0.05]))
        assert np.isfinite(float(loss))
    for x in jax.tree.leaves(s["params"]):
        assert np.isfinite(np.asarray(x)).all()
