"""Property tests (hypothesis) for the paper's H-schedules and the paper's
reported communication volumes."""
import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import RunConfig
from repro.core import schedules
from repro.optim.lr import make_lr_fn


def _run(schedule="qsr", **kw):
    base = dict(schedule=schedule, total_steps=1000, peak_lr=0.008,
                end_lr=1e-6, warmup_steps=100, h_base=4, alpha=0.0175)
    base.update(kw)
    return RunConfig(**base)


@given(alpha=st.floats(0.001, 0.5), peak=st.floats(1e-3, 1.0),
       total=st.integers(50, 5000), h_base=st.integers(1, 16))
@settings(max_examples=60, deadline=None)
def test_rounds_partition_the_run(alpha, peak, total, h_base):
    """Rounds exactly tile [0, T): sum of H == T, all H >= 1."""
    run = _run(alpha=alpha, peak_lr=peak, total_steps=total, h_base=h_base,
               warmup_steps=total // 10)
    lr = make_lr_fn(run)
    rs = list(schedules.rounds(run, lr))
    assert sum(h for _, h in rs) == total
    assert all(h >= 1 for _, h in rs)
    # t_starts are the prefix sums
    t = 0
    for ts, h in rs:
        assert ts == t
        t += h


@given(alpha=st.floats(0.005, 0.1), h_base=st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_qsr_monotone_under_decay(alpha, h_base):
    """With a monotonically decaying lr, QSR's H never decreases (except the
    forced truncation of the final round)."""
    run = _run(alpha=alpha, h_base=h_base, warmup_steps=0)
    lr = make_lr_fn(run)
    hs = [h for _, h in schedules.rounds(run, lr)]
    body = hs[:-1]
    assert all(b >= a for a, b in zip(body, body[1:]))


def test_qsr_is_quadratic_in_inv_lr():
    """H(eta) ~ (alpha/eta)^2 exactly (mod floor/max) — eq. 2."""
    run = _run(warmup_steps=0)
    lr = make_lr_fn(run)
    for t in [0, 300, 600, 900, 990]:
        h = schedules.get_h(run, t, lr)
        eta = lr(t)
        expect = max(run.h_base, int((run.alpha / eta) ** 2))
        assert h == min(expect, run.total_steps - t)


def test_warmup_pins_h_to_post_warmup_value():
    run = _run(warmup_steps=200)
    lr = make_lr_fn(run)
    assert schedules.get_h(run, 0, lr) == schedules.get_h(run, 200, lr)


def test_parallel_and_constant():
    lr = make_lr_fn(_run("parallel"))
    assert all(h == 1 for _, h in schedules.rounds(_run("parallel"), lr))
    rc = _run("constant", h_base=4, total_steps=1000)
    assert all(h == 4 for _, h in schedules.rounds(rc, make_lr_fn(rc)))


def test_ordering_of_schedules_late_in_training():
    """Late in training (small lr): H_qsr >= H_inverse >= H_const — the
    schedule ordering behind the paper's generalization ordering."""
    base = dict(total_steps=10_000, peak_lr=0.008, warmup_steps=0, h_base=4,
                alpha=0.0175, beta=0.03)
    t = 9_000
    hq = schedules.get_h(RunConfig(schedule="qsr", **base), t,
                         make_lr_fn(RunConfig(schedule="qsr", **base)))
    hi = schedules.get_h(RunConfig(schedule="inverse", **base), t,
                         make_lr_fn(RunConfig(schedule="inverse", **base)))
    hc = schedules.get_h(RunConfig(schedule="constant", **base), t,
                         make_lr_fn(RunConfig(schedule="constant", **base)))
    assert hq >= hi >= hc


def test_comm_volume_matches_paper_vit_recipe():
    """Paper Fig. 1(b): QSR on ViT-B (cosine, peak 0.008, alpha=0.0175,
    H_base=4, B=4096, 300 epochs -> ~93.8k steps, 10k warmup) uses ~10-13%
    of data-parallel communication; constant H=4 uses exactly 25%."""
    steps = round(1_281_167 / 4096 * 300)  # ImageNet, B=4096, 300 epochs
    run = RunConfig(schedule="qsr", total_steps=steps, peak_lr=0.008,
                    end_lr=1e-6, warmup_steps=10_000, h_base=4, alpha=0.0175)
    frac = schedules.comm_fraction(run, make_lr_fn(run))
    assert 0.06 < frac < 0.16, frac  # paper reports ~10.4% (Fig. 1)
    runc = RunConfig(schedule="constant", total_steps=steps, h_base=4)
    fc = schedules.comm_fraction(runc, make_lr_fn(runc))
    assert abs(fc - 0.25) < 1e-4
    assert frac < fc  # QSR communicates less than constant-H (Table 1)


@given(st.integers(1, 12))
@settings(max_examples=12, deadline=None)
def test_swap_final_round_is_local_until_end(h_base):
    run = _run("swap", h_base=h_base, switch_frac=0.5, warmup_steps=0)
    rs = list(schedules.rounds(run, make_lr_fn(run)))
    # the round that crosses the switch point extends to the end
    assert rs[-1][0] + rs[-1][1] == run.total_steps
    t0 = int(run.switch_frac * run.total_steps)
    last_start, last_h = rs[-1]
    assert last_h >= run.total_steps - t0 - h_base


def test_cubic_rule_early_late_crossover():
    """App. G: relative to comm-matched QSR, the cubic rule communicates
    more early and explosively less late — the mechanism behind QSR > cubic
    on schedules without a rapid decay tail (Table 6)."""
    base = dict(total_steps=93_838, peak_lr=0.008, end_lr=1e-6,
                warmup_steps=10_000, h_base=4, alpha=0.0175, rho=0.0075)
    rq = RunConfig(schedule="qsr", **base)
    rc = RunConfig(schedule="cubic", **base)
    lr_q, lr_c = make_lr_fn(rq), make_lr_fn(rc)
    # App. G (verbatim): the cubic rule "communicates more frequently at
    # earlier stages but much less at later stages".
    t_early, t_late = 20_000, 91_000
    assert schedules.get_h(rc, t_early, lr_c) <= schedules.get_h(rq, t_early, lr_q)
    raw_c = (rc.rho / lr_c(t_late)) ** 3
    raw_q = (rq.alpha / lr_q(t_late)) ** 2
    assert raw_c > 10 * raw_q  # tail H blows up much faster for cubic


def test_related_work_schedules_partition_and_trend():
    """Paper §A baselines: Haddadpour's H grows; Wang&Joshi's H shrinks."""
    for kind in ("linear_inc", "dec_sqrt"):
        run = _run(kind, warmup_steps=0, total_steps=2000)
        lr = make_lr_fn(run)
        rs = list(schedules.rounds(run, lr))
        assert sum(h for _, h in rs) == run.total_steps
        hs = [h for _, h in rs][:-1]
        if kind == "linear_inc":
            assert hs[-1] > hs[0]
        else:
            assert hs[-1] < hs[0]
