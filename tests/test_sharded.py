"""ShardedFlatSpace + overlapped sync (core/flat.py, core/sync.py,
core/engine.py) — mirrors tests/test_flat.py for the sharded layout.

The contract under test:
  * ShardedFlatSpace pads each dtype bucket to a multiple of `shards` and
    the padding is inert: flatten/unflatten round-trips exactly, pad
    elements never contaminate per-tensor segment statistics;
  * a full bucketed multi-round run under layout="flat_sharded" produces
    *bitwise* the params/optimizer state of layout="tree", for both paper
    algorithms and with the beyond-paper sync options (int8 quantize,
    outer Nesterov) on and off;
  * sync="overlap" at depth 0 is bitwise the blocking trajectory once the
    final in-flight reduce is flushed (the exactness mode), and depth > 0
    runs the correction form without diverging;
  * checkpoints restore across all three layouts (and across shard counts)
    exactly, via the meta side file;
  * the lowering claim (subprocess, sharded host mesh): the sharded sync
    compiles to exactly one reduce_scatter + one all_gather per dtype
    bucket — no all-reduce — for both the dp and the fsdp (pod-worker)
    policies, with the scatter leg landing 1/W of the bucket per device.
"""
import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import engine as E
from repro.core import flat as F
from repro.core import schedules
from repro.optim.lr import make_lr_fn

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# 13 never divides the smoke bucket sizes -> padding is actually exercised
SHARDS = 13


# ----------------------------------------------------------- spec/padding --

def _tree_of(shapes_dtypes, seed=0):
    rng = np.random.RandomState(seed)
    return {f"p{i}": jnp.asarray(rng.randn(*shp).astype(np.float32)).astype(dt)
            for i, (shp, dt) in enumerate(shapes_dtypes)}


def test_sharded_padding_round_trip():
    tree = _tree_of([((3, 5), jnp.float32), ((7,), jnp.bfloat16),
                     ((2, 2, 2), jnp.float32), ((1,), jnp.bfloat16)])
    spec = F.ShardedFlatSpace(tree, 5)
    assert spec.sizes == {"bfloat16": 8, "float32": 23}
    assert spec.pad == {"bfloat16": 2, "float32": 2}
    assert spec.buffer_size("float32") == 25 and spec.buffer_size("float32") % 5 == 0
    bufs = spec.flatten(tree)
    assert all(b.shape == (spec.buffer_size(k),) for k, b in bufs.items())
    # pad region is exactly zero, and invisible to unflatten
    assert (np.asarray(bufs["float32"], np.float32)[-2:] == 0).all()
    back = spec.unflatten(bufs)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(tree[k], np.float32))
    # leading worker axis pads per row
    stacked = jax.tree.map(lambda x: jnp.stack([x, x + 1]), tree)
    bufs2 = spec.flatten(stacked, lead=1)
    assert all(b.shape == (2, spec.buffer_size(k)) for k, b in bufs2.items())
    back2 = spec.unflatten(bufs2, lead=1)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back2[k], np.float32),
                                      np.asarray(stacked[k], np.float32))


def test_sharded_segment_stats_ignore_pad():
    """The pad's segment id is out of range: segment_max drops it, so a
    bucket-wide max can never be contaminated by the pad — and spread's
    clamped gather hands pad elements a real leaf's scale, harmless because
    pad deltas are exactly zero."""
    tree = _tree_of([((4, 3), jnp.float32), ((11,), jnp.float32)], seed=3)
    spec = F.ShardedFlatSpace(tree, 7)   # 23 -> pad 5
    assert spec.pad["float32"] == 5
    seg = spec.segment_ids("float32")
    assert seg.shape == (28,) and (seg[-5:] == 2).all()
    buf = spec.flatten(tree)["float32"]
    # poison the pad region: statistics must not see it
    poisoned = buf.at[-5:].set(1e9)
    per_leaf = spec.segment_max("float32", jnp.abs(poisoned))
    want = [float(jnp.max(jnp.abs(tree[k]))) for k in ("p0", "p1")]
    np.testing.assert_array_equal(np.asarray(per_leaf), np.asarray(want))
    spread = np.asarray(spec.spread("float32", per_leaf))
    np.testing.assert_array_equal(spread[:23], np.asarray(want)[seg[:23]])


# ------------------------------------------------ flat_sharded == tree ----

def _engines(schedule, optimizer, quantize, momentum, steps=8, **kw):
    cfg = R.get_smoke_config("starcoder2-3b")
    run = RunConfig(schedule=schedule, optimizer=optimizer,
                    total_steps=steps, peak_lr=3e-3, end_lr=1e-6,
                    warmup_steps=2, h_base=2, alpha=0.001, remat=False,
                    weight_decay=0.01, sync_quantize=quantize,
                    outer_momentum=momentum)
    lr_fn = make_lr_fn(run)
    trace = list(schedules.rounds(run, lr_fn))
    mk = lambda **k: E.RoundEngine(cfg, run, workers=2, b_loc=2, seq=16,
                                   data="host", **{**kw, **k})
    return mk, trace, lr_fn


@pytest.mark.parametrize("schedule,optimizer,quantize,momentum", [
    ("qsr", "adamw", False, 0.0),        # paper Alg. 2, plain mean sync
    ("qsr", "adamw", True, 0.9),         # both beyond-paper options on
    ("parallel", "sgd", False, 0.0),     # paper Alg. 1 (H=1 every round)
    ("qsr", "sgd", True, 0.0),           # int8 sync alone
])
def test_flat_sharded_run_bitwise_matches_tree(schedule, optimizer,
                                               quantize, momentum):
    """The acceptance identity, sharded edition: a full bucketed run under
    layout="flat_sharded" (with real padding) ends in *bitwise* the same
    params and optimizer state as layout="tree"."""
    mk, trace, lr_fn = _engines(schedule, optimizer, quantize, momentum)
    et = mk(layout="tree")
    es = mk(layout="flat_sharded", shards=SHARDS)
    st, ss = et.init_state(), es.init_state()
    assert any(es.spec.pad.values()), "pick SHARDS so padding is exercised"
    for t, h in trace:
        st, mt = et.run_round(st, t, h, lr_fn)
        ss, ms = es.run_round(ss, t, h, lr_fn)
        np.testing.assert_allclose(float(mt["loss"]), float(ms["loss"]),
                                   rtol=1e-6)
    ss_tree = F.to_tree_state(es.spec, ss)
    la, ta = jax.tree.flatten(st)
    lb, tb = jax.tree.flatten(ss_tree)
    assert ta == tb
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the pad region of every stateful buffer stayed exactly zero
    for buf in jax.tree.leaves({"p": ss["params"],
                                "o": {k: v for k, v in ss["opt"].items()
                                      if k != "step"}}):
        pad = es.spec.pad["float32"]
        assert (np.asarray(buf, np.float32)[..., -pad:] == 0).all()
    # params_single agrees across layouts
    pa, pb = et.params_single(st), es.params_single(ss)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- overlapped sync --------

@pytest.mark.parametrize("layout,quantize,momentum", [
    ("tree", False, 0.0),
    ("tree", True, 0.9),
    ("flat", True, 0.0),            # overlap x quantize on the flat layout:
    ("flat", True, 0.9),            # begin/apply split vs the fused kernel
    ("flat_sharded", False, 0.0),
    ("flat_sharded", True, 0.0),
    ("flat_sharded", True, 0.9),
])
def test_overlap_depth0_bitwise_matches_blocking(layout, quantize, momentum):
    """The exactness mode: sync="overlap" with depth 0 applies each round's
    pending reduce before the next round's first step, so every local step
    sees bitwise the params it would under blocking sync; flush() aligns
    the final state."""
    kw = {"shards": SHARDS} if layout == "flat_sharded" else {}
    mk, trace, lr_fn = _engines("qsr", "adamw", quantize, momentum)
    eb = mk(layout=layout, **kw)
    eo = mk(layout=layout, sync="overlap", overlap_depth=0, **kw)
    sb, so = eb.init_state(), eo.init_state()
    for t, h in trace:
        sb, mb = eb.run_round(sb, t, h, lr_fn)
        so, mo = eo.run_round(so, t, h, lr_fn)
        # identical steps -> identical in-round metrics, bitwise
        assert float(mb["loss"]) == float(mo["loss"])
        assert float(mb["divergence"]) == float(mo["divergence"])
    so = eo.flush(so)
    la, ta = jax.tree.flatten(sb)
    lb, tb = jax.tree.flatten(so)
    assert ta == tb
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # two program variants: first round (no pending) + steady state
    assert all(isinstance(k, tuple) for k in eo._programs)


def test_overlap_depth_keeps_local_progress():
    """Depth > 0 (correction form): the run stays finite and close to the
    blocking trajectory, and flush() clears the in-flight reduce."""
    mk, trace, lr_fn = _engines("qsr", "adamw", False, 0.0)
    eb = mk(layout="flat_sharded", shards=SHARDS)
    eo = mk(layout="flat_sharded", shards=SHARDS, sync="overlap",
            overlap_depth=1)
    sb, so = eb.init_state(), eo.init_state()
    for t, h in trace:
        sb, _ = eb.run_round(sb, t, h, lr_fn)
        so, _ = eo.run_round(so, t, h, lr_fn)
    assert eo._pending is not None
    so = eo.flush(so)
    assert eo._pending is None
    for a, b in zip(jax.tree.leaves(sb["params"]),
                    jax.tree.leaves(so["params"])):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        assert np.isfinite(b).all()
        # one stale step on a smoke model: a small, bounded perturbation
        assert np.abs(a - b).max() < 5e-2


@pytest.mark.parametrize("layout,momentum", [
    ("flat", 0.0), ("flat_sharded", 0.0), ("flat_sharded", 0.9),
])
def test_overlap_depth_quantized_correction_form(layout, momentum):
    """overlap x quantize at depth > 0 (the previously-untested interaction):
    the correction form runs on quantized pending syncs — the deferred
    gather dequantizes the code-sums while workers are d steps ahead — and
    stays finite and close to the blocking quantized trajectory; flush()
    clears the in-flight reduce."""
    kw = {"shards": SHARDS} if layout == "flat_sharded" else {}
    mk, trace, lr_fn = _engines("qsr", "adamw", True, momentum)
    eb = mk(layout=layout, **kw)
    eo = mk(layout=layout, sync="overlap", overlap_depth=1, **kw)
    sb, so = eb.init_state(), eo.init_state()
    for t, h in trace:
        sb, _ = eb.run_round(sb, t, h, lr_fn)
        so, _ = eo.run_round(so, t, h, lr_fn)
    assert eo._pending is not None
    # pending carries the quantized reduce: codes + per-element scales
    assert set(eo._pending) == {"q", "scale"}
    so = eo.flush(so)
    assert eo._pending is None
    for a, b in zip(jax.tree.leaves(sb["params"]),
                    jax.tree.leaves(so["params"])):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        assert np.isfinite(b).all()
        assert np.abs(a - b).max() < 5e-2


# ------------------------------------------------- checkpoint restore -----

def test_cross_layout_checkpoint_all_three():
    """tree <-> flat <-> flat_sharded (and shard-count changes) restore
    exactly: the meta side file records the writer's layout + shards and
    the engine converts through the tree layout."""
    mk, trace, lr_fn = _engines("qsr", "adamw", True, 0.9, steps=4)
    eng = {"tree": mk(layout="tree"),
           "flat": mk(layout="flat"),
           "sharded": mk(layout="flat_sharded", shards=SHARDS),
           "sharded4": mk(layout="flat_sharded", shards=4)}
    states = {k: e.init_state() for k, e in eng.items()}
    for t, h in trace:
        for k, e in eng.items():
            states[k], _ = e.run_round(states[k], t, h, lr_fn)
    for src in ("tree", "flat", "sharded"):
        for dst in ("tree", "flat", "sharded", "sharded4"):
            if src == dst:
                continue
            with tempfile.TemporaryDirectory() as d:
                eng[src].save(d, states[src], step=4)
                restored, step = eng[dst].restore(d, eng[dst].init_state())
                assert step == 4
                for a, b in zip(jax.tree.leaves(restored),
                                jax.tree.leaves(states[dst])):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))


def test_save_requires_flush_or_explicit_flush_pending_in_overlap_mode():
    """The overlap checkpoint guard is a real PendingSyncError — not a bare
    assert stripped under `python -O` — and save(flush_pending=True) writes
    the synced consensus WITHOUT consuming the in-flight pipeline."""
    mk, trace, lr_fn = _engines("qsr", "adamw", False, 0.0, steps=2)
    eo = mk(layout="flat_sharded", shards=SHARDS, sync="overlap")
    so = eo.init_state()
    t, h = trace[0]
    so, _ = eo.run_round(so, t, h, lr_fn)
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(E.PendingSyncError, match="flush"):
            eo.save(d, so, step=h)
        eo.save(d, so, step=h, flush_pending=True)   # consensus written...
        assert eo._pending is not None               # ...pipeline untouched
        # what was written IS the flushed state, bitwise
        flushed = eo.flush(so)
        restored, step = mk(layout="flat_sharded", shards=SHARDS).restore(
            d, mk(layout="flat_sharded", shards=SHARDS).init_state())
        assert step == h
        for a, b in zip(jax.tree.leaves(restored),
                        jax.tree.leaves(flushed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_save_guard_survives_python_O():
    """Run the overlap save guard under `python -O` in a subprocess: the
    old bare `assert self._pending is None` was stripped there, silently
    checkpointing pre-consensus params.  PendingSyncError must survive."""
    env = dict(os.environ, PYTHONPATH=SRC + os.pathsep +
               os.environ.get("PYTHONPATH", ""))
    code = (
        "import tempfile\n"
        "from repro.configs import registry as R\n"
        "from repro.configs.base import RunConfig\n"
        "from repro.core.engine import RoundEngine, PendingSyncError\n"
        "cfg = R.get_smoke_config('starcoder2-3b')\n"
        "run = RunConfig(schedule='constant', total_steps=4, h_base=2,\n"
        "                remat=False)\n"
        "eng = RoundEngine(cfg, run, workers=2, b_loc=2, seq=16,\n"
        "                  sync='overlap')\n"
        "eng._pending = {'stub': None}   # an in-flight reduce\n"
        "with tempfile.TemporaryDirectory() as d:\n"
        "    try:\n"
        "        eng.save(d, {}, step=0)\n"
        "    except PendingSyncError:\n"
        "        print('RAISED')\n"
        "    try:\n"
        "        eng.params_single({'params': {}})\n"
        "    except PendingSyncError:\n"
        "        print('RAISED2')\n")
    out = subprocess.run([sys.executable, "-O", "-c", code],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RAISED" in out.stdout and "RAISED2" in out.stdout


def test_restore_refuses_live_pending():
    """restore() over an in-flight sync would orphan the pending reduce —
    it must refuse (PendingSyncError), not silently drop it."""
    mk, trace, lr_fn = _engines("qsr", "adamw", False, 0.0, steps=4)
    eb = mk(layout="flat_sharded", shards=SHARDS)
    sb = eb.init_state()
    for t, h in trace:
        sb, _ = eb.run_round(sb, t, h, lr_fn)
    eo = mk(layout="flat_sharded", shards=SHARDS, sync="overlap")
    so = eo.init_state()
    t, h = trace[0]
    so, _ = eo.run_round(so, t, h, lr_fn)
    assert eo._pending is not None
    with tempfile.TemporaryDirectory() as d:
        eb.save(d, sb, step=4)
        with pytest.raises(E.PendingSyncError, match="orphan"):
            eo.restore(d, eo.init_state())
        so = eo.flush(so)
        restored, step = eo.restore(d, eo.init_state())  # now fine
        assert step == 4


@pytest.mark.parametrize("dst_layout,dst_kw", [
    ("tree", {}),
    ("flat", {}),
    ("flat_sharded", {"shards": SHARDS}),
])
def test_save_under_overlap_restores_to_blocking_trajectory(dst_layout,
                                                            dst_kw):
    """The overlap rows of the cross-layout restore matrix: a checkpoint
    written MID-overlap (flush_pending=True, reduce still in flight) holds
    the blocking consensus — restoring it into any layout and finishing
    the run under blocking sync lands bitwise on the full blocking
    trajectory.  A pre-consensus state is impossible to observe."""
    mk, trace, lr_fn = _engines("qsr", "adamw", True, 0.9)
    cut = len(trace) // 2
    t_cut = trace[cut][0]

    eb = mk(layout=dst_layout, **dst_kw)                 # blocking reference
    sb = eb.init_state()
    for t, h in trace:
        sb, _ = eb.run_round(sb, t, h, lr_fn)

    eo = mk(layout="flat_sharded", shards=SHARDS, sync="overlap")
    so = eo.init_state()
    for t, h in trace[:cut]:
        so, _ = eo.run_round(so, t, h, lr_fn)
    assert eo._pending is not None, "a reduce must be in flight at the cut"
    with tempfile.TemporaryDirectory() as d:
        eo.save(d, so, step=t_cut, flush_pending=True)
        assert eo._pending is not None                  # pipeline untouched
        er = mk(layout=dst_layout, **dst_kw)
        sr, step = er.restore(d, er.init_state())
        assert step == t_cut and er.h_trace == trace[:cut]
    for t, h in trace[cut:]:
        sr, _ = er.run_round(sr, t, h, lr_fn)
    la, ta = jax.tree.flatten(sb)
    lb, tb = jax.tree.flatten(sr)
    assert ta == tb
    for a, b in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- lowering proof (HLO) ---

def _sync_compare(*extra):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.sync_compare",
         "--arch", "starcoder2-3b", *extra],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout)


def _assert_rules_ok(rec_layout, *rules):
    """The lowering claims live in ONE place — repro.analysis.rules —
    and every record sync_compare prints carries the registry's verdicts;
    tests assert through them instead of re-deriving counts per file."""
    for r in rules:
        verdict = rec_layout["rules"][r]
        assert verdict["applies"], f"rule {r} did not apply"
        assert verdict["ok"], (r, verdict["violations"])


def test_sharded_sync_lowers_to_rs_plus_ag_per_bucket():
    """Acceptance: on the 8-device simulated mesh the flat_sharded sync is
    exactly one reduce_scatter + one all_gather per dtype bucket — no
    all-reduce — and the scatter leg lands 1/W of the flat bucket.
    The per-bucket budget is the registry's collective-budget rule
    (repro.analysis.rules); only the cross-layout byte relations stay
    test-local."""
    rec = _sync_compare("--mesh", "4x2")
    flat, sh = rec["flat"], rec["flat_sharded"]
    _assert_rules_ok(sh, "collective-budget", "no-degenerate-replica-group",
                     "no-host-callback")
    # flat (one all-reduce per bucket) and tree (per-leaf) budgets through
    # the same registry
    _assert_rules_ok(flat, "collective-budget")
    _assert_rules_ok(rec["tree"], "collective-budget")
    # W x S = 8 chunks: the scatter leg lands 1/8 of the flat bucket bytes
    assert sh["scatter_leg_bytes"] * 8 == flat["bytes_on_wire"]


def test_fsdp_policy_sharded_sync_lowers_on_pod_mesh():
    """The fsdp policy leaves the tree path: with pods as workers
    (2x2x2 mesh) the sharded sync still lowers to one reduce_scatter + one
    all_gather per bucket, chunked over (data, model) inside each pod."""
    rec = _sync_compare("--mesh", "2x2x2", "--policy", "fsdp",
                        "--param-layout", "flat_sharded")
    sh = rec["flat_sharded"]
    _assert_rules_ok(sh, "collective-budget")
    assert sh["scatter_leg_bytes"] > 0
