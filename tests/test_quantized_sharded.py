"""Quantized sync on the sharded layout — the RS-domain acceptance proofs.

The contract under test (subprocess `launch/sync_compare`, sharded host
mesh):
  * LOWERING: a quantized flat_sharded sync compiles to exactly one
    reduce_scatter + one all_gather per dtype bucket — carrying the integer
    codes at half the f32 wire bytes — plus at most ONE scalar-sized amax
    fold (4 bytes per model tensor); zero payload (bucket-sized)
    all-reduces, zero GSPMD per-element scale collectives.  On both the dp
    mesh and the fsdp pod-worker mesh, with and without outer momentum.
  * EXECUTION: the quantized trajectories of all three layouts, executed on
    the mesh for multiple perturb+sync rounds, are BITWISE equal to the
    mesh-less flat reference — the integer-code mean is order-independent,
    so no collective schedule can flip a bit (core/sync.py).
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _sync_compare(*extra):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.sync_compare",
         "--arch", "starcoder2-3b", *extra],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout)


def _assert_rs_domain(sh):
    """The collective budget of one quantized sharded sync — asserted
    through the shared rule registry (repro.analysis.rules): RS+AG per
    bucket with zero payload all-reduces and at most one scalar-sized
    amax fold (collective-budget), integer codes on every payload wire
    (wire-payload-dtype)."""
    for rule in ("collective-budget", "wire-payload-dtype"):
        verdict = sh["rules"][rule]
        assert verdict["applies"], f"rule {rule} did not apply"
        assert verdict["ok"], (rule, verdict["violations"])


def test_quantized_sharded_rs_domain_lowering_and_exec_dp():
    """Acceptance (dp 4x2 mesh): RS+AG with integer payloads + one amax
    psum, and bitwise execution equality of quantized sharded vs quantized
    flat (and tree)."""
    rec = _sync_compare("--mesh", "4x2", "--quantize", "--exec")
    sh, fl = rec["flat_sharded"], rec["flat"]
    _assert_rs_domain(sh)
    # integer wire: the RS/AG legs carry int16 codes — exactly half the f32
    # bytes the unquantized sharded sync moves on the same mesh
    plain = _sync_compare("--mesh", "4x2",
                          "--param-layout", "flat_sharded")["flat_sharded"]
    assert sh["rs_wire_bytes"] * 2 == plain["rs_wire_bytes"]
    assert sh["ag_wire_bytes"] * 2 == plain["ag_wire_bytes"]
    assert sh["rs_wire_bytes"] == sh["ag_wire_bytes"]
    # total quantized-sharded wire is well under half the flat quantized sync
    wire = sh["rs_wire_bytes"] + sh["ag_wire_bytes"] + sh["amax_fold_bytes"]
    assert wire * 2 <= fl["bytes_on_wire"]
    # the flat quantized sync, by contrast, pays bucket-sized all-reduces
    # (payload + the GSPMD scale max) — the cost the RS domain removes;
    # its (lower-bound) budget is the same registry rule
    assert fl["rules"]["collective-budget"]["ok"], \
        fl["rules"]["collective-budget"]["violations"]
    assert fl["payload_all_reduce_ops"] >= fl["n_buckets"]
    # EXECUTION: bitwise across layouts (the integer-code mean)
    ex = rec["exec"]
    assert ex["quantize"] is True
    for layout in ("tree", "flat", "flat_sharded"):
        assert ex[layout]["bitwise"], (layout, ex[layout])


def test_quantized_sharded_rs_domain_fsdp_pod_mesh():
    """Acceptance (fsdp 2x2x2 pod-worker mesh): same collective budget and
    bitwise execution with pods as workers and buckets chunked over
    (data, model)."""
    rec = _sync_compare("--mesh", "2x2x2", "--policy", "fsdp",
                        "--quantize", "--exec",
                        "--param-layout", "flat_sharded")
    sh = rec["flat_sharded"]
    _assert_rs_domain(sh)
    assert sh["scatter_leg_bytes"] > 0
    assert rec["exec"]["flat_sharded"]["bitwise"], rec["exec"]


def test_quantized_sharded_with_momentum_keeps_budget():
    """Outer Nesterov rides the apply leg elementwise: the collective
    budget must not grow."""
    rec = _sync_compare("--mesh", "4x2", "--quantize", "--momentum", "0.9",
                        "--param-layout", "flat_sharded")
    _assert_rs_domain(rec["flat_sharded"])


def test_unquantized_sharded_budget_unchanged():
    """Regression: the plain sharded sync still lowers to exactly one f32
    reduce_scatter + one all_gather per bucket, no fold, no all-reduce —
    the collective-budget rule with quantize=False allows zero folds."""
    rec = _sync_compare("--mesh", "4x2", "--param-layout", "flat_sharded")
    sh = rec["flat_sharded"]
    verdict = sh["rules"]["collective-budget"]
    assert verdict["applies"] and verdict["ok"], verdict["violations"]
    assert sh["rules_failed"] == []
