"""Fault-injection (chaos) suite — the ISSUE's end-to-end proof, as tests.

Each test drives the `run_elastic` controller: spawn 4 single-device
processes over a real jax.distributed coordinator, kill one mid-run with
`--chaos`, and assert the survivors' recovery is not merely "it didn't
crash": quantized partial sync makes the reduced-mesh CONSENSUS (params +
anchor) bitwise-reproducible by a single-process run of the same worker
count, and the rejoin generation must land within a tight norms tolerance
of its single-process reference (lane-local f32 math may drift by ulps
across process layouts; the sync itself stays integer-exact).

These carry their own `chaos` marker (not `multiproc`): they spawn up to
three multi-process generations plus reference runs back-to-back, far
heavier than the multiproc suite, and CI gives them their own job with a
recovery-telemetry artifact.  Locally: `pytest -m chaos tests/test_chaos.py`.
"""
import json

import pytest

from repro.launch import multihost

pytestmark = pytest.mark.chaos

_avail: dict = {}


def _require_multiproc():
    """Same probe the multiproc suite uses (kept local — test modules
    don't import each other): can this box actually run a 2-process
    jax.distributed job?"""
    if "ok" not in _avail:
        try:
            res = multihost.spawn_workers(
                2, total_devices=2, extra=("--mode", "probe"), timeout=300)
            _avail["ok"] = all(rc == 0 for rc, _, _ in res) and all(
                json.loads(so.strip().splitlines()[-1])["ok"]
                for _, so, _ in res)
            _avail["why"] = "" if _avail["ok"] else \
                "probe failed: " + (res[0][2] or res[0][1])[-500:]
        except Exception as e:
            _avail["ok"], _avail["why"] = False, repr(e)
    if not _avail["ok"]:
        pytest.skip(f"multi-process jax backend unavailable: {_avail['why']}")


def _check_common(tel, *, generations):
    assert tel["ok"], json.dumps(tel, indent=2)[:3000]
    gens = tel["generations"]
    assert len(gens) == generations
    g0 = gens[0]
    assert g0["detect_ok"], g0
    # the chaos victim died with the victim rc; survivors exited with the
    # membership-change verdict rc (not a crash) and an unanimous verdict
    assert g0["rcs"][2] == 7
    assert all(rc == 3 for i, rc in enumerate(g0["rcs"]) if i != 2)
    assert len(g0["verdicts"]) == 3
    assert all(v["missing"] == [2] and v["resume_round"] == 1
               for v in g0["verdicts"])
    return gens


def test_kill_mid_run_survivors_complete_on_reduced_mesh(tmp_path):
    """`--chaos kill:worker=2,round=1`: worker 2 dies before round 1's
    sync; the other three detect the missing heartbeat, exit cleanly, and
    a 3-worker generation finishes the run from the round-1 manifest —
    bitwise-equal to a single-process 3-lane run of the same remaining
    rounds (partial mean exact in the integer-code domain)."""
    _require_multiproc()
    tel = multihost.run_elastic(
        4, rounds=3, chaos="kill:worker=2,round=1",
        workdir=str(tmp_path / "kill"), heartbeat_timeout=15, timeout=900)
    gens = _check_common(tel, generations=2)
    g1 = gens[1]
    assert g1["lanes"] == 3
    assert all(rc == 0 for rc in g1["rcs"] + g1["reference_rcs"])
    assert g1["rounds_redone"] == 2
    # consensus (params + anchor) bitwise in the integer-code domain;
    # lane-local Adam moments within the norms tolerance
    assert g1["bitwise_vs_single_process"] and g1["shards_compared"], g1
    assert g1["moments_tolerance_ok"], g1


def test_preempt_restore_worker_rejoins_from_manifest(tmp_path):
    """`--chaos preempt-restore`: after the reduced-mesh generation
    completes, the full worker set rejoins from the manifest checkpoint
    (the returning lane re-anchored to consensus) and runs extra rounds —
    within the tolerance bound of a single-process reference (the restore
    itself is proven bitwise by the manifest matrix test)."""
    _require_multiproc()
    tel = multihost.run_elastic(
        4, rounds=3, chaos="preempt-restore",
        workdir=str(tmp_path / "pr"), heartbeat_timeout=15, timeout=1800)
    gens = _check_common(tel, generations=3)
    g2 = gens[2]
    assert g2["lanes"] == 4
    assert g2["rejoined_from"] == "manifest"
    assert all(rc == 0 for rc in g2["rcs"] + g2["reference_rcs"])
    # the rejoin leg's contract is the tolerance bound (a regrown worker
    # set compiles a different per-process XLA program; lane-local f32
    # math can drift by ulps across process layouts even though the sync
    # stays integer-exact)
    assert g2["tolerance_vs_single_process"] and g2["shards_compared"], g2
