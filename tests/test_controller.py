"""Adaptive controller (core/controller.py): pure decision invariants
(warmup pin, truncation, correction direction, batch ratchet, depth
frontier), the engine-side knobs (`batch_epoch` zero-recompile contract,
`set_overlap_depth` cache axis), the deterministic controller-trace
regression, and — under the `controller` marker (own CI job, excluded from
tier-1) — the fig2 QSR-vs-adaptive A/B gate."""
import json

import jax
import numpy as np
import pytest

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import engine as E
from repro.core import schedules
from repro.core.controller import (AdaptiveController, ControllerConfig,
                                   TRACE_SCHEMA, load_frontier)
from repro.optim.lr import make_lr_fn


def _run_cfg(**kw):
    base = dict(schedule="adaptive", optimizer="adamw", total_steps=24,
                peak_lr=3e-3, end_lr=1e-6, warmup_steps=2, h_base=2,
                alpha=0.001, remat=False, weight_decay=0.01)
    base.update(kw)
    return RunConfig(**base)


def _drive(ctrl, metrics_fn, total):
    """Walk the controller over a full run with fabricated telemetry."""
    t, rows = 0, []
    while t < total:
        h = ctrl.begin_round(t)
        ctrl.end_round(t, h, metrics_fn(t, h))
        rows.append((t, h))
        t += h
    return rows


def _flat_metrics(scale=1.0, run=None, lr_fn=None):
    """Telemetry with constant drift intensity kappa: divergence follows
    the SDE scaling kappa * eta * sqrt(h) exactly (eta folded in when a
    schedule is given), so the controller's feedback sees a steady
    signal."""
    def eta(t):
        return (lr_fn(max(t, run.warmup_steps))
                if run is not None and lr_fn is not None else 1.0)
    return lambda t, h: {"loss": 5.0 - 0.01 * t, "grad_norm": 1.0,
                         "divergence": scale * 0.01 * eta(t) * np.sqrt(h)}


# ------------------------------------------------------ pure H decisions --

def test_adaptive_prior_is_qsr():
    """Open-loop, "adaptive" IS the quadratic rule: get_h agrees with kind
    qsr at every step, so every SCHEDULE_KINDS-parametrized invariant
    (partition, warmup pin) transfers for free."""
    ra = _run_cfg(total_steps=500, warmup_steps=50)
    rq = _run_cfg(schedule="qsr", total_steps=500, warmup_steps=50)
    lr = make_lr_fn(ra)
    for t in range(0, 500, 7):
        assert schedules.get_h(ra, t, lr) == schedules.get_h(rq, t, lr)


def test_controller_partitions_and_pins_warmup():
    run = _run_cfg(total_steps=400, warmup_steps=80, h_base=3)
    lr = make_lr_fn(run)
    ctrl = AdaptiveController(run, lr)
    rows = _drive(ctrl, _flat_metrics(), run.total_steps)
    assert sum(h for _, h in rows) == run.total_steps
    assert all(h >= 1 for _, h in rows)
    pinned = schedules.get_h(run, run.warmup_steps, lr)
    for t, h in rows:
        if t + h <= run.warmup_steps:
            assert h == pinned, (t, h)
        rec = next(r for r in ctrl.trace if r["t"] == t)
        if t < run.warmup_steps:
            assert "warmup-pin" in rec["reasons"]
            assert rec["h_correction"] == 1.0


def test_controller_rejects_non_adaptive_run_cfg():
    run = _run_cfg(schedule="qsr")
    with pytest.raises(ValueError):
        AdaptiveController(run, make_lr_fn(run))


def test_round_boundary_pairing_enforced():
    run = _run_cfg()
    ctrl = AdaptiveController(run, make_lr_fn(run))
    with pytest.raises(RuntimeError):
        ctrl.end_round(0, 2, {"loss": 1.0, "divergence": 0.1})
    ctrl.begin_round(0)
    with pytest.raises(RuntimeError):   # mid-round re-decision is illegal
        ctrl.begin_round(0)


def test_divergence_correction_direction():
    """Hot divergence (vs its own trend) shrinks H below the prior; a cool
    stretch extends it — and the correction stays inside the clip bounds."""
    run = _run_cfg(total_steps=4000, warmup_steps=100, h_base=1,
                   alpha=0.05)   # prior >> h_base so shrink is visible
    lr = make_lr_fn(run)

    def run_with(late_scale):
        ctrl = AdaptiveController(run, lr)
        flat = _flat_metrics(run=run, lr_fn=lr)
        shifted = _flat_metrics(late_scale, run=run, lr_fn=lr)
        t = 0
        while t < run.total_steps:
            h = ctrl.begin_round(t)
            m = (flat if t <= run.total_steps // 2 else shifted)(t, h)
            ctrl.end_round(t, h, m)
            t += h
        return ctrl

    lo, hi = ControllerConfig().h_correction_bounds
    mid = run.total_steps // 2
    # the correction bites while the fast EMA has moved off the trend —
    # rounds deciding on post-switch telemetry; once both EMAs converge to
    # the new level the ratio returns to ~1 (the trend recalibrates)
    window = lambda c: [r for r in c.trace if r["t"] > mid]
    hot = run_with(8.0)
    assert any(r["h_correction"] < 1.0 for r in window(hot))
    cool = run_with(1.0 / 8.0)
    assert any(r["h_correction"] > 1.0 for r in window(cool))
    for ctrl in (hot, cool):
        assert all(lo <= r["h_correction"] <= hi for r in ctrl.trace)
        for r in ctrl.trace:     # floor + truncation hold under correction
            assert r["h"] >= 1
            assert r["t"] + r["h"] <= run.total_steps


def test_steady_run_stays_near_prior():
    """The trend-tracking reference means a smooth run barely deviates from
    the QSR prior — the controller refines the rule, it does not fight it."""
    run = _run_cfg(total_steps=2000, warmup_steps=100, alpha=0.02)
    lr = make_lr_fn(run)
    ctrl = AdaptiveController(run, lr)
    _drive(ctrl, _flat_metrics(run=run, lr_fn=lr), run.total_steps)
    for r in ctrl.trace:
        assert 0.5 <= r["h_correction"] <= 2.0, r


# ----------------------------------------------------------- batch knob ---

class _StubEngine:
    """The three attributes/methods the controller drives, no XLA."""

    def __init__(self, b_loc=8, sync_mode="blocking", adaptive_batch=True):
        self.b_loc, self.sync_mode = b_loc, sync_mode
        self.adaptive_batch = adaptive_batch
        self.batch_lanes = b_loc
        self.overlap_depth = 0
        self.calls = []

    def batch_epoch(self, lanes):
        self.calls.append(("batch", lanes))
        self.batch_lanes = lanes

    def set_overlap_depth(self, depth):
        self.calls.append(("depth", depth))
        self.overlap_depth = depth


def test_batch_ratchet_monotone_divisors():
    run = _run_cfg(total_steps=3000, warmup_steps=100, alpha=0.02)
    eng = _StubEngine(b_loc=8)
    ctrl = AdaptiveController(run, make_lr_fn(run), engine=eng)
    # loss plateaus after warmup -> improvement EMA decays -> batch grows
    _drive(ctrl, lambda t, h: {
        "loss": 5.0 - min(0.002 * t, 0.5), "grad_norm": 1.0,
        "divergence": 0.01 * np.sqrt(h)}, run.total_steps)
    lanes = [r["batch_lanes"] for r in ctrl.trace]
    assert lanes == sorted(lanes), "batch is a ratchet — never shrinks"
    assert lanes[0] == 4          # b_loc / batch_start_div
    assert lanes[-1] == 8         # grew to the allocated batch
    assert all(8 % l == 0 for l in lanes)
    assert ("batch", 8) in eng.calls
    assert any("batch-grow" in r["reasons"] for r in ctrl.trace)


# ----------------------------------------------------------- depth knob ---

def test_depth_rides_frontier_within_staleness_budget():
    run = _run_cfg(total_steps=3000, warmup_steps=100, alpha=0.02)
    frontier = {0: 1.0, 1: 0.6, 2: 0.5}   # deeper overlap is faster
    lr = make_lr_fn(run)
    eng = _StubEngine(sync_mode="overlap", adaptive_batch=False)
    ctrl = AdaptiveController(run, lr, engine=eng, frontier=frontier)
    flat = _flat_metrics(run=run, lr_fn=lr)
    hot = _flat_metrics(8.0, run=run, lr_fn=lr)   # drift above trend
    mid, t = run.total_steps // 2, 0
    while t < run.total_steps:
        h = ctrl.begin_round(t)
        ctrl.end_round(t, h, (flat if t <= mid else hot)(t, h))
        t += h
    # depth holds at 0 until the feedback signals exist
    assert ctrl.trace[0]["overlap_depth"] == 0
    assert "depth-hold-calibrating" in ctrl.trace[0]["reasons"]
    # steady drift on long rounds: the fastest frontier depth is affordable
    steady = [r for r in ctrl.trace if 0 < r["t"] <= mid]
    assert any(r["overlap_depth"] == 2 for r in steady)
    assert ("depth", 2) in eng.calls
    # drift jumps above its own trend -> the staleness budget retreats
    after = [r for r in ctrl.trace if r["t"] > mid]
    assert any(r["overlap_depth"] == 0 for r in after)
    # a short truncated final round can never afford staleness
    assert ctrl.trace[-1]["h"] > 16 or ctrl.trace[-1]["overlap_depth"] == 0


def test_load_frontier_table4_and_plain():
    recs = {"overlap": {"blocking_d0": {"s_per_round": 2.8},
                        "overlap_d1": {"s_per_round": 2.1},
                        "overlap_d1_ring": {"s_per_round": 9.9}}}
    assert load_frontier(recs) == {0: 2.8, 1: 2.1}
    assert load_frontier({"0": 1.0, "2": 0.5}) == {0: 1.0, 2: 0.5}
    assert load_frontier("/nonexistent/path.json") is None


# ------------------------------------------------- engine integration -----

def _engine(run, layout="tree", sync="blocking", **kw):
    cfg = R.get_smoke_config("starcoder2-3b")
    shards = {"flat_sharded": 2}.get(layout, 0)
    return E.RoundEngine(cfg, run, workers=2, b_loc=4, seq=16,
                         mode="bucketed", data="device", layout=layout,
                         sync=sync, shards=shards, adaptive_batch=True, **kw)


def _adaptive_run(layout="tree", sync="blocking", **ctrl_kw):
    run = _run_cfg()
    eng = _engine(run, layout=layout, sync=sync)
    lr_fn = make_lr_fn(run)
    ctrl = AdaptiveController(run, lr_fn, engine=eng, **ctrl_kw)
    state, t = eng.init_state(), 0
    while t < run.total_steps:
        h = ctrl.begin_round(t)
        state, m = eng.run_round(state, t, h, lr_fn)
        ctrl.end_round(t, h, m)
        t += h
    state = eng.flush(state)
    return eng, ctrl, state


@pytest.mark.parametrize("layout", ["tree", "flat", "flat_sharded"])
def test_adaptive_zero_recompiles_beyond_bucket_set(layout):
    """THE acceptance criterion: an adaptive run — batch epochs included —
    compiles exactly one program per visited power-of-two H bucket, the
    same budget a non-adaptive run pays.  The lane count is a traced
    argument, never a cache key."""
    eng, ctrl, _ = _adaptive_run(layout=layout)
    buckets = {E.bucket_pow2(h) for _, h in eng.h_trace}
    assert eng.compiles == len(buckets), (eng.compile_stats(), eng.h_trace)
    assert eng.batch_epochs, "the controller should have moved the batch"
    assert sum(h for _, h in eng.h_trace) == eng.run_cfg.total_steps


def test_batch_epochs_land_on_round_boundaries():
    eng, ctrl, _ = _adaptive_run()
    n_rounds = len(eng.h_trace)
    for ep in eng.batch_epochs:
        assert 0 <= ep.round_index <= n_rounds
        assert ep.b_loc % ep.lanes == 0
    # trace rows mirror the engine's audit trail
    assert [r["batch_lanes"] for r in ctrl.trace][0] == \
        eng.batch_epochs[0].lanes


def test_full_lane_adaptive_is_bitwise_plain():
    """With lanes == b_loc the gather index is the identity: an adaptive
    engine pinned at full batch is bitwise the plain engine."""
    cfg = R.get_smoke_config("starcoder2-3b")
    run = _run_cfg(schedule="qsr")
    lr_fn = make_lr_fn(run)
    ea = E.RoundEngine(cfg, run, workers=2, b_loc=4, seq=16,
                       mode="bucketed", data="device", adaptive_batch=True)
    ep = E.RoundEngine(cfg, run, workers=2, b_loc=4, seq=16,
                       mode="bucketed", data="device")
    sa, sp = ea.init_state(), ep.init_state()
    for t, h in schedules.rounds(run, lr_fn):
        sa, _ = ea.run_round(sa, t, h, lr_fn)
        sp, _ = ep.run_round(sp, t, h, lr_fn)
    for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sp)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_batch_epoch_validation():
    run = _run_cfg()
    eng = _engine(run)
    for bad in (0, 3, 5, 8):
        with pytest.raises(E.MembershipError):
            eng.batch_epoch(bad)
    plain = E.RoundEngine(R.get_smoke_config("starcoder2-3b"), run,
                          workers=2, b_loc=4, seq=16, mode="bucketed",
                          data="device")
    with pytest.raises(E.MembershipError):
        plain.batch_epoch(2)
    with pytest.raises(E.MembershipError):
        plain.set_overlap_depth(1)   # blocking engines have no depth knob


def test_overlap_depth_is_a_cache_axis():
    """Depth changes compile at most one program per (bucket, depth) and
    revisiting a depth is a cache hit."""
    run = _run_cfg(total_steps=16, h_base=4, schedule="constant")
    cfg = R.get_smoke_config("starcoder2-3b")
    eng = E.RoundEngine(cfg, run, workers=2, b_loc=4, seq=16,
                        mode="bucketed", data="device", sync="overlap",
                        overlap_depth=1)
    lr_fn = make_lr_fn(run)
    state = eng.init_state()
    state, _ = eng.run_round(state, 0, 4, lr_fn)     # depth 1, no pending
    eng.set_overlap_depth(2)
    state, _ = eng.run_round(state, 4, 4, lr_fn)     # depth 2 + pending
    eng.set_overlap_depth(1)
    state, _ = eng.run_round(state, 8, 4, lr_fn)     # depth 1 + pending
    c = eng.compiles
    eng.set_overlap_depth(2)
    state, _ = eng.run_round(state, 12, 4, lr_fn)    # revisit: cache hit
    assert eng.compiles == c and eng.cache_hits >= 1
    eng.flush(state)


# --------------------------------------------------- trace regression -----

def test_controller_trace_deterministic_regression():
    """Same seed, same config -> byte-identical trace JSON, and the record
    carries the v1 schema with per-round decisions + measured telemetry."""
    a = _adaptive_run()[1].trace_record()
    b = _adaptive_run()[1].trace_record()
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    assert a["schema"] == TRACE_SCHEMA
    assert a["summary"]["steps"] == 24
    assert a["summary"]["n_rounds"] == len(a["rounds"])
    for row in a["rounds"]:
        assert {"t", "h", "h_prior", "h_correction", "batch_lanes",
                "overlap_depth", "lr", "signals", "reasons",
                "measured"} <= set(row)
        assert np.isfinite(row["measured"]["loss"])


def test_train_driver_writes_trace(tmp_path):
    from repro.launch.train import train
    cfg = R.get_smoke_config("starcoder2-3b")
    run = _run_cfg()
    path = str(tmp_path / "controller_trace.json")
    train(cfg, run, workers=2, b_loc=4, seq=16, log_every=0,
          controller_trace=path)
    with open(path) as f:
        rec = json.load(f)
    assert rec["schema"] == TRACE_SCHEMA
    assert rec["summary"]["steps"] == run.total_steps


# ------------------------------------------------------- CI A/B smoke -----

@pytest.mark.controller
def test_fig2_ab_gate(tmp_path):
    """The CI `controller` job's gate: adaptive matches or beats QSR's
    held-out accuracy within noise while emitting a parseable trace.
    REPRO_CONTROLLER_ARTIFACTS names a directory to drop the trace +
    verdict into (the CI job uploads it); defaults to the test tmpdir."""
    import os
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]
                           / "benchmarks"))
    import fig2_generalization as fig2
    art = os.environ.get("REPRO_CONTROLLER_ARTIFACTS")
    outdir = pathlib.Path(art) if art else tmp_path
    outdir.mkdir(parents=True, exist_ok=True)
    verdict = fig2.run_ab(
        steps=300,   # the benchmark's native horizon (fig2 run() default)
        trace_path=str(outdir / "controller_trace.json"),
        out_path=str(outdir / "fig2_ab_verdict.json"))
    assert verdict["ok"]
    with open(outdir / "controller_trace.json") as f:
        assert json.load(f)["schema"] == TRACE_SCHEMA
