"""The paper's own setting, miniaturized: ViT + Local AdamW with QSR vs the
data-parallel baseline on a noisy-teacher vision task (stand-in for
ImageNet), K=8 workers.

  PYTHONPATH=src python examples/vit_local_adamw.py [--steps 300]

Reproduces the qualitative Table 1(b) result at laptop scale: QSR trains
with a fraction of the communication while matching or beating the
data-parallel baseline's held-out accuracy.
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import local_update as LU
from repro.core import schedules
from repro.data.synthetic import VisionStream
from repro.models import api, param as pm
from repro.optim.lr import make_lr_fn


def run_one(schedule: str, steps: int, k=8, b_loc=8, seed=0):
    cfg = dataclasses.replace(R.get_smoke_config("vit-b16"), n_classes=16)
    run = RunConfig(schedule=schedule, optimizer="adamw", total_steps=steps,
                    peak_lr=6e-3, end_lr=1e-5, warmup_steps=steps // 10,
                    h_base=2, alpha=3.5e-3, weight_decay=0.01, remat=False)
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(seed))
    state = LU.init_state(cfg, run, params, k)
    lr_fn = make_lr_fn(run)
    stream = VisionStream(n_classes=cfg.n_classes, seed=42)
    round_fn = jax.jit(LU.make_train_round(cfg, run))

    t, n_rounds = 0, 0
    while t < steps:
        h = schedules.get_h(run, t, lr_fn)
        imgs, labels = [], []
        for i in range(h):
            xs, ys = zip(*[stream.batch(t + i, w, b_loc) for w in range(k)])
            imgs.append(jnp.stack(xs)); labels.append(jnp.stack(ys))
        batch = {"images": jnp.stack(imgs), "labels": jnp.stack(labels)}
        lrs = jnp.asarray([lr_fn(t + i) for i in range(h)], jnp.float32)
        state, loss = round_fn(state, batch, lrs)
        t += h
        n_rounds += 1

    final = jax.tree.map(lambda x: x[0], state["params"])
    acc_fn = jax.jit(lambda p, b: mod.accuracy(cfg, p, b))
    accs = []
    for i in range(8):
        xs, ys = stream.batch(50_000 + i, 0, 64, noisy=False)
        accs.append(float(acc_fn(final, {"images": xs, "labels": ys})))
    return float(np.mean(accs)), n_rounds / steps, float(loss)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=240)
    args = ap.parse_args()
    print(f"{'method':12s} {'heldout acc':>12s} {'comm volume':>12s} "
          f"{'final loss':>11s}")
    for sched in ("parallel", "constant", "qsr"):
        acc, comm, loss = run_one(sched, args.steps)
        print(f"{sched:12s} {acc:12.3f} {comm:12.1%} {loss:11.3f}")


if __name__ == "__main__":
    main()
