"""The paper's own setting, miniaturized: ViT + Local AdamW with QSR vs the
data-parallel baseline on a noisy-teacher vision task (stand-in for
ImageNet), K=8 workers.

  PYTHONPATH=src python examples/vit_local_adamw.py [--steps 300]
      [--param-layout flat]

Reproduces the qualitative Table 1(b) result at laptop scale: QSR trains
with a fraction of the communication while matching or beating the
data-parallel baseline's held-out accuracy.

Runs through `RoundEngine` (core/engine.py): the VisionStream plugs in as a
host-data `batch_fn`, the engine owns the power-of-two bucketed compile
cache (no per-H jit), and `--param-layout flat` runs the same trajectory —
bitwise — over FlatParamSpace dtype buckets.
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import schedules
from repro.core.engine import RoundEngine
from repro.data.synthetic import VisionStream
from repro.models import api, param as pm
from repro.optim.lr import make_lr_fn


def run_one(schedule: str, steps: int, k=8, b_loc=8, seed=0, layout="tree"):
    cfg = dataclasses.replace(R.get_smoke_config("vit-b16"), n_classes=16)
    run = RunConfig(schedule=schedule, optimizer="adamw", total_steps=steps,
                    peak_lr=6e-3, end_lr=1e-5, warmup_steps=steps // 10,
                    h_base=2, alpha=3.5e-3, weight_decay=0.01, remat=False)
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(seed))
    lr_fn = make_lr_fn(run)
    stream = VisionStream(n_classes=cfg.n_classes, seed=42)

    def batch_fn(step):
        xs, ys = zip(*[stream.batch(step, w, b_loc) for w in range(k)])
        return {"images": jnp.stack(xs), "labels": jnp.stack(ys)}

    eng = RoundEngine(cfg, run, workers=k, b_loc=b_loc, seq=1, seed=seed,
                      data="host", batch_fn=batch_fn, layout=layout)
    state = eng.init_state(params)
    t, loss = 0, float("nan")
    while t < run.total_steps:
        h = schedules.get_h(run, t, lr_fn)
        state, m = eng.run_round(state, t, h, lr_fn)
        t += h
        loss = float(m["loss"])

    final = eng.params_single(state)
    acc_fn = jax.jit(lambda p, b: mod.accuracy(cfg, p, b))
    accs = []
    for i in range(8):
        xs, ys = stream.batch(50_000 + i, 0, 64, noisy=False)
        accs.append(float(acc_fn(final, {"images": xs, "labels": ys})))
    return float(np.mean(accs)), len(eng.h_trace) / steps, loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--param-layout", default="tree",
                    choices=["tree", "flat"])
    args = ap.parse_args()
    print(f"{'method':12s} {'heldout acc':>12s} {'comm volume':>12s} "
          f"{'final loss':>11s}")
    for sched in ("parallel", "constant", "qsr"):
        acc, comm, loss = run_one(sched, args.steps,
                                  layout=args.param_layout)
        print(f"{sched:12s} {acc:12.3f} {comm:12.1%} {loss:11.3f}")


if __name__ == "__main__":
    main()
