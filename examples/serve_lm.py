"""Serve a small model with batched requests: prefill + decode, including
the sliding-window ring cache used by the long_500k dry-run shape, then the
continuous-batching service loop with a hot weight swap mid-sequence
(requests keep decoding while new weights are published and swapped in
between decode steps — every emitted token stamped with its swap epoch).

  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.launch.serve import generate
from repro.models import api, param as pm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = R.get_smoke_config(args.arch)
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)

    # full-cache serving
    t0 = time.time()
    full = generate(cfg, params, prompts, gen_len=args.gen)
    t_full = time.time() - t0
    print(f"full cache   : {args.batch}x{args.gen} tokens in {t_full:.2f}s")

    # ring-buffer window serving (the long-context mode) — identical results
    # whenever the window covers the live context
    t0 = time.time()
    ring = generate(cfg, params, prompts, gen_len=args.gen,
                    max_len=args.prompt_len + args.gen,
                    window_override=args.prompt_len + args.gen // 2)
    t_ring = time.time() - t0
    same = bool(np.array_equal(np.asarray(full), np.asarray(ring)))
    print(f"ring window  : {args.batch}x{args.gen} tokens in {t_ring:.2f}s "
          f"(matches full-cache within window: {same})")
    print("sample:", np.asarray(full[0, args.prompt_len:]).tolist())

    # --- continuous batching + hot weight swap --------------------------
    # Three requests over two decode slots; after a few steps a "trainer"
    # publishes fresh weights which the batcher swaps in between decode
    # steps.  In-flight sequences are refreshed (replayed under the new
    # weights), so their remaining tokens are bitwise what a server
    # restarted from that checkpoint would emit.
    from repro.launch.batching import ContinuousBatcher, Request
    from repro.launch.weights import ServingWeights, WeightSubscriber

    fresh = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(17))
    sub = WeightSubscriber()
    batcher = ContinuousBatcher(cfg, ServingWeights(cfg, params),
                                slots=2, max_len=args.prompt_len + args.gen,
                                subscriber=sub)
    reqs = [Request(rid=i, prompt=np.asarray(prompts[i % args.batch]),
                    max_new=args.gen) for i in range(3)]
    for r in reqs:
        batcher.submit(r)
    t0 = time.time()
    steps = 0
    while batcher.step() or batcher.queue:
        steps += 1
        if steps == args.prompt_len + 4:   # mid-sequence: publish new weights
            sub.publish(1, fresh)
    t_srv = time.time() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"service loop : {toks} tokens over {steps} steps in {t_srv:.2f}s, "
          f"swaps={batcher.swaps}")
    for r in reqs:
        pre = sum(1 for e in r.epochs if e == 0)
        print(f"  rid={r.rid}: {pre} tokens from checkpoint step 0, "
              f"{len(r.out) - pre} from step {batcher.weights.step}")


if __name__ == "__main__":
    main()
