"""Paper Fig. 5: visualize the H schedule of QSR vs constant H over a cosine
learning-rate decay (ASCII, no matplotlib).

  PYTHONPATH=src python examples/h_schedule_viz.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import RunConfig
from repro.core import schedules
from repro.optim.lr import make_lr_fn

IMAGENET = 1_281_167


def main():
    # the paper's ViT-B recipe: cosine peak 0.008, B=4096, 300 epochs
    steps = round(IMAGENET / 4096 * 300)
    run = RunConfig(schedule="qsr", total_steps=steps, peak_lr=0.008,
                    end_lr=1e-6, warmup_steps=10_000, h_base=4, alpha=0.0175)
    lr = make_lr_fn(run)
    trace = schedules.h_trace(run, lr)

    print(f"QSR H-schedule, ViT-B recipe (alpha=0.0175, H_base=4), "
          f"T={steps} steps\n")
    width = 60
    h_max = max(h for _, h in trace)
    # sample ~30 rounds evenly through the run
    shown = trace[:: max(len(trace) // 30, 1)]
    print(f"{'step':>8s} {'lr':>9s} {'H':>6s}")
    for t, h in shown:
        bar = "#" * max(1, int(width * h / h_max))
        print(f"{t:8d} {lr(t):9.5f} {h:6d} |{bar}")
    comm = len(trace) / steps
    print(f"\nrounds: {len(trace)}  comm volume vs data-parallel: {comm:.1%}"
          f"  (constant H=4 would be 25.0%)")


if __name__ == "__main__":
    main()
