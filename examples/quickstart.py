"""Quickstart: train a small LM with Local AdamW + QSR on CPU, end to end.

  PYTHONPATH=src python examples/quickstart.py

What it shows:
  * the local-gradient runtime (K=4 workers, explicit worker axis),
  * the Quadratic Synchronization Rule growing H as the cosine lr decays,
  * communication volume vs data-parallel printed at the end.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.launch.train import train
from repro.optim.lr import make_lr_fn
from repro.core import schedules


def main():
    cfg = R.get_smoke_config("starcoder2-3b")
    run = RunConfig(
        schedule="qsr", optimizer="adamw",
        total_steps=120, warmup_steps=12,
        peak_lr=3e-3, end_lr=1e-5, lr_schedule="cosine",
        h_base=2, alpha=0.0012,       # QSR: H = max(2, (alpha/eta)^2)
        weight_decay=0.01, remat=False)

    print("H-schedule this run will follow:")
    lr_fn = make_lr_fn(run)
    for t, h in schedules.rounds(run, lr_fn):
        print(f"  round at step {t:4d}: lr {lr_fn(t):.5f} -> H = {h}")

    state, hist = train(cfg, run, workers=4, b_loc=8, seq=64, log_every=4)
    losses = [l for _, _, l, _ in hist]
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"{len(hist)} syncs for {run.total_steps} steps "
          f"= {len(hist)/run.total_steps:.0%} of data-parallel comm volume")


if __name__ == "__main__":
    main()
