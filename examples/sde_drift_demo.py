"""Visualize Theorem 3.1: the K-times-faster sharpness drift of QSR on the
minimizer-manifold toy problem (ASCII plot, no matplotlib needed).

  PYTHONPATH=src python examples/sde_drift_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from benchmarks.sde_drift import simulate


def main():
    k = 8
    print(f"Sharpness-reduction drift, K={k} workers "
          f"(Defs 3.1-3.3; higher = flatter faster)\n")
    rates = {}
    for sched in ("parallel", "inverse", "qsr"):
        rates[sched] = simulate(sched, k=k, steps=60_000)
    peak = max(rates.values())
    for sched, r in rates.items():
        bar = "#" * int(48 * r / peak)
        print(f"  {sched:9s} |{bar:<48s}| {r:.3f}")
    print(f"\n  QSR / parallel = {rates['qsr']/rates['parallel']:.2f}x "
          f"(theory predicts ~K = {k}x)")
    print("  ordering QSR > eta^-1 > parallel == the paper's Fig. 2 ordering")


if __name__ == "__main__":
    main()
