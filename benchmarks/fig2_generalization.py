"""Paper Fig. 2 / Table 5 (laptop scale): generalization of QSR vs the
baseline schedules on a tiny ViT + noisy-teacher vision task (K=8 Local SGD
workers, cosine decay), measuring held-out accuracy + a sharpness proxy.

Expected outcome per the PAPER itself: at small model/horizon scale, "QSR
may not yield noticeable generalization improvements" (Table 5, ResNet-50 @
90 epochs shows parity) — and that is what we observe: QSR matches the best
baseline within noise while communicating a fraction as much.  The
quantitative validation of the generalization *mechanism* (the K-times
Slow-SDE drift of Thm 3.1) is benchmarks/sde_drift.py, which does separate
cleanly.

`--ab` runs the head-to-head the CI `controller` job gates: QSR (open-loop
quadratic rule) vs `--schedule adaptive` (core/controller.py closing the
loop on the same telemetry), same seed and horizon.  The adaptive run must
match or beat QSR's held-out accuracy within noise while emitting a
parseable controller_trace.json; the verdict JSON is the job's artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry as R
from repro.configs.base import RunConfig
from repro.core import schedules
from repro.core.engine import RoundEngine
from repro.data.synthetic import VisionStream
from repro.models import api, param as pm
from repro.optim.lr import make_lr_fn


def train_one(schedule: str, *, steps=300, k=8, b_loc=8, seed=0,
              alpha=0.02, beta=0.6, peak_lr=0.12, trace_path=None,
              ctrl_cfg=None):
    cfg = dataclasses.replace(R.get_smoke_config("vit-b16"), n_classes=16)
    run = RunConfig(schedule=schedule, optimizer="sgd", total_steps=steps,
                    peak_lr=peak_lr, end_lr=1e-4, warmup_steps=steps // 10,
                    h_base=2, alpha=alpha, beta=beta, remat=False,
                    weight_decay=0.0)
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(seed))
    lr_fn = make_lr_fn(run)
    stream = VisionStream(n_classes=cfg.n_classes, seed=123)

    def batch_fn(step):
        xs, ys = zip(*[stream.batch(step, w, b_loc) for w in range(k)])
        return {"images": jnp.stack(xs), "labels": jnp.stack(ys)}

    # RoundEngine owns the compile cache (one program per power-of-two H
    # bucket instead of one jit per distinct H) and the round loop unit.
    adaptive = schedule == "adaptive"
    eng = RoundEngine(cfg, run, workers=k, b_loc=b_loc, seq=1, seed=seed,
                      data="host", batch_fn=batch_fn,
                      adaptive_batch=adaptive)
    ctrl = None
    if adaptive:
        from repro.core.controller import AdaptiveController
        ctrl = AdaptiveController(run, lr_fn, engine=eng, cfg=ctrl_cfg)
    state = eng.init_state(params)
    t = 0
    while t < steps:
        h = (ctrl.begin_round(t) if ctrl is not None
             else schedules.get_h(run, t, lr_fn))
        state, m = eng.run_round(state, t, h, lr_fn)
        if ctrl is not None:
            ctrl.end_round(t, h, m)
        t += h
    if ctrl is not None and trace_path:
        ctrl.write_trace(trace_path)

    final = eng.params_single(state)
    # held-out accuracy (clean labels, unseen steps)
    accs, sharps = [], []
    loss_fn = jax.jit(lambda p, b: mod.loss_fn(cfg, p, b, remat=False))
    acc_fn = jax.jit(lambda p, b: mod.accuracy(cfg, p, b))
    key = jax.random.PRNGKey(999)
    for i in range(8):
        xs, ys = stream.batch(10_000 + i, 0, 64, noisy=False)
        b = {"images": xs, "labels": ys}
        accs.append(float(acc_fn(final, b)))
        # sharpness proxy: loss increase under random parameter perturbation
        base = float(loss_fn(final, b))
        key, sub = jax.random.split(key)
        leaves, td = jax.tree.flatten(final)
        ks = jax.random.split(sub, len(leaves))
        pert = jax.tree.unflatten(td, [
            l + 0.01 * jnp.linalg.norm(l.reshape(-1)) /
            np.sqrt(l.size) * jax.random.normal(kk, l.shape)
            for l, kk in zip(leaves, ks)])
        sharps.append(float(loss_fn(pert, b)) - base)
    return float(np.mean(accs)), float(np.mean(sharps))


def run(csv_rows: list | None = None, *, steps=300) -> None:
    print("\n== Fig. 2 (laptop scale): generalization ordering ==")
    results = {}
    for sched in ("parallel", "constant", "inverse", "qsr"):
        acc, sharp = train_one(sched, steps=steps)
        results[sched] = (acc, sharp)
        print(f"  {sched:10s} held-out acc {acc:6.3f}  sharpness proxy "
              f"{sharp:+.4f}")
        if csv_rows is not None:
            csv_rows.append((f"fig2/{sched}/heldout_acc", "", f"{acc:.4f}"))
    ok = results["qsr"][0] >= results["parallel"][0] - 0.02
    print(f"  QSR matches/beats parallel within noise: {ok} — consistent"
          f" with Table 5 (no noticeable gain at small scale) while using"
          f" far less communication; the Thm 3.1 mechanism is validated"
          f" quantitatively by sde_drift.py")
    assert ok


def run_ab(*, steps=300, trace_path="controller_trace.json",
           out_path="fig2_ab_verdict.json") -> dict:
    """QSR vs adaptive head-to-head (the CI `controller` gate): same seed,
    same horizon; adaptive must match or beat QSR's held-out accuracy
    within the same 0.02 noise band `run()` grants QSR over parallel, AND
    its controller trace must parse against schema controller_trace/v1.
    Writes the verdict JSON and returns it; asserts the gate."""
    print("\n== Fig. 2 A/B: QSR (open-loop) vs adaptive (closed-loop) ==")
    qsr_acc, qsr_sharp = train_one("qsr", steps=steps)
    ada_acc, ada_sharp = train_one("adaptive", steps=steps,
                                   trace_path=trace_path)
    with open(trace_path) as f:
        trace = json.load(f)
    from repro.core.controller import TRACE_SCHEMA
    assert trace["schema"] == TRACE_SCHEMA, trace["schema"]
    assert trace["summary"]["steps"] == steps, trace["summary"]
    ok = ada_acc >= qsr_acc - 0.02
    verdict = {
        "schema": "fig2_ab_verdict/v1",
        "steps": steps,
        "qsr": {"heldout_acc": round(qsr_acc, 4),
                "sharpness": round(qsr_sharp, 4)},
        "adaptive": {"heldout_acc": round(ada_acc, 4),
                     "sharpness": round(ada_sharp, 4),
                     "n_rounds": trace["summary"]["n_rounds"],
                     "h_range": [trace["summary"]["h_min"],
                                 trace["summary"]["h_max"]],
                     "final_batch_lanes":
                         trace["summary"]["final_batch_lanes"],
                     "comm_fraction": trace["summary"]["comm_fraction"]},
        "gate": "adaptive_acc >= qsr_acc - 0.02",
        "ok": bool(ok),
    }
    with open(out_path, "w") as f:
        json.dump(verdict, f, indent=1)
    print(f"  qsr      acc {qsr_acc:6.3f}  sharp {qsr_sharp:+.4f}")
    print(f"  adaptive acc {ada_acc:6.3f}  sharp {ada_sharp:+.4f}  "
          f"({trace['summary']['n_rounds']} rounds, final lanes "
          f"{trace['summary']['final_batch_lanes']})")
    print(f"  adaptive matches/beats QSR within noise: {ok} -> {out_path}")
    assert ok, verdict
    return verdict


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--ab", action="store_true",
                    help="QSR vs adaptive A/B (the CI controller gate) "
                         "instead of the full baseline sweep")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--trace", default="controller_trace.json")
    ap.add_argument("--out", default="fig2_ab_verdict.json")
    args = ap.parse_args()
    if args.ab:
        run_ab(steps=args.steps, trace_path=args.trace, out_path=args.out)
    else:
        run(steps=args.steps)
