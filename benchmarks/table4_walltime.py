"""Paper Table 4 + Appendix F: the wall-clock / communication-time model.

Part 1 — validate the paper's own methodology (App. F eqs. 27-31) against
Table 4's published measurements: from (T_para_tot, T_H1_tot) derive comm and
compute times, then PREDICT T_H2_tot and the QSR totals, and compare with
what the paper measured.  (The paper reports ~1% relative error for this
model; we reproduce its arithmetic exactly.)

Part 2 — apply the same model to OUR target hardware: per-step compute and
comm times from the dry-run roofline terms (benchmarks/roofline.py), giving
projected v5e wall-clock savings for QSR per architecture.

Part 3 — the compile-cost column: wall-clock also pays one XLA compile per
distinct round program.  The legacy runtime jits one `train_round` per
distinct H the schedule visits; the RoundEngine's power-of-two bucketing
(core/engine.py) compiles at most ceil(log2(H_max)) + 1 programs.  This
section reports both counts per Table 4 recipe.
"""
from __future__ import annotations

from repro.configs.base import RunConfig
from repro.core import schedules
from repro.optim.lr import make_lr_fn

# Table 4 published totals (hours): (T_parallel, T_{H1}, H1, T_{H2}, H2,
#                                    QSR totals {h_base: (hours, f_comm)})
TABLE4 = {
    "ResNet152/2x8": dict(t_para=20.7, t_h1=19.0, h1=2, t_h2=18.0, h2=4,
                          qsr={2: 18.7, 4: 18.0},
                          recipe=dict(peak_lr=0.8, total=62_557,
                                      warmup=1_564,
                                      alphas={2: 0.2, 4: 0.25})),
    "ViT-B/2x8": dict(t_para=26.7, t_h1=21.2, h1=4, t_h2=20.5, h2=8,
                      qsr={4: 20.2, 8: 20.0},
                      recipe=dict(peak_lr=0.008, total=93_838,
                                  warmup=10_000,
                                  alphas={4: 0.0175, 8: 0.0175})),
    "ResNet152/8x8": dict(t_para=5.7, t_h1=5.1, h1=2, t_h2=4.8, h2=4,
                          qsr={2: 5.0, 4: 4.7},
                          recipe=dict(peak_lr=1.6, total=15_639, warmup=391,
                                      alphas={2: 0.2, 4: 0.2})),
    "ViT-B/8x8": dict(t_para=8.6, t_h1=5.8, h1=4, t_h2=5.3, h2=8,
                      qsr={4: 5.5, 8: 5.3},
                      recipe=dict(peak_lr=0.016, total=23_460, warmup=2_500,
                                  alphas={4: 0.0175, 8: 0.01})),
}


def appf_model(t_para: float, t_h1: float, h1: int):
    """Paper eqs. 27-28: split total time into comm + compute."""
    t_comm = h1 / (h1 - 1) * (t_para - t_h1)
    t_comp = t_para - t_comm
    return t_comm, t_comp


def _qsr_run(recipe, h_base: int) -> RunConfig:
    """The one recipe-dict -> RunConfig mapping (Parts 1 and 3 must agree)."""
    return RunConfig(schedule="qsr", h_base=h_base,
                     alpha=recipe["alphas"][h_base],
                     peak_lr=recipe["peak_lr"], total_steps=recipe["total"],
                     warmup_steps=recipe["warmup"])


def qsr_fraction(recipe, h_base: int) -> float:
    run = _qsr_run(recipe, h_base)
    return schedules.comm_fraction(run, make_lr_fn(run))


def v5e_projection(csv_rows: list | None = None) -> None:
    """Part 2: Table 4 restated for TPU v5e from the dry-run roofline terms.

    Per training pair (single-pod records): step time ~ max(compute, memory)
    + collective term (serial model — no overlap assumed, consistent with
    App. F's additive comm/comp split).  QSR pays sync/H; parallel pays the
    gradient sync every step.  DCI (multi-pod) uses the same arithmetic with
    the pod-crossing bytes at 25 GB/s."""
    import glob
    import json

    from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

    print("\n== Table 4 (v5e projection from dry-run rooflines) ==")
    print(f"{'arch':18s} {'parallel s/step':>15s} {'QSR(H=4) s/step':>15s} "
          f"{'late-QSR s/step':>15s} {'speedup':>8s}")
    for f in sorted(glob.glob("experiments/dryrun/*__train_4k__single.json")):
        r = json.load(open(f))
        if not r.get("ok") or "local_step" not in r:
            continue
        def t(m):
            return (max(m["flops"] / PEAK_FLOPS,
                        m["bytes_accessed"] / HBM_BW)
                    + m["collective_bytes_total"] / ICI_BW)
        tp = t(r["parallel_step"])
        sync_t = t(r["sync"])
        tl = t(r["local_step"])
        q4 = tl + sync_t / 4
        qinf = tl  # late training: H -> large, sync amortized away
        print(f"{r['arch']:18s} {tp:15.3f} {q4:15.3f} {qinf:15.3f} "
              f"{tp / q4:7.2f}x")
        if csv_rows is not None:
            csv_rows.append((f"table4_v5e/{r['arch']}/speedup_h4", "",
                             f"{tp/q4:.3f}"))

    # ---- multi-pod: the pod boundary (DCI ~ 25 GB/s) is where QSR pays off
    DCI_BW = 25e9
    rows = []
    for f in sorted(glob.glob("experiments/dryrun/*__train_4k__multi.json")):
        r = json.load(open(f))
        if not r.get("ok") or "local_step" not in r:
            continue
        if "dci_bytes" not in r["local_step"]:
            continue
        def t2(m):
            ici = m["collective_bytes_total"] - m["dci_bytes"]
            return (max(m["flops"] / PEAK_FLOPS,
                        m["bytes_accessed"] / HBM_BW)
                    + ici / ICI_BW + m["dci_bytes"] / DCI_BW)
        tp = t2(r["parallel_step"])
        q4 = t2(r["local_step"]) + t2(r["sync"]) / 4
        qinf = t2(r["local_step"])
        dci_p = r["parallel_step"]["dci_bytes"]
        dci_q = r["local_step"]["dci_bytes"] + r["sync"]["dci_bytes"] / 4
        rows.append((r["arch"], tp, q4, qinf, dci_p, dci_q))
    if rows:
        print("\n-- multi-pod (2x16x16): DCI-aware projection --")
        print(f"{'arch':18s} {'parallel':>10s} {'QSR(H=4)':>10s} "
              f"{'late-QSR':>10s} {'speedup':>8s} {'DCI cut':>8s}")
        for arch, tp, q4, qinf, dp_, dq_ in rows:
            cut = dp_ / max(dq_, 1.0)
            print(f"{arch:18s} {tp:10.3f} {q4:10.3f} {qinf:10.3f} "
                  f"{tp/q4:7.2f}x {cut:7.1f}x")
            if csv_rows is not None:
                csv_rows.append((f"table4_v5e_multi/{arch}/speedup_h4", "",
                                 f"{tp/q4:.3f}"))


def compile_report(csv_rows: list | None = None) -> None:
    """Part 3: XLA round-program compiles per run, legacy vs bucketed.

    legacy = one jit per distinct H visited; bucketed = one per power-of-two
    bucket, provably <= ceil(log2(H_max)) + 1 (engine.max_programs)."""
    from repro.core.engine import bucket_pow2, program_bound

    print("\n== Table 4 extra column: XLA compiles per run ==")
    print(f"{'setting':24s} {'distinct H':>10s} {'buckets':>8s} "
          f"{'bound':>6s} {'drop':>6s}")
    for name, d in TABLE4.items():
        r = d["recipe"]
        for hb in sorted(r["alphas"]):
            run = _qsr_run(r, hb)
            lr = make_lr_fn(run)
            hs = [h for _, h in schedules.rounds(run, lr)]  # one walk
            n_h = len(set(hs))
            n_b = len({bucket_pow2(h) for h in hs})
            bound = program_bound(max(hs))
            assert n_b <= bound, (name, hb, n_b, bound)
            print(f"{name + f' H>={hb}':24s} {n_h:10d} {n_b:8d} "
                  f"{bound:6d} {n_h / n_b:5.1f}x")
            if csv_rows is not None:
                csv_rows.append((f"table4/{name}/h{hb}/compiles_legacy", "",
                                 str(n_h)))
                csv_rows.append((f"table4/{name}/h{hb}/compiles_bucketed", "",
                                 str(n_b)))
    print("bucketed engine: O(log2 Hmax) compiles; legacy: O(#distinct H)")


def overlap_report(csv_rows: list | None = None) -> None:
    """Blocking vs overlapped sync, MEASURED (not asserted): the same smoke
    run through the RoundEngine under sync="blocking" and sync="overlap"
    (depth 1, flat_sharded layout), steady-state seconds/round after the
    compile warmup.  On a single host device there is no wire to hide the
    gather behind, so this column is the honest harness for the overlap
    claim — the win appears when the runtime can run the deferred
    gather/apply concurrently with the next round's first local steps, and
    the measurement (rather than an assertion) is what CI archives."""
    import time

    import jax

    from repro.configs import registry as R
    from repro.core import schedules as S
    from repro.core.engine import RoundEngine
    from repro.optim.lr import make_lr_fn

    cfg = R.get_smoke_config("starcoder2-3b")
    run_cfg = RunConfig(schedule="constant", h_base=8, total_steps=96,
                        remat=False)
    lr_fn = make_lr_fn(run_cfg)
    print("\n== Table 4 extra column: blocking vs overlapped sync "
          "(smoke, measured) ==")
    print(f"{'sync':>10s} {'depth':>6s} {'s/round':>9s} {'rounds':>7s}")
    base = None
    for sync, depth in (("blocking", 0), ("overlap", 1)):
        eng = RoundEngine(cfg, run_cfg, workers=2, b_loc=2, seq=32,
                          layout="flat_sharded", sync=sync,
                          overlap_depth=depth)
        state = eng.init_state()
        t = 0
        for _ in range(2):  # warmup: compiles every round-program variant
            h = S.get_h(run_cfg, t, lr_fn)
            state, _ = eng.run_round(state, t, h, lr_fn)
            t += h
        # ... including the flush/apply program, so the overlap leg's timed
        # window holds only steady-state rounds (a no-op under blocking)
        state = eng.flush(state)
        jax.block_until_ready(jax.tree.leaves(state))
        t0 = time.perf_counter()
        n = 0
        while t < run_cfg.total_steps:
            h = S.get_h(run_cfg, t, lr_fn)
            state, _ = eng.run_round(state, t, h, lr_fn)
            t += h
            n += 1
        jax.block_until_ready(jax.tree.leaves(state))
        per_round = (time.perf_counter() - t0) / max(n, 1)
        state = eng.flush(state)
        base = base or per_round
        print(f"{sync:>10s} {depth:6d} {per_round:9.3f} {n:7d}")
        if csv_rows is not None:
            csv_rows.append((f"table4_overlap/{sync}_d{depth}/s_per_round",
                             "", f"{per_round:.4f}"))
    print(f"overlap/blocking ratio: {per_round / base:.2f}x "
          "(CPU smoke measurement; on a real mesh the gather leg also "
          "leaves the critical path)")


def run(csv_rows: list | None = None) -> None:
    print("\n== Table 4 / App. F: wall-clock model vs paper ==")
    print(f"{'setting':18s} {'pred T_H2':>9s} {'paper':>6s} "
          f"{'pred QSR':>9s} {'paper':>6s} {'err%':>6s}")
    for name, d in TABLE4.items():
        t_comm, t_comp = appf_model(d["t_para"], d["t_h1"], d["h1"])
        pred_h2 = t_comp + t_comm / d["h2"]                    # eq. 30
        err_h2 = 100 * abs(pred_h2 - d["t_h2"]) / d["t_h2"]
        # QSR: comm fraction from the actual H-trace (eq. 31)
        hb = min(d["qsr"])
        f = qsr_fraction(d["recipe"], hb)
        pred_qsr = t_comp + f * t_comm
        err_q = 100 * abs(pred_qsr - d["qsr"][hb]) / d["qsr"][hb]
        print(f"{name:18s} {pred_h2:9.2f} {d['t_h2']:6.1f} "
              f"{pred_qsr:9.2f} {d['qsr'][hb]:6.1f} {max(err_h2, err_q):6.1f}")
        if csv_rows is not None:
            csv_rows.append((f"table4/{name}/comm_hours", "",
                             f"{t_comm:.2f}"))
            csv_rows.append((f"table4/{name}/pred_qsr_hours", "",
                             f"{pred_qsr:.2f}"))
        assert err_h2 < 8.0 and err_q < 8.0, (name, err_h2, err_q)
    print("model error <8% on every Table 4 setting "
          "(paper reports ~1% for its own runs)")
    compile_report(csv_rows)
    overlap_report(csv_rows)
    v5e_projection(csv_rows)


if __name__ == "__main__":
    run()
