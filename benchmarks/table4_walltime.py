"""Paper Table 4 + Appendix F: the wall-clock / communication-time model.

Part 1 — validate the paper's own methodology (App. F eqs. 27-31) against
Table 4's published measurements: from (T_para_tot, T_H1_tot) derive comm and
compute times, then PREDICT T_H2_tot and the QSR totals, and compare with
what the paper measured.  (The paper reports ~1% relative error for this
model; we reproduce its arithmetic exactly.)

Part 2 — apply the same model to OUR target hardware: per-step compute and
comm times from the dry-run roofline terms (benchmarks/roofline.py), giving
projected v5e wall-clock savings for QSR per architecture.

Part 3 — the compile-cost column: wall-clock also pays one XLA compile per
distinct round program.  The legacy runtime jits one `train_round` per
distinct H the schedule visits; the RoundEngine's power-of-two bucketing
(core/engine.py) compiles at most ceil(log2(H_max)) + 1 programs.  This
section reports both counts per Table 4 recipe.
"""
from __future__ import annotations

from repro.configs.base import RunConfig
from repro.core import schedules
from repro.optim.lr import make_lr_fn

# Table 4 published totals (hours): (T_parallel, T_{H1}, H1, T_{H2}, H2,
#                                    QSR totals {h_base: (hours, f_comm)})
TABLE4 = {
    "ResNet152/2x8": dict(t_para=20.7, t_h1=19.0, h1=2, t_h2=18.0, h2=4,
                          qsr={2: 18.7, 4: 18.0},
                          recipe=dict(peak_lr=0.8, total=62_557,
                                      warmup=1_564,
                                      alphas={2: 0.2, 4: 0.25})),
    "ViT-B/2x8": dict(t_para=26.7, t_h1=21.2, h1=4, t_h2=20.5, h2=8,
                      qsr={4: 20.2, 8: 20.0},
                      recipe=dict(peak_lr=0.008, total=93_838,
                                  warmup=10_000,
                                  alphas={4: 0.0175, 8: 0.0175})),
    "ResNet152/8x8": dict(t_para=5.7, t_h1=5.1, h1=2, t_h2=4.8, h2=4,
                          qsr={2: 5.0, 4: 4.7},
                          recipe=dict(peak_lr=1.6, total=15_639, warmup=391,
                                      alphas={2: 0.2, 4: 0.2})),
    "ViT-B/8x8": dict(t_para=8.6, t_h1=5.8, h1=4, t_h2=5.3, h2=8,
                      qsr={4: 5.5, 8: 5.3},
                      recipe=dict(peak_lr=0.016, total=23_460, warmup=2_500,
                                  alphas={4: 0.0175, 8: 0.01})),
}


def appf_model(t_para: float, t_h1: float, h1: int):
    """Paper eqs. 27-28: split total time into comm + compute."""
    t_comm = h1 / (h1 - 1) * (t_para - t_h1)
    t_comp = t_para - t_comm
    return t_comm, t_comp


def _qsr_run(recipe, h_base: int) -> RunConfig:
    """The one recipe-dict -> RunConfig mapping (Parts 1 and 3 must agree)."""
    return RunConfig(schedule="qsr", h_base=h_base,
                     alpha=recipe["alphas"][h_base],
                     peak_lr=recipe["peak_lr"], total_steps=recipe["total"],
                     warmup_steps=recipe["warmup"])


def qsr_fraction(recipe, h_base: int) -> float:
    run = _qsr_run(recipe, h_base)
    return schedules.comm_fraction(run, make_lr_fn(run))


def v5e_projection(csv_rows: list | None = None) -> None:
    """Part 2: Table 4 restated for TPU v5e from the dry-run roofline terms.

    Per training pair (single-pod records): step time ~ max(compute, memory)
    + collective term (serial model — no overlap assumed, consistent with
    App. F's additive comm/comp split).  QSR pays sync/H; parallel pays the
    gradient sync every step.  DCI (multi-pod) uses the same arithmetic with
    the pod-crossing bytes at 25 GB/s."""
    import glob
    import json

    from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

    print("\n== Table 4 (v5e projection from dry-run rooflines) ==")
    print(f"{'arch':18s} {'parallel s/step':>15s} {'QSR(H=4) s/step':>15s} "
          f"{'late-QSR s/step':>15s} {'speedup':>8s}")
    for f in sorted(glob.glob("experiments/dryrun/*__train_4k__single.json")):
        r = json.load(open(f))
        if not r.get("ok") or "local_step" not in r:
            continue
        def t(m):
            return (max(m["flops"] / PEAK_FLOPS,
                        m["bytes_accessed"] / HBM_BW)
                    + m["collective_bytes_total"] / ICI_BW)
        tp = t(r["parallel_step"])
        sync_t = t(r["sync"])
        tl = t(r["local_step"])
        q4 = tl + sync_t / 4
        qinf = tl  # late training: H -> large, sync amortized away
        print(f"{r['arch']:18s} {tp:15.3f} {q4:15.3f} {qinf:15.3f} "
              f"{tp / q4:7.2f}x")
        if csv_rows is not None:
            csv_rows.append((f"table4_v5e/{r['arch']}/speedup_h4", "",
                             f"{tp/q4:.3f}"))

    # ---- multi-pod: the pod boundary (DCI ~ 25 GB/s) is where QSR pays off
    DCI_BW = 25e9
    rows = []
    for f in sorted(glob.glob("experiments/dryrun/*__train_4k__multi.json")):
        r = json.load(open(f))
        if not r.get("ok") or "local_step" not in r:
            continue
        if "dci_bytes" not in r["local_step"]:
            continue
        def t2(m):
            ici = m["collective_bytes_total"] - m["dci_bytes"]
            return (max(m["flops"] / PEAK_FLOPS,
                        m["bytes_accessed"] / HBM_BW)
                    + ici / ICI_BW + m["dci_bytes"] / DCI_BW)
        tp = t2(r["parallel_step"])
        q4 = t2(r["local_step"]) + t2(r["sync"]) / 4
        qinf = t2(r["local_step"])
        dci_p = r["parallel_step"]["dci_bytes"]
        dci_q = r["local_step"]["dci_bytes"] + r["sync"]["dci_bytes"] / 4
        rows.append((r["arch"], tp, q4, qinf, dci_p, dci_q))
    if rows:
        print("\n-- multi-pod (2x16x16): DCI-aware projection --")
        print(f"{'arch':18s} {'parallel':>10s} {'QSR(H=4)':>10s} "
              f"{'late-QSR':>10s} {'speedup':>8s} {'DCI cut':>8s}")
        for arch, tp, q4, qinf, dp_, dq_ in rows:
            cut = dp_ / max(dq_, 1.0)
            print(f"{arch:18s} {tp:10.3f} {q4:10.3f} {qinf:10.3f} "
                  f"{tp/q4:7.2f}x {cut:7.1f}x")
            if csv_rows is not None:
                csv_rows.append((f"table4_v5e_multi/{arch}/speedup_h4", "",
                                 f"{tp/q4:.3f}"))


def compile_report(csv_rows: list | None = None) -> None:
    """Part 3: XLA round-program compiles per run, legacy vs bucketed.

    legacy = one jit per distinct H visited; bucketed = one per power-of-two
    bucket, provably <= ceil(log2(H_max)) + 1 (engine.max_programs)."""
    from repro.core.engine import bucket_pow2, program_bound

    print("\n== Table 4 extra column: XLA compiles per run ==")
    print(f"{'setting':24s} {'distinct H':>10s} {'buckets':>8s} "
          f"{'bound':>6s} {'drop':>6s}")
    for name, d in TABLE4.items():
        r = d["recipe"]
        for hb in sorted(r["alphas"]):
            run = _qsr_run(r, hb)
            lr = make_lr_fn(run)
            hs = [h for _, h in schedules.rounds(run, lr)]  # one walk
            n_h = len(set(hs))
            n_b = len({bucket_pow2(h) for h in hs})
            bound = program_bound(max(hs))
            assert n_b <= bound, (name, hb, n_b, bound)
            print(f"{name + f' H>={hb}':24s} {n_h:10d} {n_b:8d} "
                  f"{bound:6d} {n_h / n_b:5.1f}x")
            if csv_rows is not None:
                csv_rows.append((f"table4/{name}/h{hb}/compiles_legacy", "",
                                 str(n_h)))
                csv_rows.append((f"table4/{name}/h{hb}/compiles_bucketed", "",
                                 str(n_b)))
    print("bucketed engine: O(log2 Hmax) compiles; legacy: O(#distinct H)")


def overlap_report(csv_rows: list | None = None,
                   recs: dict | None = None) -> None:
    """Blocking vs overlapped sync, MEASURED (not asserted): the same smoke
    run through the RoundEngine under sync="blocking" and sync="overlap"
    (depth 1, flat_sharded layout), steady-state seconds/round after the
    compile warmup.  On a single host device there is no wire to hide the
    gather behind, so this column is the honest harness for the overlap
    claim — the win appears when the runtime can run the deferred
    gather/apply concurrently with the next round's first local steps, and
    the measurement (rather than an assertion) is what CI archives."""
    import time

    import jax

    from repro.configs import registry as R
    from repro.core import schedules as S
    from repro.core.engine import RoundEngine
    from repro.optim.lr import make_lr_fn

    cfg = R.get_smoke_config("starcoder2-3b")
    print("\n== Table 4 extra column: blocking vs overlapped sync "
          "(smoke, measured) ==")
    print(f"{'sync':>10s} {'depth':>6s} {'wire':>10s} {'s/round':>9s} "
          f"{'rounds':>7s}")
    base = None
    # the ring-int8 row measures the wire-mode's compute cost on the same
    # harness: per-hop requantization trades arithmetic for bytes, and the
    # honest CPU number is what the autotuner's s/round axis weighs against
    # the ~2.3x byte cut (launch/autotune.py)
    for sync, depth, wire in (("blocking", 0, "auto"),
                              ("overlap", 1, "auto"),
                              ("blocking", 0, "ring-int8")):
        run_cfg = RunConfig(schedule="constant", h_base=8, total_steps=96,
                            remat=False, sync_quantize=wire == "ring-int8",
                            sync_wire=wire)
        lr_fn = make_lr_fn(run_cfg)
        eng = RoundEngine(cfg, run_cfg, workers=2, b_loc=2, seq=32,
                          layout="flat_sharded", sync=sync,
                          overlap_depth=depth)
        state = eng.init_state()
        t = 0
        for _ in range(2):  # warmup: compiles every round-program variant
            h = S.get_h(run_cfg, t, lr_fn)
            state, _ = eng.run_round(state, t, h, lr_fn)
            t += h
        # ... including the flush/apply program, so the overlap leg's timed
        # window holds only steady-state rounds (a no-op under blocking)
        state = eng.flush(state)
        jax.block_until_ready(jax.tree.leaves(state))
        t0 = time.perf_counter()
        n = 0
        while t < run_cfg.total_steps:
            h = S.get_h(run_cfg, t, lr_fn)
            state, _ = eng.run_round(state, t, h, lr_fn)
            t += h
            n += 1
        jax.block_until_ready(jax.tree.leaves(state))
        per_round = (time.perf_counter() - t0) / max(n, 1)
        state = eng.flush(state)
        base = base or per_round
        tag = f"{sync}_d{depth}" + ("_ring" if wire == "ring-int8" else "")
        print(f"{sync:>10s} {depth:6d} {wire:>10s} {per_round:9.3f} "
              f"{n:7d}")
        if csv_rows is not None:
            csv_rows.append((f"table4_overlap/{tag}/s_per_round",
                             "", f"{per_round:.4f}"))
        if recs is not None:
            recs.setdefault("overlap", {})[tag] = {
                "s_per_round": per_round, "rounds": n}
        if tag == "overlap_d1":
            print(f"overlap/blocking ratio: {per_round / base:.2f}x "
                  "(CPU smoke measurement; on a real mesh the gather leg "
                  "also leaves the critical path)")
        elif tag == "blocking_d0_ring":
            print(f"ring/blocking ratio: {per_round / base:.2f}x "
                  "(requantization arithmetic per hop; the wire pays "
                  "~2.3x fewer bytes — benchmarks/bench_sync_baseline.json)")


def observer_report(csv_rows: list | None = None,
                    recs: dict | None = None) -> None:
    """Table 4 extra column: blocking vs overlap vs overlap + async
    observer, MEASURED with a real per-round eval + checkpoint observer.

    The blocking and overlap+inline rows pay the observer on the round
    loop: device_get the synced view, compute an eval scalar, write the
    checkpoint — the stall shows up as the max of the round-time series.
    The overlap+async row submits the same synced view to the background
    AsyncObserver (core/observer.py) and keeps training; the device_get
    and I/O land on the worker thread, so the round-time series stays
    flat (the checkpoint stall is absent) and mean s/round drops back to
    the no-observer overlap rate.  Recorded (JSON artifact in CI), not
    asserted: it is a wall-clock measurement."""
    import tempfile
    import time

    import jax
    import numpy as np

    from repro.checkpoint import io as ckpt_io
    from repro.configs import registry as R
    from repro.core import schedules as S
    from repro.core.engine import RoundEngine
    from repro.core.observer import AsyncObserver
    from repro.optim.lr import make_lr_fn

    cfg = R.get_smoke_config("starcoder2-3b")
    # short rounds: the observer stall (device_get + checkpoint write) is a
    # large fraction of a round, so hiding it is measurable above host noise
    run_cfg = RunConfig(schedule="constant", h_base=2, total_steps=52,
                        remat=False)
    lr_fn = make_lr_fn(run_cfg)
    every = 2   # observer cadence (rounds) — identical for all three rows
    print("\n== Table 4 extra column: blocking vs overlap vs overlap+async "
          f"observer (smoke, eval+ckpt every {every} rounds, measured) ==")
    print(f"{'mode':>16s} {'s/round':>9s} {'max round':>10s} {'rounds':>7s} "
          f"{'dropped':>8s}")
    rows = {}
    for label, sync, depth, asynchronous in (
            ("blocking", "blocking", 0, False),
            ("overlap", "overlap", 1, False),
            ("overlap+async", "overlap", 1, True)):
        eng = RoundEngine(cfg, run_cfg, workers=2, b_loc=2, seq=32,
                          layout="flat_sharded", sync=sync,
                          overlap_depth=depth)
        state = eng.init_state()
        with tempfile.TemporaryDirectory() as ckdir:
            def observe(step, snap):
                # the observer payload: one eval scalar off the consensus
                # params + a full checkpoint write
                ev = float(np.linalg.norm(np.asarray(
                    next(iter(snap["state"]["params"].values())),
                    np.float32)))
                ckpt_io.save(ckdir, snap["state"], step=step,
                             extra={**snap["extra"], "eval": ev})
            obs = AsyncObserver(observe) if asynchronous else None
            t = 0
            for _ in range(2):   # warmup: every program variant + the view
                h = S.get_h(run_cfg, t, lr_fn)
                state, _ = eng.run_round(state, t, h, lr_fn)
                t += h
                jax.block_until_ready(jax.tree.leaves(
                    eng.synced_view(state)))
            times, n = [], 0
            while t < run_cfg.total_steps:
                t0 = time.perf_counter()
                h = S.get_h(run_cfg, t, lr_fn)
                state, _ = eng.run_round(state, t, h, lr_fn)
                t += h
                if n % every == 0:
                    snap = {"state": eng.synced_view(state),
                            "extra": eng.checkpoint_extra()}
                    if obs is not None:
                        obs.submit(t, snap)
                    else:
                        observe(t, {"state": ckpt_io.stage(snap["state"]),
                                    "extra": snap["extra"]})
                jax.block_until_ready(jax.tree.leaves(state))
                times.append(time.perf_counter() - t0)
                n += 1
            dropped = 0
            if obs is not None:
                obs.drain()
                dropped = obs.dropped
                obs.close()
            state = eng.flush(state)
        per_round = sum(times) / max(n, 1)
        rows[label] = {"s_per_round": per_round, "max_round_s": max(times),
                       "rounds": n, "dropped": dropped,
                       "round_times": [round(x, 5) for x in times]}
        print(f"{label:>16s} {per_round:9.3f} {max(times):10.3f} {n:7d} "
              f"{dropped:8d}")
        if csv_rows is not None:
            csv_rows.append((f"table4_observer/{label}/s_per_round", "",
                             f"{per_round:.4f}"))
            csv_rows.append((f"table4_observer/{label}/max_round_s", "",
                             f"{max(times):.4f}"))
    if recs is not None:
        recs["observer"] = rows
    print("async observer: the eval+checkpoint stall leaves the round-time "
          "series (device_get + I/O run on the worker thread)")


def run(csv_rows: list | None = None, *, recs: dict | None = None,
        sections: tuple = ("model", "compile", "overlap", "observer",
                           "v5e")) -> None:
    if "model" in sections:
        _model_report(csv_rows)
    if "compile" in sections:
        compile_report(csv_rows)
    if "overlap" in sections:
        overlap_report(csv_rows, recs=recs)
    if "observer" in sections:
        observer_report(csv_rows, recs=recs)
    if "v5e" in sections:
        v5e_projection(csv_rows)


def _model_report(csv_rows: list | None = None) -> None:
    print("\n== Table 4 / App. F: wall-clock model vs paper ==")
    print(f"{'setting':18s} {'pred T_H2':>9s} {'paper':>6s} "
          f"{'pred QSR':>9s} {'paper':>6s} {'err%':>6s}")
    for name, d in TABLE4.items():
        t_comm, t_comp = appf_model(d["t_para"], d["t_h1"], d["h1"])
        pred_h2 = t_comp + t_comm / d["h2"]                    # eq. 30
        err_h2 = 100 * abs(pred_h2 - d["t_h2"]) / d["t_h2"]
        # QSR: comm fraction from the actual H-trace (eq. 31)
        hb = min(d["qsr"])
        f = qsr_fraction(d["recipe"], hb)
        pred_qsr = t_comp + f * t_comm
        err_q = 100 * abs(pred_qsr - d["qsr"][hb]) / d["qsr"][hb]
        print(f"{name:18s} {pred_h2:9.2f} {d['t_h2']:6.1f} "
              f"{pred_qsr:9.2f} {d['qsr'][hb]:6.1f} {max(err_h2, err_q):6.1f}")
        if csv_rows is not None:
            csv_rows.append((f"table4/{name}/comm_hours", "",
                             f"{t_comm:.2f}"))
            csv_rows.append((f"table4/{name}/pred_qsr_hours", "",
                             f"{pred_qsr:.2f}"))
        assert err_h2 < 8.0 and err_q < 8.0, (name, err_h2, err_q)
    print("model error <8% on every Table 4 setting "
          "(paper reports ~1% for its own runs)")


def main() -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--sections", default="model,compile,overlap,observer,v5e",
                    help="comma list of report sections to run")
    ap.add_argument("--out", default=None,
                    help="write the measured overlap/observer rows as JSON "
                         "(the CI walltime artifact)")
    args = ap.parse_args()
    recs: dict = {}
    run(sections=tuple(args.sections.split(",")), recs=recs)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
