"""Paper Tables 1-3 / Fig. 1: communication volume of every synchronization
strategy under the paper's exact recipes (ImageNet: ResNet-152 B=4096 200ep,
ViT-B B=4096/16384 300ep; cosine+linear+step decay).

Comm volume = rounds/steps relative to data-parallel (one all-reduce per
step) — computed from the actual H-trace, compared against the paper's
reported numbers.

`sync_lowering` adds the per-sync *lowering* axis the schedule math can't
see: bytes on wire and collectives per sync for the tree vs flat param
layouts, measured from compiled HLO by launch/hlo_analysis via the
launch/sync_compare subprocess (it must pin the host device count before
jax initializes, hence the shell-out)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

from repro.configs.base import RunConfig
from repro.core import schedules
from repro.optim.lr import make_lr_fn

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

IMAGENET = 1_281_167

# (label, recipe kwargs, paper-reported comm %, tolerance)
CASES = [
    # paper Table 1(a)'s comm column is cropped in the text; Fig. 1 reports
    # 20.1% for the Hb=4 recipe, and Tables 2a/3a bracket Hb=2 at 40-43%.
    ("ResNet152/B4096/QSR(Hb=2,a=0.2)",
     dict(schedule="qsr", h_base=2, alpha=0.2, peak_lr=0.8,
          total_steps=round(IMAGENET / 4096 * 200),
          warmup_steps=round(IMAGENET / 4096 * 5)), 41.5, 3.0),
    ("ResNet152/B4096/QSR(Hb=4,a=0.25)",
     dict(schedule="qsr", h_base=4, alpha=0.25, peak_lr=0.8,
          total_steps=round(IMAGENET / 4096 * 200),
          warmup_steps=round(IMAGENET / 4096 * 5)), 20.1, 4.0),
    ("ResNet152/B16384/QSR(Hb=2,a=0.2,lr=1.6)",
     dict(schedule="qsr", h_base=2, alpha=0.2, peak_lr=1.6,
          total_steps=round(IMAGENET / 16384 * 200),
          warmup_steps=round(IMAGENET / 16384 * 5)), 42.8, 5.0),
    ("ResNet152/B16384/QSR(Hb=4,a=0.2,lr=1.6)",
     dict(schedule="qsr", h_base=4, alpha=0.2, peak_lr=1.6,
          total_steps=round(IMAGENET / 16384 * 200),
          warmup_steps=round(IMAGENET / 16384 * 5)), 21.9, 4.0),
    ("ViT-B/B4096/QSR(Hb=4,a=0.0175)",
     dict(schedule="qsr", h_base=4, alpha=0.0175, peak_lr=0.008,
          total_steps=round(IMAGENET / 4096 * 300), warmup_steps=10_000),
     10.4, 5.0),
    ("ViT-B/B16384/QSR(Hb=4,a=0.0175,lr=0.016)",
     dict(schedule="qsr", h_base=4, alpha=0.0175, peak_lr=0.016,
          total_steps=round(IMAGENET / 16384 * 300), warmup_steps=2_500),
     16.1, 8.0),
    ("ViT-B/B16384/QSR(Hb=8,a=0.01)",
     dict(schedule="qsr", h_base=8, alpha=0.01, peak_lr=0.016,
          total_steps=round(IMAGENET / 16384 * 300), warmup_steps=2_500),
     9.8, 5.0),
    ("ViT-B/B4096/step-decay/QSR(Hb=4,a=0.015)",
     dict(schedule="qsr", lr_schedule="step", h_base=4, alpha=0.015,
          peak_lr=0.008, total_steps=round(IMAGENET / 4096 * 300),
          warmup_steps=10_000), 12.7, 6.0),
    ("ViT-B/B4096/step-decay/QSR(Hb=8,a=0.015)",
     dict(schedule="qsr", lr_schedule="step", h_base=8, alpha=0.015,
          peak_lr=0.008, total_steps=round(IMAGENET / 4096 * 300),
          warmup_steps=10_000), 7.2, 4.0),
    ("ViT-B/B4096/constant H=4",
     dict(schedule="constant", h_base=4,
          total_steps=round(IMAGENET / 4096 * 300)), 25.0, 0.01),
    ("ViT-B/B4096/constant H=8",
     dict(schedule="constant", h_base=8,
          total_steps=round(IMAGENET / 4096 * 300)), 12.5, 0.01),
    ("ViT-B/B4096/inverse(b=0.03,Hb=4)",
     dict(schedule="inverse", beta=0.03, h_base=4, peak_lr=0.008,
          total_steps=round(IMAGENET / 4096 * 300), warmup_steps=10_000),
     None, None),
    ("ViT-B/B4096/postlocal(t0=50%,H=8)",
     dict(schedule="postlocal", h_base=8, switch_frac=0.5,
          total_steps=round(IMAGENET / 4096 * 300)), None, None),
]


def run(csv_rows: list | None = None) -> None:
    print("\n== Table 1-3 / Fig. 1: communication volume vs paper ==")
    print(f"{'recipe':52s} {'comm%':>8s} {'paper%':>8s} {'match':>6s}")
    for label, kw, paper, tol in CASES:
        run_cfg = RunConfig(**kw)
        frac = 100 * schedules.comm_fraction(run_cfg, make_lr_fn(run_cfg))
        ok = "-" if paper is None else ("yes" if abs(frac - paper) <= tol
                                        else "NO")
        ps = "-" if paper is None else f"{paper:.1f}"
        print(f"{label:52s} {frac:8.2f} {ps:>8s} {ok:>6s}")
        if csv_rows is not None:
            csv_rows.append((f"table1_comm/{label}", "", f"{frac:.2f}%"))
        if paper is not None:
            assert abs(frac - paper) <= tol, (label, frac, paper)


def sync_lowering(csv_rows: list | None = None, *,
                  arch: str = "starcoder2-3b",
                  meshes: tuple[str, ...] = ("8x1", "4x2"),
                  json_records: list | None = None) -> None:
    """Bytes-on-wire + collectives-per-sync for all three param layouts.

    8x1 is pure data-parallel: tree and flat move identical bytes, flat in
    one all-reduce per dtype bucket instead of one per leaf, and
    flat_sharded decomposes that all-reduce into one reduce_scatter + one
    all_gather whose scatter leg lands 1/W of the bucket per device (the
    `rs-leg` column — the ~W x drop that `--sync overlap` can then hide
    behind the next round's compute).  4x2 adds model sharding: tree
    all-reduces shard-local bytes (and pays resharding all-to-alls); flat
    pays the replicated buffer; flat_sharded chunks the buffer over model
    too, so its legs shrink by W x S.
    """
    print("\n== per-sync lowering: tree vs flat vs flat_sharded "
          f"({arch} smoke, dp policy) ==")
    print(f"{'mesh':>6s} {'layout':>12s} {'all-red':>8s} {'rs+ag':>6s} "
          f"{'collectives':>12s} {'bytes/sync':>12s} {'rs-leg':>10s} "
          f"{'tensors':>8s}")
    env = dict(os.environ, PYTHONPATH=_SRC +
               os.pathsep + os.environ.get("PYTHONPATH", ""))
    for mesh in meshes:
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.sync_compare",
             "--arch", arch, "--mesh", mesh],
            capture_output=True, text=True, env=env, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        rec = json.loads(out.stdout)
        if json_records is not None:
            json_records.append({"mesh": mesh, "arch": arch, "sync": rec})
        for layout in ("tree", "flat", "flat_sharded"):
            r = rec[layout]
            n_coll = sum(r["collective_counts"].values())
            rs_ag = r["reduce_scatter_ops"] + r["all_gather_ops"]
            tensors = (f"{r['n_leaves']} lvs" if layout == "tree"
                       else f"{r['n_buckets']} bkts")
            print(f"{mesh:>6s} {layout:>12s} {r['all_reduce_ops']:8d} "
                  f"{rs_ag:6d} {n_coll:12d} {r['bytes_on_wire']:12,d} "
                  f"{r['scatter_leg_bytes']:10,d} {tensors:>8s}")
            if csv_rows is not None:
                base = f"table1_comm/sync_{mesh}_{layout}"
                csv_rows.append((f"{base}/all_reduces", "",
                                 str(r["all_reduce_ops"])))
                csv_rows.append((f"{base}/bytes_on_wire", "",
                                 str(r["bytes_on_wire"])))
                if layout == "flat_sharded":
                    csv_rows.append((f"{base}/scatter_leg_bytes", "",
                                     str(r["scatter_leg_bytes"])))
        # the layout contracts, checked wherever the benchmark runs
        assert rec["flat"]["all_reduce_ops"] == rec["flat"]["n_buckets"]
        assert rec["tree"]["all_reduce_ops"] >= rec["tree"]["n_leaves"]
        sh = rec["flat_sharded"]
        assert sh["all_reduce_ops"] == 0
        assert sh["reduce_scatter_ops"] == sh["n_buckets"]
        assert sh["all_gather_ops"] == sh["n_buckets"]
        # scatter leg lands a strict fraction of the flat bucket bytes
        assert sh["scatter_leg_bytes"] * 2 <= rec["flat"]["bytes_on_wire"]


def sync_lowering_quantized(csv_rows: list | None = None, *,
                            arch: str = "starcoder2-3b",
                            meshes: tuple[tuple[str, str], ...] = (
                                ("4x2", "dp"), ("2x2x2", "fsdp")),
                            json_records: list | None = None) -> None:
    """The quantized-sync wire budget, flat vs flat_sharded (README
    §Quantized sync on the sharded layout).

    Quantized, the flat layout pays TWO bucket-sized f32 all-reduces per
    sync (the delta payload + the GSPMD worker-amax for the scales); the
    sharded layout runs in the reduce-scatter domain instead — per bucket
    one reduce_scatter + one all_gather carrying int16 integer codes (half
    the f32 bytes), plus ONE scalar-sized amax fold (4 bytes per model
    tensor, `amax-fold` column) for the whole sync.  Zero payload
    all-reduces, zero GSPMD scale collectives — asserted here and in
    tests/test_quantized_sharded.py.
    """
    print("\n== per-sync lowering, QUANTIZED: flat vs flat_sharded "
          f"({arch} smoke) ==")
    print(f"{'mesh':>8s} {'policy':>6s} {'layout':>12s} {'payload-ar':>10s} "
          f"{'rs+ag':>6s} {'amax-fold':>10s} {'bytes/sync':>12s} "
          f"{'rs-wire':>10s}")
    env = dict(os.environ, PYTHONPATH=_SRC +
               os.pathsep + os.environ.get("PYTHONPATH", ""))
    for mesh, policy in meshes:
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.sync_compare",
             "--arch", arch, "--mesh", mesh, "--policy", policy,
             "--quantize", "--param-layout", "flat,flat_sharded"],
            capture_output=True, text=True, env=env, timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        rec = json.loads(out.stdout)
        if json_records is not None:
            json_records.append({"mesh": mesh, "policy": policy,
                                 "arch": arch, "quantize": True,
                                 "sync": rec})
        for layout in ("flat", "flat_sharded"):
            r = rec[layout]
            fold = (f"{r['amax_fold_ops']}x{r['amax_fold_bytes']}B"
                    if r["amax_fold_ops"] else "-")
            print(f"{mesh:>8s} {policy:>6s} {layout:>12s} "
                  f"{r['payload_all_reduce_ops']:10d} "
                  f"{r['reduce_scatter_ops'] + r['all_gather_ops']:6d} "
                  f"{fold:>10s} {r['bytes_on_wire']:12,d} "
                  f"{r['rs_wire_bytes']:10,d}")
            if csv_rows is not None:
                base = f"table1_comm/sync_q_{mesh}_{policy}_{layout}"
                csv_rows.append((f"{base}/bytes_on_wire", "",
                                 str(r["bytes_on_wire"])))
                csv_rows.append((f"{base}/payload_all_reduces", "",
                                 str(r["payload_all_reduce_ops"])))
        sh = rec["flat_sharded"]
        assert sh["payload_all_reduce_ops"] == 0
        assert sh["amax_fold_ops"] <= 1
        assert sh["reduce_scatter_ops"] == sh["n_buckets"]
        assert sh["all_gather_ops"] == sh["n_buckets"]
        assert sh["amax_fold_bytes"] <= 4 * sh["n_leaves"] + 64
        # the integer wire beats the quantized flat sync by >= 2x
        assert sh["bytes_on_wire"] * 2 <= rec["flat"]["bytes_on_wire"]


def sync_lowering_ring(csv_rows: list | None = None, *,
                       arch: str = "starcoder2-3b",
                       meshes: tuple[tuple[str, str], ...] = (
                           ("4x2", "dp"), ("2x2x2", "fsdp")),
                       json_records: list | None = None) -> None:
    """The ring-int8 wire budget vs the exact int-codes RS wire (README
    §Wire modes).

    `--wire ring-int8` swaps the one-shot reduce_scatter for W-1 re-
    quantizing ppermute hops plus an int8 all-gather: every payload
    collective carries s8 — the one-shot RS had to widen to wire_dtype(W)
    (int16/int32) so the exact code sum cannot overflow, the ring re-centers
    to a fresh int8 scale each hop instead.  Asserted per mesh: the payload
    dtype split is s8-ONLY (zero s16/s32 payload — the acceptance proof),
    zero payload all-reduces and reduce_scatters, >= (W-1) permute hops per
    bucket, and >= 2x fewer bytes on wire than the int-codes sync.
    """
    print("\n== per-sync lowering, RING-INT8 vs int-codes RS "
          f"({arch} smoke, flat_sharded) ==")
    print(f"{'mesh':>8s} {'policy':>6s} {'wire':>10s} {'permutes':>8s} "
          f"{'rs+ag':>6s} {'bytes/sync':>12s} {'payload dtypes':>16s} "
          f"{'vs int-codes':>12s}")
    env = dict(os.environ, PYTHONPATH=_SRC +
               os.pathsep + os.environ.get("PYTHONPATH", ""))
    for mesh, policy in meshes:
        recs = {}
        for wire in ("auto", "ring-int8"):
            out = subprocess.run(
                [sys.executable, "-m", "repro.launch.sync_compare",
                 "--arch", arch, "--mesh", mesh, "--policy", policy,
                 "--quantize", "--wire", wire,
                 "--param-layout", "flat_sharded"],
                capture_output=True, text=True, env=env, timeout=600)
            assert out.returncode == 0, out.stderr[-2000:]
            recs[wire] = json.loads(out.stdout)
            if json_records is not None:
                json_records.append({"mesh": mesh, "policy": policy,
                                     "arch": arch, "quantize": True,
                                     "wire": wire, "sync": recs[wire]})
        # worker-axis size: dp's workers span the data axis (DxM meshes),
        # fsdp's span the pod axis (PxDxM) — the leading field either way
        w = int(mesh.split("x")[0])
        for wire, label in (("auto", "int-codes"), ("ring-int8", "ring")):
            r = recs[wire]["flat_sharded"]
            ratio = (recs["auto"]["flat_sharded"]["bytes_on_wire"]
                     / r["bytes_on_wire"])
            dts = ",".join(f"{k}:{v}" for k, v in
                           sorted(r["payload_ops_by_dtype"].items()))
            print(f"{mesh:>8s} {policy:>6s} {label:>10s} "
                  f"{r['collective_permute_ops']:8d} "
                  f"{r['reduce_scatter_ops'] + r['all_gather_ops']:6d} "
                  f"{r['bytes_on_wire']:12,d} {dts:>16s} {ratio:11.2f}x")
            if csv_rows is not None:
                base = f"table1_comm/sync_ring_{mesh}_{policy}_{label}"
                csv_rows.append((f"{base}/bytes_on_wire", "",
                                 str(r["bytes_on_wire"])))
        ring = recs["ring-int8"]["flat_sharded"]
        # int8 on every wire: every payload collective carries s8, none int16+
        assert set(ring["payload_ops_by_dtype"]) == {"s8"}, \
            ring["payload_ops_by_dtype"]
        assert ring["payload_all_reduce_ops"] == 0
        assert ring["reduce_scatter_ops"] == 0
        assert ring["collective_permute_ops"] >= (w - 1) * ring["n_buckets"]
        # >= 2x fewer bytes than the exact int-codes RS wire (acceptance)
        assert ring["bytes_on_wire"] * 2 <= \
            recs["auto"]["flat_sharded"]["bytes_on_wire"], \
            (ring["bytes_on_wire"],
             recs["auto"]["flat_sharded"]["bytes_on_wire"])


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write the sync-lowering records as JSON (the CI "
                         "matrix uploads this as a build artifact)")
    args = ap.parse_args()
    records: list = []
    run()
    sync_lowering(json_records=records)
    sync_lowering_quantized(json_records=records)
    sync_lowering_ring(json_records=records)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"records": records}, f, indent=1)
        print(f"\nwrote {args.out}")


if __name__ == "__main__":
    main()
