"""Theorem 3.1 / Defs 3.1-3.3 validation: sharpness-reduction drift on a
minimizer manifold.

Toy loss with a manifold of minima and position-dependent sharpness
(label-noise form, the Blanc et al. 2020 / Li et al. 2021c mechanism):

    L(x, y) = 1/2 (1 + x^2) y^2            (expected loss)
    g_y     = (1 + x^2) (y - xi)           (label noise xi ~ N(0, s^2))
    g_x     = x (y^2 - 2 y xi)             (unbiased on the manifold)

Manifold Gamma = {y=0}; normal-direction Hessian lambda(x) = 1 + x^2, so
"flatter" means |x| smaller.  On Gamma the expected x-gradient vanishes;
the only force moving x is the SLOW drift from the y-diffusion:
E[g_x] = x * Var(y), with Var(y) set by the OU equilibrium of the
optimizer's own noise.  Defs 3.1-3.3 predict the decay rate of E[x^2]:
  1/(2B) for parallel SGD, K/(2B) for Local SGD with QSR (K times larger),
  in between for H ~ eta^-1.  We measure exactly those ratios.

Two ring-int8 drift measurements ride along (README §Wire modes):

  * `requant_hops=K` injects the per-hop requantization noise model into
    the sync: the ring's K-hop chain replaces the exact worker mean with
    mean + err, |err| <= 2 (K+1)/254 * max|worker - mean| (the bound
    core/sync.py ring_tolerance charges per round).  The QSR drift
    ordering must survive the noisy wire — asserted in run().
  * ring_ab() is the model-free check: the REAL smoke transformer trained
    twice from identical seeds, exact int-codes wire vs ring-int8, end-of-
    run loss delta and param divergence reported against ring_tolerance.
"""
from __future__ import annotations

import numpy as np


def simulate(schedule: str, *, k: int = 8, eta: float = 0.02,
             alpha: float = 0.25, beta: float = 0.4, steps: int = 200_000,
             b_loc: int = 1, sigma: float = 1.0, x0: float = 1.0,
             seed: int = 0, requant_hops: int = 0) -> float:
    """Returns the measured decay rate of log E[x^2] per unit slow-SDE time
    (t = steps * eta^2).

    requant_hops > 0 turns each sync's exact worker mean into the ring-int8
    noise model: every worker receives mean + err with err drawn uniformly
    inside the per-round re-quantization bound 2 (hops+1)/254 * max|delta|
    (all workers get the SAME err — the ring all-gathers one owner-computed
    value, so the wire noise is common-mode, not per-worker)."""
    rng = np.random.RandomState(seed)
    n_rep = 256  # independent replicates for expectation
    x = np.full((n_rep, k), x0)
    y = np.zeros((n_rep, k))

    def ring_mean(v):
        m = v.mean(axis=1, keepdims=True)
        if requant_hops:
            bound = 2.0 * (requant_hops + 1) / 254.0
            amax = np.abs(v - m).max(axis=1, keepdims=True)
            m = m + bound * amax * rng.uniform(-1.0, 1.0, m.shape)
        return m

    if schedule == "parallel":
        h = 1
    elif schedule == "inverse":
        h = max(1, int(beta / eta))
    elif schedule == "qsr":
        h = max(1, int((alpha / eta) ** 2))
    else:
        raise ValueError(schedule)

    times, vals = [], []
    for t in range(steps):
        xi = sigma * rng.randn(n_rep, k, b_loc).mean(axis=2)
        if schedule == "parallel":
            # all workers share the averaged gradient (global batch K*b_loc)
            gx = (x * (y ** 2 - 2 * y * xi)).mean(axis=1, keepdims=True)
            gy = ((1 + x ** 2) * (y - xi)).mean(axis=1, keepdims=True)
            x = x - eta * gx
            y = y - eta * gy
        else:
            gx = x * (y ** 2 - 2 * y * xi)
            gy = (1 + x ** 2) * (y - xi)
            x = x - eta * gx
            y = y - eta * gy
            if (t + 1) % h == 0:
                x[:] = ring_mean(x)
                y[:] = ring_mean(y)
        if (t + 1) % max(steps // 200, 1) == 0:
            ex2 = float((x.mean(axis=1) ** 2).mean())
            times.append((t + 1) * eta ** 2)  # slow-SDE time
            vals.append(ex2)

    # fit the log-linear decay rate over the un-saturated segment
    pts = [(tt, v) for tt, v in zip(times, vals)
           if 0.02 * x0 ** 2 < v < 0.95 * x0 ** 2]
    if len(pts) < 3:  # decayed too fast: use the first crossing time
        t_cross = next((tt for tt, v in zip(times, vals)
                        if v < 0.05 * x0 ** 2), times[-1])
        return float(np.log(20.0) / t_cross)
    ts = np.array([p[0] for p in pts])
    lv = np.log([p[1] for p in pts])
    slope = np.polyfit(ts, lv, 1)[0]
    return float(-slope)


def ring_ab(csv_rows: list | None = None, *, rounds: int = 4, h: int = 4,
            workers: int = 2, b_loc: int = 2, seq: int = 32) -> dict:
    """The model-free drift measurement: train the smoke transformer twice
    from identical seeds and data — exact int-codes wire vs ring-int8 —
    and report the end-of-run loss delta and max param divergence.  The
    divergence must stay within `ring_tolerance` of the engine's per-round
    delta-amax heuristic (4 h lr per round, the multihost harness bound)
    plus the output-dtype cast allowance: this is the measured price of
    int8 on every hop, the number §Wire modes quotes."""
    import jax
    import numpy as np

    from repro.configs import registry as R
    from repro.configs.base import RunConfig
    from repro.core import schedules
    from repro.core.engine import RoundEngine
    from repro.core.sync import ring_tolerance
    from repro.optim.lr import make_lr_fn

    cfg = R.get_smoke_config("starcoder2-3b")

    def train(wire):
        run_cfg = RunConfig(schedule="constant", h_base=h,
                            total_steps=rounds * h, remat=False,
                            sync_quantize=True, sync_wire=wire)
        eng = RoundEngine(cfg, run_cfg, workers=workers, b_loc=b_loc,
                          seq=seq, seed=0, layout="flat_sharded",
                          sync="blocking")
        lr_fn = make_lr_fn(run_cfg)
        state, t, losses = eng.init_state(), 0, []
        for _ in range(rounds):
            hh = schedules.get_h(run_cfg, t, lr_fn)
            state, m = eng.run_round(state, t, hh, lr_fn)
            losses.append(float(m["loss"]))
            t += hh
        return losses, eng.flush(state), run_cfg

    losses_e, st_e, _ = train("auto")
    losses_r, st_r, rc = train("ring-int8")
    div = excess = 0.0
    for b in st_e["params"]:
        a = np.asarray(st_e["params"][b], np.float32)
        g = np.asarray(st_r["params"][b], np.float32)
        if not a.size:
            continue
        d = np.abs(a - g)
        div = max(div, float(np.max(d)))
        # cast allowance: each round's anchor cast can straddle an output-
        # dtype rounding boundary, worth one quantum per round (the
        # multihost harness comparison rule)
        eps = (2.0 ** -7 if "bfloat16" in b else 2.0 ** -23) * rounds
        excess = max(excess, float(np.max(d - np.abs(a) * eps)))
    tol = ring_tolerance(workers, 4.0 * h * rc.peak_lr, rounds)
    loss_d = abs(losses_e[-1] - losses_r[-1])
    print(f"  ring A/B ({rounds} rounds x h={h}, {workers} workers): "
          f"final loss exact {losses_e[-1]:.4f} ring {losses_r[-1]:.4f} "
          f"(|delta| {loss_d:.2e})")
    print(f"  param divergence {div:.3e} (excess past cast allowance "
          f"{excess:.3e} vs ring_tolerance {tol:.3e})")
    assert all(np.isfinite(losses_r)), losses_r
    assert excess <= tol, (excess, tol)
    if csv_rows is not None:
        csv_rows.append(("sde_drift/ring_ab/loss_delta", "", f"{loss_d:.2e}"))
        csv_rows.append(("sde_drift/ring_ab/param_div", "", f"{div:.2e}"))
    return {"loss_delta": loss_d, "param_div": div, "excess": excess,
            "tol": tol}


def run(csv_rows: list | None = None, *, fast: bool = True) -> None:
    print("\n== Slow-SDE drift (Thm 3.1): sharpness-reduction rate ==")
    k = 8
    steps = 60_000 if fast else 200_000
    rates = {}
    for sched in ("parallel", "inverse", "qsr"):
        rates[sched] = simulate(sched, k=k, steps=steps)
        print(f"  {sched:10s} drift rate {rates[sched]:8.4f}")
    r_qsr = rates["qsr"] / max(rates["parallel"], 1e-9)
    r_inv = rates["inverse"] / max(rates["parallel"], 1e-9)
    print(f"  ratios vs parallel: QSR {r_qsr:.2f}x (theory ~K={k}x), "
          f"inverse {r_inv:.2f}x (theory in (1,K))")
    # the ordering predicted by Defs 3.1-3.3:
    assert rates["qsr"] > rates["inverse"] > 0.5 * rates["parallel"], rates
    assert r_qsr > 2.0, r_qsr   # K-amplified drift clearly visible
    # the ring wire's noise model must not disturb the QSR drift: K-1 hops
    # of re-quantization on every sync, ordering and amplification intact
    ring_rate = simulate("qsr", k=k, steps=steps, requant_hops=k - 1)
    r_ring = ring_rate / max(rates["parallel"], 1e-9)
    print(f"  qsr+ring-int8 noise model: rate {ring_rate:8.4f} "
          f"({r_ring:.2f}x parallel)")
    assert ring_rate > rates["inverse"], (ring_rate, rates)
    assert r_ring > 2.0, r_ring
    if csv_rows is not None:
        for s, r in rates.items():
            csv_rows.append((f"sde_drift/{s}", "", f"{r:.4f}"))
        csv_rows.append(("sde_drift/qsr_vs_parallel", "", f"{r_qsr:.2f}x"))
        csv_rows.append(("sde_drift/qsr_ring_noise", "", f"{ring_rate:.4f}"))
    ring_ab(csv_rows, rounds=3 if fast else 4)


if __name__ == "__main__":
    run(fast=False)
