"""Theorem 3.1 / Defs 3.1-3.3 validation: sharpness-reduction drift on a
minimizer manifold.

Toy loss with a manifold of minima and position-dependent sharpness
(label-noise form, the Blanc et al. 2020 / Li et al. 2021c mechanism):

    L(x, y) = 1/2 (1 + x^2) y^2            (expected loss)
    g_y     = (1 + x^2) (y - xi)           (label noise xi ~ N(0, s^2))
    g_x     = x (y^2 - 2 y xi)             (unbiased on the manifold)

Manifold Gamma = {y=0}; normal-direction Hessian lambda(x) = 1 + x^2, so
"flatter" means |x| smaller.  On Gamma the expected x-gradient vanishes;
the only force moving x is the SLOW drift from the y-diffusion:
E[g_x] = x * Var(y), with Var(y) set by the OU equilibrium of the
optimizer's own noise.  Defs 3.1-3.3 predict the decay rate of E[x^2]:
  1/(2B) for parallel SGD, K/(2B) for Local SGD with QSR (K times larger),
  in between for H ~ eta^-1.  We measure exactly those ratios.
"""
from __future__ import annotations

import numpy as np


def simulate(schedule: str, *, k: int = 8, eta: float = 0.02,
             alpha: float = 0.25, beta: float = 0.4, steps: int = 200_000,
             b_loc: int = 1, sigma: float = 1.0, x0: float = 1.0,
             seed: int = 0) -> float:
    """Returns the measured decay rate of log E[x^2] per unit slow-SDE time
    (t = steps * eta^2)."""
    rng = np.random.RandomState(seed)
    n_rep = 256  # independent replicates for expectation
    x = np.full((n_rep, k), x0)
    y = np.zeros((n_rep, k))

    if schedule == "parallel":
        h = 1
    elif schedule == "inverse":
        h = max(1, int(beta / eta))
    elif schedule == "qsr":
        h = max(1, int((alpha / eta) ** 2))
    else:
        raise ValueError(schedule)

    times, vals = [], []
    for t in range(steps):
        xi = sigma * rng.randn(n_rep, k, b_loc).mean(axis=2)
        if schedule == "parallel":
            # all workers share the averaged gradient (global batch K*b_loc)
            gx = (x * (y ** 2 - 2 * y * xi)).mean(axis=1, keepdims=True)
            gy = ((1 + x ** 2) * (y - xi)).mean(axis=1, keepdims=True)
            x = x - eta * gx
            y = y - eta * gy
        else:
            gx = x * (y ** 2 - 2 * y * xi)
            gy = (1 + x ** 2) * (y - xi)
            x = x - eta * gx
            y = y - eta * gy
            if (t + 1) % h == 0:
                x[:] = x.mean(axis=1, keepdims=True)
                y[:] = y.mean(axis=1, keepdims=True)
        if (t + 1) % max(steps // 200, 1) == 0:
            ex2 = float((x.mean(axis=1) ** 2).mean())
            times.append((t + 1) * eta ** 2)  # slow-SDE time
            vals.append(ex2)

    # fit the log-linear decay rate over the un-saturated segment
    pts = [(tt, v) for tt, v in zip(times, vals)
           if 0.02 * x0 ** 2 < v < 0.95 * x0 ** 2]
    if len(pts) < 3:  # decayed too fast: use the first crossing time
        t_cross = next((tt for tt, v in zip(times, vals)
                        if v < 0.05 * x0 ** 2), times[-1])
        return float(np.log(20.0) / t_cross)
    ts = np.array([p[0] for p in pts])
    lv = np.log([p[1] for p in pts])
    slope = np.polyfit(ts, lv, 1)[0]
    return float(-slope)


def run(csv_rows: list | None = None, *, fast: bool = True) -> None:
    print("\n== Slow-SDE drift (Thm 3.1): sharpness-reduction rate ==")
    k = 8
    steps = 60_000 if fast else 200_000
    rates = {}
    for sched in ("parallel", "inverse", "qsr"):
        rates[sched] = simulate(sched, k=k, steps=steps)
        print(f"  {sched:10s} drift rate {rates[sched]:8.4f}")
    r_qsr = rates["qsr"] / max(rates["parallel"], 1e-9)
    r_inv = rates["inverse"] / max(rates["parallel"], 1e-9)
    print(f"  ratios vs parallel: QSR {r_qsr:.2f}x (theory ~K={k}x), "
          f"inverse {r_inv:.2f}x (theory in (1,K))")
    # the ordering predicted by Defs 3.1-3.3:
    assert rates["qsr"] > rates["inverse"] > 0.5 * rates["parallel"], rates
    assert r_qsr > 2.0, r_qsr   # K-amplified drift clearly visible
    if csv_rows is not None:
        for s, r in rates.items():
            csv_rows.append((f"sde_drift/{s}", "", f"{r:.4f}"))
        csv_rows.append(("sde_drift/qsr_vs_parallel", "", f"{r_qsr:.2f}x"))


if __name__ == "__main__":
    run(fast=False)
