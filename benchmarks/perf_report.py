"""§Perf report: assemble the hillclimb iteration tables (baseline vs each
variant) from experiments/dryrun + experiments/perf records.

Record paths resolve relative to the REPO ROOT, not the caller's cwd, and a
missing or malformed record is a WARNING (stderr) + a skipped row, never a
crash: CI runs this report on checkouts that carry only a subset of the
experiment records, and the report's job is to show what is there."""
from __future__ import annotations

import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if __package__ in (None, ""):          # run as a script: python benchmarks/…
    sys.path.insert(0, _ROOT)
    sys.path.insert(0, os.path.join(_ROOT, "src"))   # repro.* for roofline

from benchmarks.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

PAIRS = {
    "starcoder2-3b x train_4k (dp, 16x16)": [
        ("baseline (paper-faithful, full remat)",
         "experiments/dryrun/starcoder2-3b__train_4k__single.json"),
        ("it1a remat=save_collectives",
         "experiments/perf/sc2_train_save_coll.json"),
        ("it1b seq-parallel residual",
         "experiments/perf/sc2_train_seqshard.json"),
        ("it1c both", "experiments/perf/sc2_train_both.json"),
        ("it2 seq-parallel + no remat",
         "experiments/perf/sc2_train_seq_noremat.json"),
        ("it3 seq-parallel + dots remat",
         "experiments/perf/sc2_train_seq_dots.json"),
    ],
    "kimi-k2-1t x train_4k (fsdp, 16x16)": [
        ("baseline (global argsort dispatch)",
         "experiments/dryrun/kimi-k2-1t-a32b__train_4k__single.json"),
        ("it1 shard-local MoE dispatch",
         "experiments/perf/kimi_train_moeshard.json"),
        ("it2 + seq-parallel residual",
         "experiments/perf/kimi_train_moeshard_seq.json"),
        ("it3 + dots remat",
         "experiments/perf/kimi_train_ms_seq_dots.json"),
        ("it4 shard_map all-to-all dispatch",
         "experiments/perf/kimi_train_shardmap.json"),
        ("it5 shard_map + microbatch=8",
         "experiments/perf/kimi_train_sm_mb8.json"),
    ],
    "kimi-k2-1t x train_4k (fsdp, 2x16x16 multi-pod)": [
        ("baseline", "experiments/dryrun/kimi-k2-1t-a32b__train_4k__multi.json"),
        ("opt: sharded dispatch + microbatch=8",
         "experiments/perf/kimi_train_multi_ms_mb8.json"),
    ],
    "gemma3-4b x decode_32k (dp, 16x16)": [
        ("baseline (batch-sharded cache)",
         "experiments/dryrun/gemma3-4b__decode_32k__single.json"),
        ("it1 flash-decode cache layout (seq over model)",
         "experiments/perf/gemma3_decode_seqmodel.json"),
        ("it2 + bf16-native QK/PV dots",
         "experiments/perf/gemma3_decode_seqmodel_bf16.json"),
    ],
}


def _metrics(rec):
    if "local_step" in rec:
        h = rec["full"].get("h") or 4
        m = {k: rec["local_step"][k] + rec["sync"][k] / h
             for k in ("flops", "bytes_accessed", "collective_bytes_total")}
    else:
        key = "prefill" if "prefill" in rec else "decode"
        m = {k: rec[key][k]
             for k in ("flops", "bytes_accessed", "collective_bytes_total")}
    mem = rec["full"]["per_device_memory"]
    m["temp_gib"] = mem["temp_bytes"] / 2**30
    m["compute_s"] = m["flops"] / PEAK_FLOPS
    m["memory_s"] = m["bytes_accessed"] / HBM_BW
    m["collective_s"] = m["collective_bytes_total"] / ICI_BW
    m["bound_s"] = max(m["compute_s"], m["memory_s"], m["collective_s"])
    return m


def run(csv_rows: list | None = None) -> None:
    print("\n== §Perf hillclimb results (per device, per step/call) ==")
    for pair, variants in PAIRS.items():
        print(f"\n--- {pair} ---")
        base = None
        print(f"{'variant':42s} {'compute':>8s} {'memory':>8s} {'coll':>8s} "
              f"{'bound':>8s} {'temp':>9s} {'vs base':>8s}")
        for label, path in variants:
            full = os.path.join(_ROOT, path)
            if not os.path.exists(full):
                print(f"{label:42s}   (missing)")
                print(f"perf_report: WARNING skipping missing record {path}",
                      file=sys.stderr)
                continue
            try:
                rec = json.load(open(full))
            except (json.JSONDecodeError, OSError) as e:
                print(f"{label:42s}   (unreadable)")
                print(f"perf_report: WARNING unreadable record {path}: {e}",
                      file=sys.stderr)
                continue
            if not rec.get("ok", True):
                print(f"{label:42s}   FAILED")
                continue
            try:
                m = _metrics(rec)
            except KeyError as e:
                print(f"{label:42s}   (malformed)")
                print(f"perf_report: WARNING record {path} missing {e}",
                      file=sys.stderr)
                continue
            if base is None:
                base = m
            ratio = m["bound_s"] / base["bound_s"]
            print(f"{label:42s} {m['compute_s']:8.3f} {m['memory_s']:8.3f} "
                  f"{m['collective_s']:8.3f} {m['bound_s']:8.3f} "
                  f"{m['temp_gib']:8.1f}G {ratio:7.2%}")
            if csv_rows is not None:
                csv_rows.append((f"perf/{pair}/{label}",
                                 f"{1e6*m['bound_s']:.0f}", f"{ratio:.3f}"))


if __name__ == "__main__":
    run()
