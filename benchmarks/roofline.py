"""Roofline analysis (deliverable g): convert dry-run records into the three
roofline terms per (arch x shape x mesh), identify the dominant bottleneck,
and report MODEL_FLOPS / HLO_FLOPs utilization.

Hardware constants (TPU v5e):
    peak bf16 compute   197 TFLOP/s per chip
    HBM bandwidth       819 GB/s per chip
    ICI link bandwidth  ~50 GB/s per link

Terms (seconds per training step / per serving call, PER DEVICE):
    compute    = HLO_FLOPs / peak
    memory     = HLO_bytes / HBM_bw        (upper bound: XLA's bytes-accessed
                                            counts per-op operands+results)
    collective = collective_bytes / ICI_bw

For training, per-step cost of the paper-faithful local method is
    local_step + sync / H        (QSR's whole point: sync amortized by H)
vs the data-parallel baseline's parallel_step.
"""
from __future__ import annotations

import glob
import json
import math
import os

from repro.configs import registry as R
from repro.models import api, param as pm
from repro.models.param import is_def

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_params(arch: str) -> tuple[int, int]:
    """(total params N, active params N_active) — N_active discounts MoE
    expert weights by top_k/n_experts."""
    cfg = R.get_config(arch)
    defs = api.get_module(cfg).param_defs(cfg)
    total = active = 0
    for d in __import__("jax").tree.leaves(defs, is_leaf=is_def):
        n = math.prod(d.shape)
        total += n
        frac = (cfg.top_k / cfg.n_experts
                if cfg.n_experts and "experts" in d.axes else 1.0)
        active += int(n * frac)
    return total, active


def model_flops_per_step(arch: str, shape: dict, *, n_devices: int) -> float:
    """6 * N_active * D tokens (fwd+bwd), per device."""
    _, n_active = model_params(arch)
    tokens = shape["global_batch"] * shape["seq_len"]
    return 6.0 * n_active * tokens / n_devices


def terms(metrics: dict) -> dict:
    return {
        "compute_s": metrics["flops"] / PEAK_FLOPS,
        "memory_s": metrics["bytes_accessed"] / HBM_BW,
        "collective_s": metrics["collective_bytes_total"] / ICI_BW,
    }


def dominant(t: dict) -> str:
    return max(t, key=t.get).replace("_s", "")


def analyze_record(rec: dict) -> dict | None:
    from repro.launch.shapes import SHAPES
    if not rec.get("ok"):
        return None
    arch, shape_name = rec["arch"], rec["shape"]
    shape = SHAPES[shape_name]
    nd = rec["n_devices"]
    out = {"arch": arch, "shape": shape_name, "mesh": rec["mesh"],
           "policy": rec["policy"]}

    if "local_step" in rec:
        h = rec["full"].get("h") or 4
        per_step = {k: rec["local_step"][k] + rec["sync"][k] / h
                    for k in ("flops", "bytes_accessed",
                              "collective_bytes_total")}
        t = terms(per_step)
        tp = terms(rec["parallel_step"])
        mf = model_flops_per_step(arch, {"global_batch": shape.global_batch,
                                         "seq_len": shape.seq_len},
                                  n_devices=nd)
        out.update({
            "fn": f"local_step+sync/H (H={h})", "terms": t,
            "dominant": dominant(t),
            "parallel_terms": tp, "parallel_dominant": dominant(tp),
            "model_flops": mf,
            "useful_flops_ratio": mf / max(per_step["flops"], 1.0),
            "sync_coll_bytes": rec["sync"]["collective_bytes_total"],
            "local_coll_bytes": rec["local_step"]["collective_bytes_total"],
            "parallel_coll_bytes":
                rec["parallel_step"]["collective_bytes_total"],
            "step_time_bound_s": max(t.values()),
            "parallel_step_time_bound_s": max(tp.values()),
        })
    else:
        key = "prefill" if "prefill" in rec else "decode"
        t = terms(rec[key])
        _, n_active = model_params(arch)
        tokens = rec[key + "_tokens"] if key + "_tokens" in rec else (
            shape.global_batch * (shape.seq_len if key == "prefill" else 1))
        mf = 2.0 * n_active * tokens / nd
        out.update({
            "fn": key, "terms": t, "dominant": dominant(t),
            "model_flops": mf,
            "useful_flops_ratio": mf / max(rec[key]["flops"], 1.0),
            "step_time_bound_s": max(t.values()),
        })
    out["memory_gib"] = {
        k: v / 2**30 for k, v in rec["full"]["per_device_memory"].items()}
    out["fits_hbm_16g"] = (
        rec["full"]["per_device_memory"]["argument_bytes"]
        + rec["full"]["per_device_memory"]["temp_bytes"]) < 16 * 2**30
    return out


def load_records(pattern: str = "experiments/dryrun/*.json") -> list[dict]:
    out = []
    for f in sorted(glob.glob(pattern)):
        if os.path.basename(f).startswith("test_"):
            continue
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def run(csv_rows: list | None = None, pattern="experiments/dryrun/*.json"):
    recs = [analyze_record(r) for r in load_records(pattern)]
    recs = [r for r in recs if r]
    if not recs:
        print("\n== Roofline: no dry-run records found "
              "(run scripts/run_dryrun_matrix.sh first) ==")
        return
    print("\n== Roofline (per device, per step/call) ==")
    hdr = (f"{'arch':17s} {'shape':12s} {'mesh':8s} {'compute':>9s} "
           f"{'memory':>9s} {'collect':>9s} {'dom':>8s} {'useful':>7s}")
    print(hdr)
    for r in sorted(recs, key=lambda x: (x['arch'], x['shape'], x['mesh'])):
        t = r["terms"]
        print(f"{r['arch']:17s} {r['shape']:12s} {r['mesh']:8s} "
              f"{t['compute_s']:9.4f} {t['memory_s']:9.4f} "
              f"{t['collective_s']:9.4f} {r['dominant']:>8s} "
              f"{100*r['useful_flops_ratio']:6.1f}%")
        if csv_rows is not None:
            csv_rows.append((
                f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
                f"{1e6*r['step_time_bound_s']:.1f}",
                r["dominant"]))


if __name__ == "__main__":
    run()
