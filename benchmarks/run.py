"""Benchmark harness — one module per paper table/figure.

  table1_comm          Tables 1-3 / Fig. 1 communication volumes
  table4_walltime      Table 4 / App. F wall-clock model
  sde_drift            Theorem 3.1 Slow-SDE drift ratios
  fig2_generalization  Fig. 2 generalization ordering (laptop scale)
  roofline             §Roofline terms from the dry-run records
  microbench           us/call for the hot kernels (CPU reference path)

Prints a ``name,us_per_call,derived`` CSV at the end.
"""
from __future__ import annotations

import os
import sys
import time


def _microbench(csv_rows: list) -> None:
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref

    print("\n== kernel microbench (CPU jnp reference path) ==")
    cases = {
        "rms_norm/4x1024x2048": lambda: ref.rms_norm(
            jax.random.normal(jax.random.PRNGKey(0), (4, 1024, 2048)),
            jnp.ones((2048,))),
        "attention/1x512x8x64": lambda: ref.attention(
            jax.random.normal(jax.random.PRNGKey(0), (1, 512, 8, 64)),
            jax.random.normal(jax.random.PRNGKey(1), (1, 512, 2, 64)),
            jax.random.normal(jax.random.PRNGKey(2), (1, 512, 2, 64))),
        "adamw/1M": lambda: ref.adamw_update(
            jnp.ones((1 << 20,)), jnp.zeros((1 << 20,)),
            jnp.zeros((1 << 20,)), jnp.ones((1 << 20,)), lr=1e-3, beta1=0.9,
            beta2=0.999, eps=1e-8, weight_decay=0.1, step=1.0),
    }
    import jax as _jax
    for name, fn in cases.items():
        jitted = _jax.jit(fn)
        _jax.block_until_ready(jitted())  # compile
        t0 = time.perf_counter()
        n = 5
        for _ in range(n):
            _jax.block_until_ready(jitted())
        us = (time.perf_counter() - t0) / n * 1e6
        print(f"  {name:28s} {us:10.1f} us/call")
        csv_rows.append((f"microbench/{name}", f"{us:.1f}", ""))


def main() -> None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from benchmarks import (fig2_generalization, perf_report, roofline,
                            sde_drift, table1_comm, table4_walltime)

    csv_rows: list = []
    table1_comm.run(csv_rows)
    table1_comm.sync_lowering(csv_rows)
    table4_walltime.run(csv_rows)
    sde_drift.run(csv_rows)
    fast = os.environ.get("REPRO_BENCH_FAST", "1") == "1"
    fig2_generalization.run(csv_rows, steps=120 if fast else 400)
    roofline.run(csv_rows)
    perf_report.run(csv_rows)
    _microbench(csv_rows)

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
