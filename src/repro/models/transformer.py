"""Decoder-only transformer LM (dense + MoE + prefix-LM variants).

Covers: starcoder2-3b, gemma3-4b, qwen1.5-110b, phi3-medium-14b (dense),
dbrx-132b, kimi-k2-1t (MoE, via cfg.n_experts), paligemma-3b (prefix-LM over
stub image embeddings).  Layers are `lax.scan`-stacked so HLO size is
depth-independent; per-layer sliding windows ride along as scan xs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import moe as moe_mod
from repro.models.param import ParamDef


def _layer_defs(cfg: ModelConfig) -> dict:
    d = {"ln1": cm.norm_defs(cfg), "ln2": cm.norm_defs(cfg),
         "attn": cm.attn_defs(cfg)}
    if cfg.n_experts > 0:
        d["moe"] = moe_mod.moe_defs(cfg)
    else:
        d["mlp"] = cm.mlp_defs(cfg)
    return d


def param_defs(cfg: ModelConfig) -> dict:
    return {
        "embed": cm.embed_defs(cfg),
        "layers": cm.stack_defs(_layer_defs(cfg), cfg.n_layers),
        "final_norm": cm.norm_defs(cfg),
    }


def _windows(cfg: ModelConfig) -> jnp.ndarray:
    return jnp.asarray([cfg.layer_window(i) for i in range(cfg.n_layers)],
                       jnp.int32)


def _block(cfg, p, h, *, positions, window, prefix_len, cache=None,
           cache_pos=None, ring=False):
    a, new_cache = cm.attn_apply(
        cfg, p["attn"], cm.norm_apply(cfg, p["ln1"], h), positions=positions,
        layer_window=window, prefix_len=prefix_len, cache=cache,
        cache_pos=cache_pos, ring=ring)
    h = h + checkpoint_name(a, "attn_out")   # post-all-reduce activation
    hn = cm.norm_apply(cfg, p["ln2"], h)
    if cfg.n_experts > 0:
        f, aux = moe_mod.moe_apply(cfg, p["moe"], hn)
    else:
        f, aux = cm.mlp_apply(cfg, p["mlp"], hn), jnp.zeros((), jnp.float32)
    return h + checkpoint_name(f, "mlp_out"), aux, new_cache


def _remat_wrap(body, remat):
    """remat=True -> full remat; remat="save_collectives" -> recompute
    everything EXCEPT the post-all-reduce block outputs, so the forward
    tensor-parallel collectives never re-run in the backward pass."""
    if remat == "save_collectives":
        pol = jax.checkpoint_policies.save_only_these_names(
            "attn_out", "mlp_out")
        return jax.checkpoint(body, policy=pol)
    if remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(body, policy=pol)
    if remat:
        return jax.checkpoint(body)
    return body


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            prefix_embeds: jax.Array | None = None, remat=True,
            act_constraint=None):
    """Full-sequence forward. Returns (logits [B,S,V], aux_loss).

    prefix_embeds [B,P,D]: bidirectional prefix (PaliGemma image tokens);
    logits are returned for the *text* positions only.
    """
    h = cm.embed_apply(cfg, params["embed"], tokens)
    prefix_len = 0
    if prefix_embeds is not None:
        prefix_len = prefix_embeds.shape[1]
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    positions = jnp.arange(h.shape[1])

    def body(carry, xs):
        hh, aux = carry
        lp, window = xs
        hh, a, _ = _block(cfg, lp, hh, positions=positions, window=window,
                          prefix_len=prefix_len)
        if act_constraint is not None:
            hh = act_constraint(hh)
        return (hh, aux + a), None

    body = _remat_wrap(body, remat)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                               (params["layers"], _windows(cfg)),
                               unroll=cm.scan_unroll())
    h = cm.norm_apply(cfg, params["final_norm"], h)
    if prefix_len:
        h = h[:, prefix_len:]
    return cm.unembed_apply(cfg, params["embed"], h), aux


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *, remat=True,
            act_constraint=None):
    logits, aux = forward(cfg, params, batch["tokens"],
                          prefix_embeds=batch.get("prefix_embeds"),
                          remat=remat, act_constraint=act_constraint)
    return cm.lm_loss(logits, batch["labels"]) + cfg.router_aux_coef * aux


# --------------------------------------------------------------------------
# Serving: KV cache, prefill, single-token decode
# --------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               window_override: int = 0):
    """Abstract KV cache.  window_override>0 enables the sub-quadratic
    long-context mode: each layer's cache is capped at its own sliding
    window (or the override for full-attention layers) and served as a ring
    buffer.  With window_override=0 the cache holds the full stream (layer
    windows are then enforced by masking only, so prefill can always write
    the whole prompt)."""
    if window_override > 0:
        # Stacked-scan cache requires uniform length; use the max needed.
        ln = max(min(max_len, cfg.layer_window(i) or window_override)
                 for i in range(cfg.n_layers))
    else:
        ln = max_len
    kv = (cfg.n_layers, batch, ln, cfg.n_kv_heads, cfg.hd)
    return {"k": jax.ShapeDtypeStruct(kv, dtype),
            "v": jax.ShapeDtypeStruct(kv, dtype)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               window_override: int = 0):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_spec(cfg, batch, max_len, dtype, window_override))


def _scan_cached(cfg, params, h, *, positions, prefix_len, cache, cache_pos,
                 ring=False):
    def body(carry, xs):
        hh = carry
        lp, window, ck, cv = xs
        hh, _, nc = _block(cfg, lp, hh, positions=positions, window=window,
                           prefix_len=prefix_len, cache={"k": ck, "v": cv},
                           cache_pos=cache_pos, ring=ring)
        return hh, (nc["k"], nc["v"])

    h, (nk, nv) = jax.lax.scan(
        body, h, (params["layers"], _windows(cfg), cache["k"], cache["v"]),
        unroll=cm.scan_unroll())
    return h, {"k": nk, "v": nv}


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, cache: dict, *,
            prefix_embeds: jax.Array | None = None):
    """Run the prompt through the model, filling the cache from position 0.
    Returns (logits for the last position [B,V], cache)."""
    h = cm.embed_apply(cfg, params["embed"], tokens)
    prefix_len = 0
    if prefix_embeds is not None:
        prefix_len = prefix_embeds.shape[1]
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    positions = jnp.arange(h.shape[1])
    h, cache = _scan_cached(cfg, params, h, positions=positions,
                            prefix_len=prefix_len, cache=cache, cache_pos=0)
    h = cm.norm_apply(cfg, params["final_norm"], h[:, -1:])
    return cm.unembed_apply(cfg, params["embed"], h)[:, 0], cache


def decode_step(cfg: ModelConfig, params: dict, token: jax.Array, cache: dict,
                pos, *, prefix_len: int = 0, ring: bool = False):
    """One decode step. token [B] int32; pos scalar int32 (aligned batch) or
    [B] int32 (ragged continuous batching — each slot writes/attends at its
    own position).  ring=True: the cache is a circular buffer shorter than
    the stream (sub-quadratic long-context serving).  On the Pallas
    backends every per-layer attention here lowers to the single-query
    `flash_decode` kernel (kernels/flash_attention.py), which takes the
    traced per-layer window, ragged offsets, and ring key positions as
    runtime operands.  Returns (logits [B,V], new cache)."""
    h = cm.embed_apply(cfg, params["embed"], token[:, None])
    pos = jnp.asarray(pos)
    # pos may be scalar (aligned batch) or [B] (ragged continuous batching)
    positions = pos[None, None] if pos.ndim == 0 else pos[:, None]
    h, cache = _scan_cached(cfg, params, h, positions=positions,
                            prefix_len=prefix_len, cache=cache, cache_pos=pos,
                            ring=ring)
    h = cm.norm_apply(cfg, params["final_norm"], h)
    return cm.unembed_apply(cfg, params["embed"], h)[:, 0], cache
