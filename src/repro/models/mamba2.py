"""Mamba2 — SSD (state-space duality), arXiv:2405.21060.

Chunked SSD: intra-chunk contributions are a masked quadratic form (dense,
MXU-friendly), inter-chunk contributions flow through a `lax.scan` state
recurrence — depth- and length-scalable, O(S·Q) instead of O(S^2).
Decode is a single state update per token: the sub-quadratic path used by
the `long_500k` input shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.errors import ShapeError
from repro.kernels import ops as kops
from repro.models import common as cm
from repro.models.param import ParamDef


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, n_heads, conv_dim


def mixer_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_inner, h, conv_dim = dims(cfg)
    return {
        "wz": ParamDef((d, d_inner), ("embed", "mlp")),
        "wxBC": ParamDef((d, conv_dim), ("embed", "conv_dim")),
        "wdt": ParamDef((d, h), ("embed", "heads")),
        "conv_w": ParamDef((cfg.ssm_conv, conv_dim), (None, "conv_dim")),
        "conv_b": ParamDef((conv_dim,), ("conv_dim",), "zeros"),
        "A_log": ParamDef((h,), ("heads",), "ones"),
        "dt_bias": ParamDef((h,), ("heads",), "zeros"),
        "D": ParamDef((h,), ("heads",), "ones"),
        "norm": ParamDef((d_inner,), ("mlp",), "ones"),
        "wout": ParamDef((d_inner, d), ("mlp", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x [B,S,C], w [K,C]."""
    k, c = w.shape
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype), window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=c)
    return out + b.astype(x.dtype)


def ssd_chunked(x, dt, A, B_, C_, D, chunk: int, initial_state=None):
    """SSD over a full sequence.

    x [B,S,H,P]; dt [B,S,H] (>0); A [H] (<0); B_,C_ [B,S,N]; D [H].
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    b, s, h, p = x.shape
    n = B_.shape[-1]
    q = min(chunk, s)
    if s % q != 0:
        raise ShapeError(f"seq len {s} not divisible by chunk {q}")
    nc = s // q
    f32 = jnp.float32

    xc = x.reshape(b, nc, q, h, p).astype(f32)
    dtc = dt.reshape(b, nc, q, h).astype(f32)
    Bc = B_.reshape(b, nc, q, n).astype(f32)
    Cc = C_.reshape(b, nc, q, n).astype(f32)
    a = dtc * A.astype(f32)                            # [B,nc,Q,H], negative
    cum = jnp.cumsum(a, axis=2)                        # running log-decay

    # ---- intra-chunk: masked quadratic form ----
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,Qi,Qj,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(li), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)       # [B,nc,Q,Q]
    M = scores[..., None] * L * dtc[:, :, None, :, :]    # weight by dt_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc)

    # ---- chunk-final states ----
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)           # [B,nc,Q,H]
    s_c = jnp.einsum("bcjh,bcjhp,bcjn->bchpn", dec_end * dtc, xc, Bc)
    chunk_dec = jnp.exp(cum[:, :, -1, :])                # [B,nc,H]

    # ---- inter-chunk recurrence ----
    h0 = (jnp.zeros((b, h, p, n), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(hprev, inp):
        sc, dec = inp                                    # [B,H,P,N], [B,H]
        hnew = hprev * dec[:, :, None, None] + sc
        return hnew, hprev

    s_cT = jnp.moveaxis(s_c, 1, 0)                       # [nc,B,H,P,N]
    decT = jnp.moveaxis(chunk_dec, 1, 0)                 # [nc,B,H]
    h_last, h_in = jax.lax.scan(step, h0, (s_cT, decT),
                                unroll=cm.scan_unroll())
    h_in = jnp.moveaxis(h_in, 0, 1)                      # state entering chunk

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, h_in, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), h_last


def mixer_apply(cfg: ModelConfig, p: dict, u: jax.Array, *,
                cache: dict | None = None, initial_state=None):
    """u [B,S,d_model] -> (out, new_cache | final_state).

    cache (decode): {"conv": [B,K-1,Cd], "ssm": [B,H,P,N]} — S must be 1.
    """
    b, s, _ = u.shape
    d_inner, h, conv_dim = dims(cfg)
    n, pdim = cfg.ssm_state, cfg.ssm_headdim
    z = u @ p["wz"]
    xBC = u @ p["wxBC"]
    dt_raw = u @ p["wdt"] + p["dt_bias"].astype(u.dtype)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    new_cache = None
    if cache is not None:
        window = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC], axis=1)
        conv_out = (jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                               p["conv_w"].astype(jnp.float32))
                    + p["conv_b"].astype(jnp.float32))[:, None]
        xBC_c = jax.nn.silu(conv_out).astype(u.dtype)
        xs = xBC_c[..., :d_inner].reshape(b, 1, h, pdim)
        B_ = xBC_c[..., d_inner:d_inner + n]
        C_ = xBC_c[..., d_inner + n:]
        # single-step state update
        hs = cache["ssm"].astype(jnp.float32)            # [B,H,P,N]
        dec = jnp.exp(dt[:, 0, :] * A[None])             # [B,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0, :], xs[:, 0].astype(jnp.float32),
                         B_[:, 0].astype(jnp.float32))
        hs = hs * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", C_[:, 0].astype(jnp.float32), hs)
        y = y + xs[:, 0].astype(jnp.float32) * p["D"].astype(jnp.float32)[None, :, None]
        y = y.reshape(b, 1, d_inner).astype(u.dtype)
        new_cache = {"conv": window[:, 1:], "ssm": hs}
    else:
        xBC_c = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
        xs = xBC_c[..., :d_inner].reshape(b, s, h, pdim)
        B_ = xBC_c[..., d_inner:d_inner + n]
        C_ = xBC_c[..., d_inner + n:]
        y, final = ssd_chunked(xs, dt, A, B_, C_, p["D"], cfg.ssm_chunk,
                               initial_state=initial_state)
        y = y.reshape(b, s, d_inner)
        new_cache = {"conv": xBC[:, -(cfg.ssm_conv - 1):, :], "ssm": final}

    y = kops.rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["wout"], new_cache


# --------------------------------------------------------------------------
# Full mamba2 LM
# --------------------------------------------------------------------------

def _layer_defs(cfg: ModelConfig) -> dict:
    return {"ln": cm.norm_defs(cfg), "mixer": mixer_defs(cfg)}


def param_defs(cfg: ModelConfig) -> dict:
    return {
        "embed": cm.embed_defs(cfg),
        "layers": cm.stack_defs(_layer_defs(cfg), cfg.n_layers),
        "final_norm": cm.norm_defs(cfg),
    }


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            remat: bool = True, prefix_embeds=None):
    h = cm.embed_apply(cfg, params["embed"], tokens)
    if prefix_embeds is not None:
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)

    def body(hh, lp):
        out, _ = mixer_apply(cfg, lp["mixer"], cm.norm_apply(cfg, lp["ln"], hh))
        return hh + out, None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["layers"], unroll=cm.scan_unroll())
    h = cm.norm_apply(cfg, params["final_norm"], h)
    if prefix_embeds is not None:
        h = h[:, prefix_embeds.shape[1]:]
    return cm.unembed_apply(cfg, params["embed"], h), jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *, remat=True):
    logits, _ = forward(cfg, params, batch["tokens"], remat=remat)
    return cm.lm_loss(logits, batch["labels"])


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               window_override: int = 0):
    del max_len, window_override  # state size is O(1) in sequence length
    d_inner, h, conv_dim = dims(cfg)
    l = cfg.n_layers
    return {
        "conv": jax.ShapeDtypeStruct((l, batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "ssm": jax.ShapeDtypeStruct((l, batch, h, cfg.ssm_headdim, cfg.ssm_state),
                                    jnp.float32),
    }


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16, window_override=0):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        cache_spec(cfg, batch, max_len, dtype, window_override))


def _scan_cached(cfg, params, h, cache):
    def body(hh, xs):
        lp, cc, cs = xs
        out, nc = mixer_apply(cfg, lp["mixer"], cm.norm_apply(cfg, lp["ln"], hh),
                              cache={"conv": cc, "ssm": cs})
        return hh + out, (nc["conv"], nc["ssm"])

    h, (nconv, nssm) = jax.lax.scan(
        body, h, (params["layers"], cache["conv"], cache["ssm"]),
        unroll=cm.scan_unroll())
    return h, {"conv": nconv, "ssm": nssm}


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, cache: dict,
            **_):
    """Full-sequence prefill; cache becomes the post-prompt SSM/conv state."""
    h = cm.embed_apply(cfg, params["embed"], tokens)

    def body(hh, lp):
        out, nc = mixer_apply(cfg, lp["mixer"], cm.norm_apply(cfg, lp["ln"], hh))
        return hh + out, (nc["conv"].astype(cache["conv"].dtype), nc["ssm"])

    h, (nconv, nssm) = jax.lax.scan(body, h, params["layers"],
                                    unroll=cm.scan_unroll())
    h = cm.norm_apply(cfg, params["final_norm"], h[:, -1:])
    logits = cm.unembed_apply(cfg, params["embed"], h)[:, 0]
    return logits, {"conv": nconv, "ssm": nssm}


def decode_step(cfg: ModelConfig, params: dict, token: jax.Array, cache: dict,
                pos, *, prefix_len: int = 0, ring: bool = False):
    del pos, prefix_len, ring  # state carries all history
    h = cm.embed_apply(cfg, params["embed"], token[:, None])
    h, cache = _scan_cached(cfg, params, h, cache)
    h = cm.norm_apply(cfg, params["final_norm"], h)
    return cm.unembed_apply(cfg, params["embed"], h)[:, 0], cache
