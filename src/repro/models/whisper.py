"""Whisper (arXiv:2212.04356) — encoder-decoder transformer backbone.

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: `input_specs()` supplies precomputed frame embeddings
[B, enc_seq=1500, d_model].  We implement the full transformer encoder and
the causal decoder with cross-attention.  Hardware adaptation note (see
DESIGN.md): learned absolute positions are replaced by RoPE on the decoder
(length-extrapolable; whisper's 448-token learned table cannot express the
assigned 32k/500k decode shapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.param import ParamDef


def _enc_layer_defs(cfg: ModelConfig) -> dict:
    return {"ln1": cm.norm_defs(cfg), "ln2": cm.norm_defs(cfg),
            "attn": cm.attn_defs(cfg), "mlp": cm.mlp_defs(cfg)}


def _dec_layer_defs(cfg: ModelConfig) -> dict:
    return {"ln1": cm.norm_defs(cfg), "ln2": cm.norm_defs(cfg),
            "ln3": cm.norm_defs(cfg), "attn": cm.attn_defs(cfg),
            "xattn": cm.attn_defs(cfg), "mlp": cm.mlp_defs(cfg)}


def param_defs(cfg: ModelConfig) -> dict:
    return {
        "embed": cm.embed_defs(cfg),
        "enc_layers": cm.stack_defs(_enc_layer_defs(cfg), cfg.n_enc_layers),
        "enc_norm": cm.norm_defs(cfg),
        "dec_layers": cm.stack_defs(_dec_layer_defs(cfg), cfg.n_layers),
        "final_norm": cm.norm_defs(cfg),
    }


def encode(cfg: ModelConfig, params: dict, frames: jax.Array, *,
           remat: bool = True) -> jax.Array:
    """frames [B, enc_seq, d_model] (stub frontend output) -> memory."""
    positions = jnp.arange(frames.shape[1])

    def body(hh, lp):
        a, _ = cm.attn_apply(cfg, lp["attn"], cm.norm_apply(cfg, lp["ln1"], hh),
                             positions=positions, use_rope=False,
                             kv_source=cm.norm_apply(cfg, lp["ln1"], hh))
        hh = hh + a
        hh = hh + cm.mlp_apply(cfg, lp["mlp"], cm.norm_apply(cfg, lp["ln2"], hh))
        return hh, None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, frames, params["enc_layers"],
                        unroll=cm.scan_unroll())
    return cm.norm_apply(cfg, params["enc_norm"], h)


def _dec_block(cfg, lp, h, memory, *, positions, cache=None, cache_pos=None,
               ring=False):
    a, nc = cm.attn_apply(cfg, lp["attn"], cm.norm_apply(cfg, lp["ln1"], h),
                          positions=positions, cache=cache,
                          cache_pos=cache_pos, ring=ring)
    h = h + a
    x, _ = cm.attn_apply(cfg, lp["xattn"], cm.norm_apply(cfg, lp["ln2"], h),
                         positions=positions, kv_source=memory)
    h = h + x
    return h + cm.mlp_apply(cfg, lp["mlp"], cm.norm_apply(cfg, lp["ln3"], h)), nc


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            frames: jax.Array, remat: bool = True):
    """Teacher-forced training forward: (logits [B,S,V], aux=0)."""
    memory = encode(cfg, params, frames, remat=remat)
    h = cm.embed_apply(cfg, params["embed"], tokens)
    positions = jnp.arange(h.shape[1])

    def body(hh, lp):
        hh, _ = _dec_block(cfg, lp, hh, memory, positions=positions)
        return hh, None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["dec_layers"],
                        unroll=cm.scan_unroll())
    h = cm.norm_apply(cfg, params["final_norm"], h)
    return cm.unembed_apply(cfg, params["embed"], h), jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *, remat=True):
    logits, _ = forward(cfg, params, batch["tokens"], frames=batch["frames"],
                        remat=remat)
    return cm.lm_loss(logits, batch["labels"])


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               window_override: int = 0):
    ln = min(max_len, window_override) if window_override else max_len
    kv = (cfg.n_layers, batch, ln, cfg.n_kv_heads, cfg.hd)
    mem = (batch, cfg.enc_seq, cfg.d_model)
    return {"k": jax.ShapeDtypeStruct(kv, dtype),
            "v": jax.ShapeDtypeStruct(kv, dtype),
            "memory": jax.ShapeDtypeStruct(mem, dtype)}


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16, window_override=0):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        cache_spec(cfg, batch, max_len, dtype, window_override))


def _scan_cached(cfg, params, h, memory, *, positions, cache_pos, cache,
                 ring=False):
    def body(hh, xs):
        lp, ck, cv = xs
        hh, nc = _dec_block(cfg, lp, hh, memory, positions=positions,
                            cache={"k": ck, "v": cv}, cache_pos=cache_pos,
                            ring=ring)
        return hh, (nc["k"], nc["v"])

    h, (nk, nv) = jax.lax.scan(body, h,
                               (params["dec_layers"], cache["k"], cache["v"]),
                               unroll=cm.scan_unroll())
    return h, nk, nv


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, cache: dict, *,
            frames: jax.Array | None = None, **_):
    """Encode audio (stub frames) and run the decoder prompt."""
    if frames is not None:
        memory = encode(cfg, params, frames, remat=False)
    else:
        memory = cache["memory"]
    h = cm.embed_apply(cfg, params["embed"], tokens)
    positions = jnp.arange(h.shape[1])
    h, nk, nv = _scan_cached(cfg, params, h, memory, positions=positions,
                             cache_pos=0, cache=cache)
    h = cm.norm_apply(cfg, params["final_norm"], h[:, -1:])
    logits = cm.unembed_apply(cfg, params["embed"], h)[:, 0]
    return logits, {"k": nk, "v": nv, "memory": memory.astype(cache["memory"].dtype)}


def decode_step(cfg: ModelConfig, params: dict, token: jax.Array, cache: dict,
                pos, *, prefix_len: int = 0, ring: bool = False):
    del prefix_len
    h = cm.embed_apply(cfg, params["embed"], token[:, None])
    positions = jnp.asarray(pos)[None, None]
    h, nk, nv = _scan_cached(cfg, params, h, cache["memory"].astype(h.dtype),
                             positions=positions, cache_pos=pos,
                             cache=cache, ring=ring)
    h = cm.norm_apply(cfg, params["final_norm"], h)
    logits = cm.unembed_apply(cfg, params["embed"], h)[:, 0]
    return logits, {"k": nk, "v": nv, "memory": cache["memory"]}
