"""Mixture-of-Experts FFN with top-k routing and capacity-bounded dispatch.

Dispatch is sort-based (argsort by expert id + scatter into an [E, C, d]
capacity buffer) rather than the GShard one-hot einsum: for kimi-k2's 384
experts the one-hot dispatch tensor would be ~40x larger than the buffer.
The expert axis is sharded over the `data` mesh axis (expert parallelism);
the token->expert scatter therefore lowers to an all-to-all in the HLO.

Covers dbrx-132b (16e top-4) and kimi-k2 (384e top-8 + 1 shared expert).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.param import ParamDef

# Expert-parallel dispatch granularity (set by the runtime, see
# core.local_update.make_loss): 1 = global argsort dispatch; n>1 = shard-
# local dispatch with per-shard capacity — the only cross-shard movement is
# the token->expert all-to-all (GSPMD-friendly; §Perf pair 1).
_DISPATCH_SHARDS = 1
_DISPATCH_MODE = "auto"       # auto | global | sharded | shard_map
_DISPATCH_MESH = None         # Mesh for the shard_map path


def set_dispatch_shards(n: int) -> None:
    global _DISPATCH_SHARDS
    _DISPATCH_SHARDS = max(1, int(n))


def set_dispatch(mode: str = "auto", mesh=None) -> None:
    global _DISPATCH_MODE, _DISPATCH_MESH
    _DISPATCH_MODE = mode
    _DISPATCH_MESH = mesh


def moe_defs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    defs = {
        "router": ParamDef((d, e), ("embed", None)),
        "wi": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "wg": ParamDef((e, d, f), ("experts", "embed", "mlp")),
        "wo": ParamDef((e, f, d), ("experts", "mlp", "embed")),
    }
    if cfg.n_shared_experts:
        defs["shared"] = cm.mlp_defs(cfg, d_ff=cfg.d_ff * cfg.n_shared_experts)
    return defs


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # pad to a multiple of 8


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array):
    """x [B,S,d] -> (out [B,S,d], aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    if _DISPATCH_MODE == "shard_map" and _DISPATCH_MESH is not None:
        return _moe_apply_shard_map(cfg, p, x, _DISPATCH_MESH)
    if _DISPATCH_SHARDS > 1 and t % _DISPATCH_SHARDS == 0:
        return _moe_apply_sharded(cfg, p, x, _DISPATCH_SHARDS)
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # [T,E]
    top_p, top_i = jax.lax.top_k(probs, k)                      # [T,k]
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)           # renormalize

    # ---- load-balance aux loss (Switch/GShard style) ----
    me = jnp.mean(probs, axis=0)                                # mean router prob
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=1), axis=0
    ) / k                                                       # token fraction
    aux = e * jnp.sum(me * ce)

    # ---- sort-based capacity dispatch ----
    c = capacity(cfg, t)
    flat_e = top_i.reshape(-1)                                  # [T*k]
    order = jnp.argsort(flat_e)                                 # stable
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))       # [E]
    pos_in_e = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos_in_e < c
    pos_cl = jnp.minimum(pos_in_e, c - 1)
    tok_of_slot = order // k                                    # [T*k]

    src = xf[tok_of_slot] * keep[:, None].astype(xf.dtype)
    buf = jnp.zeros((e, c, d), xf.dtype).at[sorted_e, pos_cl].add(src)

    # ---- expert computation (sharded over the expert axis) ----
    hg = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    hi = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    hout = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hg) * hi, p["wo"])

    # ---- combine: gather back, weight by router prob ----
    gathered = hout[sorted_e, pos_cl] * keep[:, None].astype(hout.dtype)
    inv = jnp.argsort(order)
    per_slot = gathered[inv].reshape(t, k, d)
    out = jnp.sum(per_slot * top_p[..., None].astype(per_slot.dtype), axis=1)

    if cfg.n_shared_experts:
        out = out + cm.mlp_apply(cfg, p["shared"], xf)
    return out.reshape(b, s, d).astype(x.dtype), aux


def _moe_apply_sharded(cfg: ModelConfig, p: dict, x: jax.Array, shards: int):
    """Shard-local dispatch: top-k, argsort and the capacity buffer are all
    computed per data shard (every op carries the leading shard dim, so GSPMD
    never materializes a global token-slot tensor); the expert einsum then
    contracts against 'data'-sharded expert weights, which lowers to one
    all-to-all per layer instead of global all-gathers."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    tl = t // shards
    xs = x.reshape(shards, tl, d)
    try:
        from jax.sharding import PartitionSpec as P
        xs = jax.lax.with_sharding_constraint(xs, P("data", None, None))
    except Exception:
        pass  # no mesh in scope (CPU smoke tests)

    logits = xs.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # [S?,tl,E]
    top_p, top_i = jax.lax.top_k(probs, k)                   # [sh,tl,k]
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_i, e, dtype=jnp.float32),
                          axis=2), axis=(0, 1)) / k
    aux = e * jnp.sum(me * ce)

    c = capacity(cfg, tl)                                    # per-shard cap
    flat_e = top_i.reshape(shards, tl * k)
    order = jnp.argsort(flat_e, axis=1)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    seg_start = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(
        sorted_e)                                            # [sh,E]
    pos_in_e = jnp.arange(tl * k)[None] - jnp.take_along_axis(
        seg_start, sorted_e, axis=1)
    keep = pos_in_e < c
    pos_cl = jnp.minimum(pos_in_e, c - 1)
    tok = order // k                                         # [sh,tl*k]

    src = jnp.take_along_axis(
        xs, jnp.broadcast_to(tok[..., None], (shards, tl * k, d)), axis=1)
    src = src * keep[..., None].astype(src.dtype)
    sh_ix = jnp.arange(shards)[:, None]
    buf = jnp.zeros((shards, e, c, d), xs.dtype).at[
        sh_ix, sorted_e, pos_cl].add(src)

    # expert compute: weights are 'data'-sharded on E -> all-to-all here
    hg = jnp.einsum("xecd,edf->xecf", buf, p["wg"])
    hi = jnp.einsum("xecd,edf->xecf", buf, p["wi"])
    hout = jnp.einsum("xecf,efd->xecd", jax.nn.silu(hg) * hi, p["wo"])

    gathered = hout[sh_ix, sorted_e, pos_cl]                 # [sh,tl*k,d]
    gathered = gathered * keep[..., None].astype(hout.dtype)
    inv = jnp.argsort(order, axis=1)
    per_slot = jnp.take_along_axis(
        gathered, jnp.broadcast_to(inv[..., None], gathered.shape), axis=1)
    per_slot = per_slot.reshape(shards, tl, k, d)
    out = jnp.sum(per_slot * top_p[..., None].astype(per_slot.dtype), axis=2)

    if cfg.n_shared_experts:
        out = out + cm.mlp_apply(cfg, p["shared"], xs.reshape(shards * tl, d)
                                 ).reshape(shards, tl, d)
    return out.reshape(b, s, d).astype(x.dtype), aux


def _local_dispatch_compute(cfg, xl, router, wil, wgl, wol, *, n_data: int):
    """Per-shard body of the shard_map dispatch: local top-k + capacity
    buffer, all_to_all to expert owners, local expert matmuls (f-dim sharded
    over 'model' -> psum), all_to_all back, local combine."""
    e, k = cfg.n_experts, cfg.top_k
    tl, d = xl.shape
    logits = xl.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, -1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(top_i, e, dtype=jnp.float32),
                          axis=1), axis=0) / k
    # per-shard load-balance statistics, averaged outside the shard_map — a
    # different (equally valid) estimator than the global-batch aux loss;
    # they agree in expectation but not per step.
    aux = e * jnp.sum(me * ce)

    c = capacity(cfg, tl)
    flat = top_i.reshape(-1)
    order = jnp.argsort(flat)
    se = flat[order]
    seg = jnp.searchsorted(se, jnp.arange(e))
    pos = jnp.arange(tl * k) - seg[se]
    keep = pos < c
    posc = jnp.minimum(pos, c - 1)
    tok = order // k
    src = xl[tok] * keep[:, None].astype(xl.dtype)
    buf = jnp.zeros((e, c, d), xl.dtype).at[se, posc].add(src)

    buf2 = jax.lax.all_to_all(buf, "data", split_axis=0, concat_axis=1,
                              tiled=True)                  # [E/na, na*C, d]
    # f-dim stays sharded over the AUTO 'model' axis: GSPMD partitions the
    # expert matmuls and inserts the f-contraction psum itself.
    hg = jnp.einsum("ecd,edf->ecf", buf2, wgl)
    hi = jnp.einsum("ecd,edf->ecf", buf2, wil)
    hout = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hg) * hi, wol)
    back = jax.lax.all_to_all(hout, "data", split_axis=1, concat_axis=0,
                              tiled=True)                  # [E, C, d]

    gathered = back[se, posc] * keep[:, None].astype(back.dtype)
    inv = jnp.argsort(order)
    per_slot = gathered[inv].reshape(tl, k, d)
    out = jnp.sum(per_slot * top_p[..., None].astype(per_slot.dtype), axis=1)
    return out, aux


def _moe_apply_shard_map(cfg: ModelConfig, p: dict, x: jax.Array, mesh):
    """Expert-parallel dispatch as an explicit shard_map: deterministic
    all_to_all instead of GSPMD-inferred collectives (§Perf pair 1 it4)."""
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    n_data = dict(zip(mesh.axis_names, mesh.devices.shape))["data"]
    xf = x.reshape(b * s, d)

    def body(xl, router, wil, wgl, wol):
        return _local_dispatch_compute(cfg, xl, router, wil, wgl, wol,
                                       n_data=n_data)

    # manual over 'data' only (the all_to_all axis); 'model' and 'pod'
    # (the worker vmap dim) stay automatic under GSPMD
    def body2(*a):
        out, aux = body(*a)
        return out, aux[None]  # [1] per shard -> gathered over 'data'

    fn = cm.shard_map_compat(
        body2, mesh, manual_axes={"data"},
        in_specs=(P("data", None), P(None, None),
                  P("data", None, None), P("data", None, None),
                  P("data", None, None)),
        out_specs=(P("data", None), P("data")))
    out, aux_sh = fn(xf, p["router"], p["wi"], p["wg"], p["wo"])
    aux = jnp.mean(aux_sh)
    out = out.reshape(b, s, d)
    if cfg.n_shared_experts:
        out = out + cm.mlp_apply(cfg, p["shared"], x)
    return out.astype(x.dtype), aux
