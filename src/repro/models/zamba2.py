"""Zamba2 — Mamba2 backbone + weight-shared attention blocks (arXiv:2411.15242).

Structure: `n_layers` Mamba2 layers; after every `shared_attn_period` of them a
single *shared* (weight-tied) transformer block runs on the concatenation of
the hidden state and the original embedding (Zamba's concat trick), projected
back to d_model.  The backbone is grouped into scans of `shared_attn_period`
mamba layers so HLO cost reflects the true ratio of mamba:attention compute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import mamba2 as m2
from repro.models.param import ParamDef


def shared_block_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "in_proj": ParamDef((2 * d, d), ("embed", "embed")),
        "ln1": cm.norm_defs(cfg), "ln2": cm.norm_defs(cfg),
        "attn": cm.attn_defs(cfg),
        "mlp": cm.mlp_defs(cfg),
        "out_proj": ParamDef((d, d), ("embed", "embed")),
    }


def n_groups(cfg: ModelConfig) -> tuple[int, int]:
    g = cfg.n_layers // cfg.shared_attn_period
    rem = cfg.n_layers - g * cfg.shared_attn_period
    return g, rem


def param_defs(cfg: ModelConfig) -> dict:
    g, rem = n_groups(cfg)
    mdefs = m2._layer_defs(cfg)
    grouped = cm.stack_defs(cm.stack_defs(mdefs, cfg.shared_attn_period), g)
    defs = {
        "embed": cm.embed_defs(cfg),
        "groups": grouped,                       # [G, period, ...]
        "shared": shared_block_defs(cfg),        # weight-tied attention block
        "final_norm": cm.norm_defs(cfg),
    }
    if rem:
        defs["tail"] = cm.stack_defs(mdefs, rem)
    return defs


def _shared_apply(cfg, p, h, h0, *, positions, cache=None, cache_pos=None,
                  ring=False):
    x = jnp.concatenate([h, h0], axis=-1) @ p["in_proj"]
    a, nc = cm.attn_apply(cfg, p["attn"], cm.norm_apply(cfg, p["ln1"], x),
                          positions=positions, cache=cache, cache_pos=cache_pos,
                          ring=ring)
    x = x + a
    x = x + cm.mlp_apply(cfg, p["mlp"], cm.norm_apply(cfg, p["ln2"], x))
    return h + x @ p["out_proj"], nc


def _mamba_block(cfg, lp, h, cache=None):
    out, nc = m2.mixer_apply(cfg, lp["mixer"], cm.norm_apply(cfg, lp["ln"], h),
                             cache=cache)
    return h + out, nc


def forward(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            remat: bool = True, prefix_embeds=None):
    h0 = cm.embed_apply(cfg, params["embed"], tokens)
    positions = jnp.arange(h0.shape[1])
    g, rem = n_groups(cfg)

    def inner(hh, lp):
        hh, _ = _mamba_block(cfg, lp, hh)
        return hh, None

    def group_body(hh, gp):
        hh, _ = jax.lax.scan(inner, hh, gp, unroll=cm.scan_unroll())
        hh, _ = _shared_apply(cfg, params["shared"], hh, h0,
                              positions=positions)
        return hh, None

    if remat:
        group_body = jax.checkpoint(group_body)
    h, _ = jax.lax.scan(group_body, h0, params["groups"],
                        unroll=cm.scan_unroll())
    if rem:
        h, _ = jax.lax.scan(inner, h, params["tail"], unroll=cm.scan_unroll())
    h = cm.norm_apply(cfg, params["final_norm"], h)
    return cm.unembed_apply(cfg, params["embed"], h), jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *, remat=True):
    logits, _ = forward(cfg, params, batch["tokens"], remat=remat)
    return cm.lm_loss(logits, batch["labels"])


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               window_override: int = 0):
    g, rem = n_groups(cfg)
    mspec = m2.cache_spec(cfg, batch, max_len, dtype)
    ln = min(max_len, window_override) if window_override else max_len
    kv = (g, batch, ln, cfg.n_kv_heads, cfg.hd)
    return {
        "mamba": mspec,  # [L, ...] over all mamba layers (groups*period + rem)
        "attn_k": jax.ShapeDtypeStruct(kv, dtype),
        "attn_v": jax.ShapeDtypeStruct(kv, dtype),
    }


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16, window_override=0):
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        cache_spec(cfg, batch, max_len, dtype, window_override))


def _cached_pass(cfg, params, h0, cache, *, positions, cache_pos, ring,
                 decode: bool):
    """Shared decode/prefill-free pass over groups with caches."""
    g, rem = n_groups(cfg)
    period = cfg.shared_attn_period
    h = h0
    mcache = cache["mamba"]
    new_conv, new_ssm, new_k, new_v = [], [], [], []
    for gi in range(g):
        for li in range(period):
            idx = gi * period + li
            lp = jax.tree.map(lambda x: x[gi, li], params["groups"])
            mc = ({"conv": mcache["conv"][idx], "ssm": mcache["ssm"][idx]}
                  if decode else None)
            h, nc = _mamba_block(cfg, lp, h, cache=mc)
            new_conv.append(nc["conv"]); new_ssm.append(nc["ssm"])
        ac = {"k": cache["attn_k"][gi], "v": cache["attn_v"][gi]}
        h, nac = _shared_apply(cfg, params["shared"], h, h0,
                               positions=positions, cache=ac,
                               cache_pos=cache_pos, ring=ring)
        new_k.append(nac["k"]); new_v.append(nac["v"])
    for li in range(rem):
        idx = g * period + li
        lp = jax.tree.map(lambda x: x[li], params["tail"])
        mc = ({"conv": mcache["conv"][idx], "ssm": mcache["ssm"][idx]}
              if decode else None)
        h, nc = _mamba_block(cfg, lp, h, cache=mc)
        new_conv.append(nc["conv"]); new_ssm.append(nc["ssm"])
    newc = {
        "mamba": {"conv": jnp.stack([c.astype(mcache["conv"].dtype) for c in new_conv]),
                  "ssm": jnp.stack(new_ssm)},
        "attn_k": jnp.stack(new_k), "attn_v": jnp.stack(new_v),
    }
    return h, newc


def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, cache: dict,
            **_):
    h0 = cm.embed_apply(cfg, params["embed"], tokens)
    positions = jnp.arange(h0.shape[1])
    h, newc = _cached_pass(cfg, params, h0, cache, positions=positions,
                           cache_pos=0, ring=False, decode=False)
    h = cm.norm_apply(cfg, params["final_norm"], h[:, -1:])
    return cm.unembed_apply(cfg, params["embed"], h)[:, 0], newc


def decode_step(cfg: ModelConfig, params: dict, token: jax.Array, cache: dict,
                pos, *, prefix_len: int = 0, ring: bool = False):
    del prefix_len
    h0 = cm.embed_apply(cfg, params["embed"], token[:, None])
    positions = jnp.asarray(pos)[None, None]
    h, newc = _cached_pass(cfg, params, h0, cache, positions=positions,
                           cache_pos=pos, ring=ring, decode=True)
    h = cm.norm_apply(cfg, params["final_norm"], h)
    return cm.unembed_apply(cfg, params["embed"], h)[:, 0], newc
