"""Uniform model protocol: family -> module dispatch.

Every module exposes:
  param_defs(cfg) -> ParamDef tree
  loss_fn(cfg, params, batch, *, remat) -> scalar loss
  forward(cfg, params, ...) -> (logits, aux)
  cache_spec / init_cache / prefill / decode_step   (decoder families)
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models import mamba2, transformer, vit, whisper, zamba2


_FAMILY = {
    "dense": transformer,
    "moe": transformer,          # cfg.n_experts drives the MoE FFN
    "vlm": transformer,          # prefix_embeds in the batch
    "ssm": mamba2,
    "hybrid": zamba2,
    "audio": whisper,
    "vision": vit,
}


def get_module(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def batch_keys(cfg: ModelConfig) -> tuple[str, ...]:
    """Input tensors a training batch must contain (besides labels)."""
    if cfg.family == "vlm":
        return ("tokens", "prefix_embeds")
    if cfg.family == "audio":
        return ("tokens", "frames")
    if cfg.family == "vision":
        return ("images",)
    return ("tokens",)
