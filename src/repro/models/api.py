"""Uniform model protocol: family -> module dispatch.

Every module exposes:
  param_defs(cfg) -> ParamDef tree
  loss_fn(cfg, params, batch, *, remat) -> scalar loss
  forward(cfg, params, ...) -> (logits, aux)
  cache_spec / init_cache / prefill / decode_step   (decoder families)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba2, transformer, vit, whisper, zamba2


_FAMILY = {
    "dense": transformer,
    "moe": transformer,          # cfg.n_experts drives the MoE FFN
    "vlm": transformer,          # prefix_embeds in the batch
    "ssm": mamba2,
    "hybrid": zamba2,
    "audio": whisper,
    "vision": vit,
}


def get_module(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def zero_cache_slots(cache, slots):
    """Zero the given batch lanes of a decode cache, whatever the family.

    Every cache leaf across families carries the batch axis at position 1 —
    transformer KV [L,B,S,Hkv,hd], mamba2 conv/ssm [L,B,...], zamba2
    attn/mamba state [G-or-L,B,...] — so one tree.map clears KV rows and
    recurrent SSM/conv state alike.  This is the slot-recycle invariant the
    ContinuousBatcher relies on: transformer KV happens to survive a dirty
    lane (positional overwrite + causal mask), but recurrent state does
    not, and a hot weight swap's replay needs clean lanes for any family."""
    idx = jnp.asarray(slots, jnp.int32)
    return jax.tree.map(lambda c: c.at[:, idx].set(0), cache)


def batch_keys(cfg: ModelConfig) -> tuple[str, ...]:
    """Input tensors a training batch must contain (besides labels)."""
    if cfg.family == "vlm":
        return ("tokens", "prefix_embeds")
    if cfg.family == "audio":
        return ("tokens", "frames")
    if cfg.family == "vision":
        return ("images",)
    return ("tokens",)
