"""ViT-B/16 classifier — the paper's own architecture (Dosovitskiy et al.,
2021; Beyer et al. 2022 recipe: GAP head, fixed sin-cos positions).

Used by the paper-faithful example (`examples/vit_local_adamw.py`) and the
generalization benchmark.  Patch extraction is a reshape+linear (pure JAX).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models.param import ParamDef


def param_defs(cfg: ModelConfig, patch: int = 16, channels: int = 3) -> dict:
    d = cfg.d_model
    return {
        "patch_proj": ParamDef((patch * patch * channels, d), (None, "embed")),
        "patch_bias": ParamDef((d,), ("embed",), "zeros"),
        "layers": cm.stack_defs({
            "ln1": cm.norm_defs(cfg), "ln2": cm.norm_defs(cfg),
            "attn": cm.attn_defs(cfg), "mlp": cm.mlp_defs(cfg),
        }, cfg.n_layers),
        "final_norm": cm.norm_defs(cfg),
        "head": ParamDef((d, cfg.n_classes), ("embed", None)),
        "head_bias": ParamDef((cfg.n_classes,), (None,), "zeros"),
    }


def _sincos_positions(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2).astype(jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def forward(cfg: ModelConfig, params: dict, images: jax.Array, *,
            patch: int = 16, remat: bool = False) -> jax.Array:
    """images [B,H,W,C] -> logits [B,n_classes]."""
    b, hh, ww, c = images.shape
    ph, pw = hh // patch, ww // patch
    x = images.reshape(b, ph, patch, pw, patch, c).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(b, ph * pw, patch * patch * c)
    h = x.astype(params["patch_proj"].dtype) @ params["patch_proj"] + params["patch_bias"]
    h = h + _sincos_positions(ph * pw, cfg.d_model).astype(h.dtype)
    positions = jnp.arange(ph * pw)

    def body(hcar, lp):
        hn = cm.norm_apply(cfg, lp["ln1"], hcar)
        a, _ = cm.attn_apply(cfg, lp["attn"], hn, positions=positions,
                             use_rope=False, kv_source=hn)
        hcar = hcar + a
        hcar = hcar + cm.mlp_apply(cfg, lp["mlp"],
                                   cm.norm_apply(cfg, lp["ln2"], hcar))
        return hcar, None

    if remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["layers"], unroll=cm.scan_unroll())
    h = cm.norm_apply(cfg, params["final_norm"], h)
    pooled = jnp.mean(h, axis=1)  # GAP head (Beyer et al. 2022)
    return (pooled @ params["head"] + params["head_bias"]).astype(jnp.float32)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *, remat=False):
    logits = forward(cfg, params, batch["images"], remat=remat)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def accuracy(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    logits = forward(cfg, params, batch["images"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
