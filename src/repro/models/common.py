"""Shared neural-net building blocks (pure JAX, functional).

Every block has (a) a ``*_defs`` function producing declarative ParamDefs and
(b) an ``*_apply`` function consuming the materialized params.  Attention and
norms route through ``repro.kernels.ops`` so the Pallas kernels are used on
TPU while CPU falls back to the jnp oracles.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.errors import ShapeError
from repro.kernels import ops as kops
from repro.models.param import ParamDef

def scan_unroll():
    """Full-unroll switch for dry-run cost analysis: XLA's cost_analysis
    counts a while-loop body once, so the roofline pass unrolls every scan
    (REPRO_DRYRUN_UNROLL=1) to get exact FLOP/byte/collective counts."""
    return bool(int(os.environ.get("REPRO_DRYRUN_UNROLL", "0")))


def shard_map_compat(f, mesh, *, in_specs, out_specs, manual_axes=None):
    """`shard_map` across jax versions.

    Newer jax exposes `jax.shard_map(..., axis_names=..., check_vma=...)`;
    0.4.x has `jax.experimental.shard_map.shard_map(..., auto=...,
    check_rep=...)` where `auto` is the complement of the manual axes.
    Callers name the *manual* axes (None = all mesh axes manual) and this
    shim translates.  On 0.4.x the partial-manual form (`auto=...`) trips an
    XLA SPMD-partitioner check on the CPU backend, so there every axis goes
    manual: axes the specs never mention are then implicitly replicated,
    which is semantically identical for bodies whose collectives only touch
    the manual axes.  Replication checking is disabled either way: the call
    sites use psum_scatter/all_gather/all_to_all patterns the checker cannot
    always infer through.
    """
    manual = (frozenset(mesh.axis_names) if manual_axes is None
              else frozenset(manual_axes))
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, axis_names=manual,
                             in_specs=in_specs, out_specs=out_specs,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def norm_defs(cfg: ModelConfig) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": ParamDef((cfg.d_model,), ("embed",), "ones"),
                "bias": ParamDef((cfg.d_model,), ("embed",), "zeros")}
    return {"scale": ParamDef((cfg.d_model,), ("embed",), "ones")}


def norm_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
        return out.astype(x.dtype)
    return kops.rms_norm(x, p["scale"])


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [B,S,H,D]; positions [S] or [B,S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq  # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA, optional QKV bias / sliding window / prefix-LM / KV cache)
# --------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    defs = {
        "wq": ParamDef((d, hq * hd), ("embed", "heads")),
        "wk": ParamDef((d, hkv * hd), ("embed", "kv")),
        "wv": ParamDef((d, hkv * hd), ("embed", "kv")),
        "wo": ParamDef((hq * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((hq * hd,), ("heads",), "zeros")
        defs["bk"] = ParamDef((hkv * hd,), ("kv",), "zeros")
        defs["bv"] = ParamDef((hkv * hd,), ("kv",), "zeros")
    return defs


def _attn_chunked(q, k, v, *, causal, window, prefix_len, q_offset, q_block=512):
    """Block the query dim so the [Sq,Sk] score tile stays bounded."""
    b, sq, hq, hd = q.shape
    if sq <= q_block:
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    prefix_len=prefix_len, q_offset=q_offset)
    while sq % q_block:  # largest divisor of sq at most the target block
        q_block -= 1
    nblk = sq // q_block
    qs = q.reshape(b, nblk, q_block, hq, hd).swapaxes(0, 1)  # [n,b,qb,h,d]

    def body(carry, inp):
        i, qi = inp
        o = kops.flash_attention(qi, k, v, causal=causal, window=window,
                                 prefix_len=prefix_len,
                                 q_offset=q_offset + i * q_block)
        return carry, o

    _, outs = jax.lax.scan(body, 0, (jnp.arange(nblk), qs),
                           unroll=scan_unroll())
    return outs.swapaxes(0, 1).reshape(b, sq, hq, hd)


def attn_apply(cfg: ModelConfig, p: dict, x: jax.Array, *,
               positions: jax.Array, layer_window=0, prefix_len=0,
               cache: dict | None = None, cache_pos=None, ring: bool = False,
               kv_source: jax.Array | None = None, use_rope: bool = True):
    """Returns (out, new_cache).

    cache: {"k": [B,Smax,Hkv,hd], "v": ...} — decode/streaming path.  With
    ring=True the cache is a circular buffer shorter than the stream; keys
    carry their absolute positions for masking.
    kv_source: if given, cross-attention (keys/values from this tensor).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_source is None else kv_source
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (src @ p["wk"]).reshape(b, src.shape[1], hkv, hd)
    v = (src @ p["wv"]).reshape(b, src.shape[1], hkv, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(hq, hd)
        k = k + p["bk"].reshape(hkv, hd)
        v = v + p["bv"].reshape(hkv, hd)
    if use_rope and kv_source is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if kv_source is not None:
        # cross attention: no causal mask, no cache update
        o = _attn_chunked(q, k, v, causal=False, window=0, prefix_len=0,
                          q_offset=0)
    elif cache is not None:
        # decode: write k/v at cache_pos, attend over the whole cache.
        # cache_pos may be per-batch [B] (ragged continuous batching).
        ln = cache["k"].shape[1]
        per_batch = getattr(cache_pos, "ndim", 0) and jnp.ndim(cache_pos) > 0
        if per_batch:
            if ring:
                raise ShapeError("ragged positions + ring cache unsupported")
            dus = jax.vmap(
                lambda c, u, pp: jax.lax.dynamic_update_slice_in_dim(
                    c, u, pp, axis=0))
            ck = dus(cache["k"], k.astype(cache["k"].dtype), cache_pos)
            cv = dus(cache["v"], v.astype(cache["v"].dtype), cache_pos)
            new_cache = {"k": ck, "v": cv}
            o = kops.flash_attention(q, ck, cv, causal=True,
                                     window=layer_window,
                                     prefix_len=prefix_len,
                                     q_offset=cache_pos)
            return o.reshape(b, s, hq * hd) @ p["wo"], new_cache
        if ring:
            write = jnp.mod(cache_pos, ln)
            base = cache_pos - write
            idx = jnp.arange(ln)
            k_positions = jnp.where(idx <= write, base + idx, base - ln + idx)
        else:
            write = cache_pos
            k_positions = None
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), write, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), write, axis=1)
        new_cache = {"k": ck, "v": cv}
        o = kops.flash_attention(q, ck, cv, causal=True, window=layer_window,
                                 prefix_len=prefix_len, q_offset=cache_pos,
                                 k_positions=k_positions)
    else:
        o = _attn_chunked(q, k, v, causal=True, window=layer_window,
                          prefix_len=prefix_len, q_offset=0)
    return o.reshape(b, s, hq * hd) @ p["wo"], new_cache


# --------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# --------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    defs = {"wi": ParamDef((d, f), ("embed", "mlp")),
            "wo": ParamDef((f, d), ("mlp", "embed"))}
    if cfg.act == "swiglu":
        defs["wg"] = ParamDef((d, f), ("embed", "mlp"))
    return defs


def mlp_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        # fused gate+up projection (Pallas kernel on TPU; jnp oracle on CPU)
        return kops.swiglu(x, p["wg"], p["wi"]) @ p["wo"]
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig) -> dict:
    defs = {"tok": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                            "embed", scale=0.02)}
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return defs


def embed_apply(cfg: ModelConfig, p: dict, tokens: jax.Array) -> jax.Array:
    h = p["tok"][tokens]
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def unembed_apply(cfg: ModelConfig, p: dict, h: jax.Array) -> jax.Array:
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    return (h @ w.astype(h.dtype)).astype(jnp.float32)


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------

def lm_loss(logits: jax.Array, labels: jax.Array,
            mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token cross-entropy in fp32. logits [..,S,V], labels [..,S]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def stack_defs(defs, n: int):
    """Prepend a scan 'layers' axis of size n to every ParamDef in a tree."""
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
