"""Declarative parameter definitions.

Every model module describes its parameters once, as a nested dict of
``ParamDef(shape, logical_axes, init)``.  From that single description we derive:

  * ``init_params``      — materialized pytree (PRNG-seeded),
  * ``abstract_params``  — ShapeDtypeStruct pytree (for ``eval_shape``/dry-run),
  * ``param_specs``      — pytree of ``jax.sharding.PartitionSpec`` produced by
                           mapping *logical* axis names onto mesh axes under a
                           sharding policy (with per-tensor conflict resolution
                           and divisibility checks).

Logical axis vocabulary (see DESIGN.md §2):
  worker   — local-gradient replica axis (leading axis added by the runtime)
  layers   — scan-stacked layer axis (never sharded)
  embed    — d_model dim (sharded over the fsdp axis under the `fsdp` policy)
  mlp      — ffn hidden dim            -> 'model'
  heads    — attention q-head dim      -> 'model'
  kv       — kv-head dim               -> 'model' (replicated if not divisible)
  vocab    — vocabulary dim            -> 'model' (replicated if not divisible)
  experts  — MoE expert dim            -> expert-parallel axis
  (None)   — replicated dim
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.errors import ShapeError

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | embed | scaled(fan_in)
    scale: float = 1.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ShapeError(
                f"ParamDef shape {self.shape} and axes {self.axes} "
                "must have equal rank")


def _leaf_init(rng: jax.Array, d: ParamDef, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "embed":
        return (jax.random.normal(rng, d.shape) * d.scale).astype(dtype)
    if d.init == "normal":
        # fan-in scaled truncated-normal-ish init (lecun normal)
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(rng, d.shape) * std).astype(dtype)
    raise ValueError(f"unknown init {d.init!r}")


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(defs: Pytree, rng: jax.Array, dtype=jnp.float32) -> Pytree:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    rngs = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(
        treedef, [_leaf_init(r, d, dtype) for r, d in zip(rngs, leaves)]
    )


def abstract_params(defs: Pytree, dtype=jnp.float32) -> Pytree:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def
    )


# --------------------------------------------------------------------------
# Logical-axis -> mesh-axis rules.
# --------------------------------------------------------------------------

# Ordered: earlier entries claim mesh axes first within each tensor.
_POLICY_RULES: dict[str, list[tuple[str, tuple[str, ...]]]] = {
    # One model replica per *data rank*; tensor-parallel over 'model'.
    "dp": [
        ("worker", ("pod", "data")),
        ("experts", ("data",)),   # dp MoE models still expert-shard if possible
        ("vocab", ("model",)),
        ("heads", ("model",)),
        ("kv", ("model",)),
        ("mlp", ("model",)),
        ("conv_dim", ("model",)),
    ],
    # One replica per *pod*; params fully sharded inside the pod (FSDP+TP+EP).
    "fsdp": [
        ("worker", ("pod",)),
        ("experts", ("data",)),
        ("vocab", ("model",)),
        ("heads", ("model",)),
        ("kv", ("model",)),
        ("mlp", ("model",)),
        ("conv_dim", ("model",)),
        ("embed", ("data",)),
    ],
}


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(axes: tuple[str | None, ...], shape: tuple[int, ...],
             policy: str, mesh) -> P:
    """Map logical axes of one tensor to a PartitionSpec under `policy`.

    Skips a mapping when (a) the mesh axis is absent, (b) the dim is not
    divisible by the mesh-axis size, or (c) the mesh axis was already claimed
    by a higher-priority logical axis of this same tensor.
    """
    sizes = mesh_axis_sizes(mesh)
    rules = dict(_POLICY_RULES[policy])
    used: set[str] = set()
    out: list[Any] = []
    for dim, ax in zip(shape, axes):
        target = rules.get(ax) if ax is not None else None
        if target is None:
            out.append(None)
            continue
        avail = tuple(a for a in target if a in sizes and a not in used)
        total = math.prod(sizes[a] for a in avail) if avail else 1
        if avail and total > 1 and dim % total == 0:
            used.update(avail)
            out.append(avail if len(avail) > 1 else avail[0])
        else:
            out.append(None)
    return P(*out)


def param_specs(defs: Pytree, policy: str, mesh,
                extra_leading: tuple[str | None, ...] = ()) -> Pytree:
    """Specs for a defs tree; `extra_leading` prepends logical axes (e.g. the
    worker axis the local-gradient runtime adds)."""

    def one(d: ParamDef) -> P:
        axes = tuple(extra_leading) + d.axes
        shape = (0,) * len(extra_leading) + d.shape  # shape only used for div-check
        # leading worker axis: divisibility checked by caller (W is chosen to match)
        sizes = mesh_axis_sizes(mesh)
        rules = dict(_POLICY_RULES[policy])
        full_shape = list(shape)
        for i, ax in enumerate(extra_leading):
            if ax == "worker":
                tgt = rules.get("worker", ())
                full_shape[i] = math.prod(sizes.get(a, 1) for a in tgt)
        return spec_for(axes, tuple(full_shape), policy, mesh)

    return jax.tree.map(one, defs, is_leaf=is_def)


def worker_count(policy: str, mesh) -> int:
    """Number of local-gradient workers (divergent replicas) for a policy/mesh."""
    sizes = mesh_axis_sizes(mesh)
    axes = dict(_POLICY_RULES[policy])["worker"]
    return math.prod(sizes.get(a, 1) for a in axes)


def worker_mesh_axes(policy: str, mesh) -> tuple[str, ...]:
    sizes = mesh_axis_sizes(mesh)
    return tuple(a for a in dict(_POLICY_RULES[policy])["worker"] if a in sizes)


def count_params(defs: Pytree) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(math.prod(d.shape) for d in leaves)
