"""Learning-rate schedules from the paper (§4.1, App. C): cosine, linear and
step decay, each with linear warmup.  Step decay is the paper's construction:
eta_step(t) = 2^round(log2(eta_cos(t)))."""
from __future__ import annotations

import math


def cosine(t: int, *, peak: float, end: float, warmup: int, total: int) -> float:
    if warmup and t < warmup:
        return peak * (t + 1) / warmup
    frac = min(max(t - warmup, 0) / max(total - warmup, 1), 1.0)
    return end + 0.5 * (peak - end) * (1 + math.cos(math.pi * frac))


def linear(t: int, *, peak: float, end: float, warmup: int, total: int) -> float:
    if warmup and t < warmup:
        return peak * (t + 1) / warmup
    frac = min(max(t - warmup, 0) / max(total - warmup, 1), 1.0)
    return peak + frac * (end - peak)


def step(t: int, *, peak: float, end: float, warmup: int, total: int) -> float:
    """Paper App. C: cosine rounded to powers of two."""
    eta = cosine(t, peak=peak, end=end, warmup=warmup, total=total)
    if eta <= 0:
        return end
    return 2.0 ** round(math.log2(eta))


SCHEDULES = {"cosine": cosine, "linear": linear, "step": step}


def make_lr_fn(run_cfg):
    fn = SCHEDULES[run_cfg.lr_schedule]

    def lr(t: int) -> float:
        return fn(t, peak=run_cfg.peak_lr, end=run_cfg.end_lr,
                  warmup=run_cfg.warmup_steps, total=run_cfg.total_steps)

    return lr
