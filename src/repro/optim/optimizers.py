"""Optimizers, built from scratch in JAX (no optax): SGD+momentum and AdamW.

The update itself is the innermost loop of every local step, so it routes
through `repro.kernels.ops.adamw_update` — the fused Pallas kernel on TPU,
the jnp oracle elsewhere.  Optimizer state is a pytree mirroring params;
with the local-gradient runtime a leading worker axis rides along
transparently (updates are elementwise).

Because every update is an elementwise `jax.tree.map`, the optimizers are
layout-agnostic: under the flat layout (core/flat.py) `params` is a dict of
a few dtype-bucketed [W, N] buffers, so the hot path collapses from one
kernel launch per leaf (each padded to the Pallas block size) to one launch
per dtype bucket per local step — at most one block of padding total, and
per-element math (hence the trained params) bitwise-identical to the tree
layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Any      # params -> opt_state
    update: Any    # (params, opt_state, grads, lr) -> (params, opt_state)


def sgd(momentum: float = 0.9, weight_decay: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, state, grads, lr):
        def one(p, m, g):
            gf = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m1 = momentum * m + gf
            d = gf + momentum * m1 if nesterov else m1
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), m1

        out = jax.tree.map(one, params, state["mu"], grads)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"mu": new_m, "step": state["step"] + 1}

    return Optimizer(init, update)


def adamw(beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.05, clip_norm: float = 0.0) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(params, state, grads, lr):
        if clip_norm > 0:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, clip_norm / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        step = state["step"] + 1
        stepf = step.astype(jnp.float32)

        def one(p, m, v, g):
            return kops.adamw_update(p, m, v, g, lr=lr, beta1=beta1,
                                     beta2=beta2, eps=eps,
                                     weight_decay=weight_decay, step=stepf)

        out = jax.tree.map(one, params, state["m"], state["v"], grads)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2), "step": step}

    return Optimizer(init, update)


def global_norm(tree: Pytree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def make_optimizer(run_cfg) -> Optimizer:
    if run_cfg.optimizer == "sgd":
        return sgd(momentum=0.9, weight_decay=run_cfg.weight_decay)
    return adamw(weight_decay=run_cfg.weight_decay)
