"""Config dataclasses shared by all architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio | vision
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # sliding-window pattern: every `window_pattern`-th layer (1-indexed) is
    # global; others use `window`. window_pattern=0 -> all layers full attention
    # (unless window>0 and window_pattern<0 -> all layers windowed).
    window: int = 0
    window_pattern: int = 0
    act: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = True
    embed_scale: bool = False        # gemma-style sqrt(d_model) embedding scale
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    shared_attn_period: int = 0      # zamba2: shared attn block every N layers
    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0                 # stub-frontend frame count (whisper: 1500)
    # --- VLM (paligemma) ---
    n_img_tokens: int = 0            # stub-frontend patch count
    # --- vision classifier (paper's ViT) ---
    n_classes: int = 0
    # serving: window used for the long_500k variant on full-attention archs
    long_decode_window: int = 8192
    source: str = ""                 # citation

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def layer_window(self, i: int) -> int:
        """Sliding window for layer i (0 = full attention)."""
        if self.window <= 0:
            return 0
        if self.window_pattern < 0:
            return self.window
        if self.window_pattern == 0:
            return self.window
        return 0 if (i + 1) % self.window_pattern == 0 else self.window


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Distribution + numerics policy for a run."""
    sharding: str = "dp"            # dp | fsdp  (see DESIGN.md §2)
    param_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"      # full | save_collectives  (§Perf pair 3)
    seq_shard_activations: bool = False  # Korthikanti-style sequence parallel
    moe_dispatch_shards: int = 1    # >1: shard-local MoE dispatch (§Perf)
    moe_dispatch: str = "auto"      # auto | sharded | shard_map (§Perf)
    microbatch: int = 1             # grad-accumulation chunks per local step
    optimizer: str = "adamw"        # adamw | sgd
    # H schedule
    # qsr | constant | inverse | cubic | postlocal | swap | parallel
    # | linear_inc | dec_sqrt  (related-work baselines, paper §A)
    schedule: str = "qsr"
    h_base: int = 4
    alpha: float = 0.0175           # QSR growth coefficient
    beta: float = 0.03              # inverse-rule coefficient
    rho: float = 0.0075             # cubic-rule coefficient
    switch_frac: float = 0.5        # post-local / swap switching point
    # lr schedule
    lr_schedule: str = "cosine"     # cosine | linear | step
    peak_lr: float = 0.008
    end_lr: float = 1e-6
    warmup_steps: int = 0
    total_steps: int = 1000
    weight_decay: float = 0.05
    # serving layout (see launch/shapes.py _cache_sharding)
    cache_layout: str = "batch"      # batch | seq_model (flash-decode)
    # sync options (beyond-paper)
    sync_quantize: bool = False      # int8-quantized sync deltas
    outer_momentum: float = 0.0      # DiLoCo-style Nesterov outer optimizer
    # wire mode for the quantized sync payload (README §Wire modes):
    #   auto     — exact Σq contract; codes travel in wire_dtype(W)
    #              (int16/int32) so the sum never overflows
    #   ring-int8 — W-hop re-quantizing ppermute ring; int8 on every hop,
    #              beyond-exact semantics (drift measured, not assumed)
    sync_wire: str = "auto"
