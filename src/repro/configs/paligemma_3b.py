"""paligemma-3b [vlm] — SigLIP(stub) + gemma decoder, GQA(kv=1)
[arXiv:2407.07726]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b", family="vlm",
        n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
        vocab=257216, head_dim=256, rope_theta=1e4,
        act="swiglu", norm="rmsnorm", tie_embeddings=True, embed_scale=True,
        n_img_tokens=256,
        source="arXiv:2407.07726",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-smoke", family="vlm",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=1, d_ff=512,
        vocab=512, head_dim=64, act="swiglu", norm="rmsnorm",
        tie_embeddings=True, embed_scale=True, n_img_tokens=16,
    )
