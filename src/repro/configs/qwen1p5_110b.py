"""qwen1.5-110b [dense] — QKV bias, GQA(kv=8) [hf:Qwen/Qwen1.5-0.5B]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=49152,
        vocab=152064, head_dim=128, rope_theta=1e6, qkv_bias=True,
        act="swiglu", norm="rmsnorm", tie_embeddings=False,
        source="hf:Qwen/Qwen1.5-0.5B",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
        vocab=512, head_dim=32, qkv_bias=True,
        act="swiglu", norm="rmsnorm", tie_embeddings=False,
    )
