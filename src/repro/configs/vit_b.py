"""ViT-B/16 — the paper's own architecture (ImageNet classifier, Beyer et
al. 2022 recipe) [arXiv:2010.11929 / paper §4]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="vit-b16", family="vision",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
        vocab=0, act="gelu", norm="layernorm", tie_embeddings=False,
        n_classes=1000, source="arXiv:2010.11929",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="vit-smoke", family="vision",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=0, act="gelu", norm="layernorm", tie_embeddings=False,
        n_classes=10,
    )
