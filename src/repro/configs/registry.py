"""Architecture registry: --arch <id> -> (config, smoke_config, default policy).

`policy` is the default sharding policy (DESIGN.md §2):
  dp   — one replica per data rank (paper-faithful worker granularity)
  fsdp — one replica per pod (DiLoCo-style mapping for >100B models)
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    module: str
    policy: str
    notes: str = ""


ARCHS: dict[str, ArchEntry] = {
    "starcoder2-3b":   ArchEntry("starcoder2_3b", "dp"),
    "paligemma-3b":    ArchEntry("paligemma_3b", "dp"),
    "gemma3-4b":       ArchEntry("gemma3_4b", "dp"),
    "whisper-base":    ArchEntry("whisper_base", "dp"),
    "zamba2-1.2b":     ArchEntry("zamba2_1p2b", "dp"),
    "qwen1.5-110b":    ArchEntry("qwen1p5_110b", "fsdp"),
    "mamba2-130m":     ArchEntry("mamba2_130m", "dp"),
    "dbrx-132b":       ArchEntry("dbrx_132b", "fsdp"),
    "phi3-medium-14b": ArchEntry("phi3_medium_14b", "dp",
                                 "AdamW moments dominate; fsdp also supported"),
    "kimi-k2-1t-a32b": ArchEntry("kimi_k2_1t", "fsdp"),
    "vit-b16":         ArchEntry("vit_b", "dp", "paper's own architecture"),
}


def _mod(arch: str):
    return importlib.import_module(f"repro.configs.{ARCHS[arch].module}")


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).smoke_config()


def get_policy(arch: str) -> str:
    return ARCHS[arch].policy


ASSIGNED = [a for a in ARCHS if a != "vit-b16"]
