"""phi3-medium-14b [dense] — RoPE, SwiGLU, GQA(kv=10) [arXiv:2404.14219]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-medium-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17920,
        vocab=100352, head_dim=128, rope_theta=1e4,
        act="swiglu", norm="rmsnorm", tie_embeddings=False,
        source="arXiv:2404.14219",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
        vocab=512, head_dim=32, act="swiglu", norm="rmsnorm",
        tie_embeddings=False,
    )
