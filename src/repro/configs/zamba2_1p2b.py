"""zamba2-1.2b [hybrid] — Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
        vocab=32000, head_dim=64, act="gelu", norm="rmsnorm",
        tie_embeddings=True,
        ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
        shared_attn_period=6,
        source="arXiv:2411.15242",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=5, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=512, head_dim=32, act="gelu", norm="rmsnorm",
        tie_embeddings=True,
        ssm_state=16, ssm_expand=2, ssm_headdim=32, ssm_conv=4, ssm_chunk=16,
        shared_attn_period=2,
    )
