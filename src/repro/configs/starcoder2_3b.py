"""starcoder2-3b [dense] — GQA(kv=2), RoPE, sliding window 4096, LN+GELU
[arXiv:2402.19173]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
        vocab=49152, head_dim=128, rope_theta=1e5,
        window=4096, window_pattern=-1,  # every layer windowed (native 4k SWA)
        act="gelu", norm="layernorm", tie_embeddings=True,
        source="arXiv:2402.19173",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
        vocab=512, head_dim=32, window=64, window_pattern=-1,
        act="gelu", norm="layernorm", tie_embeddings=True,
    )
