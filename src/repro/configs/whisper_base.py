"""whisper-base [audio] — enc-dec, conv/mel frontend is a STUB (input_specs
provides 1500 frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
        vocab=51865, act="gelu", norm="layernorm", tie_embeddings=True,
        n_enc_layers=6, enc_seq=1500,
        source="arXiv:2212.04356",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab=512, act="gelu", norm="layernorm", tie_embeddings=True,
        n_enc_layers=2, enc_seq=64,
    )
