"""mamba2-130m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab=50280, act="swiglu", norm="rmsnorm", tie_embeddings=True,
        ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
        source="arXiv:2405.21060",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=2, d_model=128, n_heads=0, n_kv_heads=0, d_ff=0,
        vocab=512, act="swiglu", norm="rmsnorm", tie_embeddings=True,
        ssm_state=16, ssm_expand=2, ssm_headdim=32, ssm_conv=4, ssm_chunk=16,
    )
