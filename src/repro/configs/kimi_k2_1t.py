"""kimi-k2-1t-a32b [moe] — trillion-param MoE: 384 experts top-8 + 1 shared
expert, per-expert d_ff=2048, GQA(kv=8) [arXiv:2501.kimi2]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
        vocab=163840, head_dim=128, rope_theta=5e4,
        act="swiglu", norm="rmsnorm", tie_embeddings=False,
        n_experts=384, top_k=8, n_shared_experts=1, capacity_factor=1.25,
        source="arXiv:2501.kimi2",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=64,
        vocab=512, head_dim=32, act="swiglu", norm="rmsnorm",
        tie_embeddings=False, n_experts=4, top_k=2, n_shared_experts=1,
        capacity_factor=8.0,
    )
