"""dbrx-132b [moe] — 16 experts top-4, fine-grained, GQA(kv=8)
[hf:databricks/dbrx-base]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
        vocab=100352, head_dim=128, rope_theta=5e5,
        act="swiglu", norm="layernorm", tie_embeddings=False,
        n_experts=16, top_k=4, capacity_factor=1.25,
        source="hf:databricks/dbrx-base",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke", family="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=512, head_dim=32, act="swiglu", norm="layernorm",
        tie_embeddings=False, n_experts=4, top_k=2, capacity_factor=8.0,
    )
