"""gemma3-4b [dense] — 5:1 local:global sliding-window pattern, GQA(kv=4),
128k context [hf:google/gemma-3-1b-pt]."""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
        vocab=262144, head_dim=256, rope_theta=1e6,
        window=1024, window_pattern=6,  # layers 6,12,... global; rest 1k SWA
        act="swiglu", norm="rmsnorm", tie_embeddings=True, embed_scale=True,
        source="hf:google/gemma-3-1b-pt",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab=512, head_dim=64, window=32, window_pattern=2,
        act="swiglu", norm="rmsnorm", tie_embeddings=True, embed_scale=True,
    )
