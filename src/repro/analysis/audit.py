"""Static program auditor: lower every supported config, evaluate rules.

The audit matrix covers three kinds of point:

* ``sync`` — every sync sub-program (blocking / partial / begin / apply)
  per (layout x wire x mesh/policy), AOT-lowered via
  ``launch/shapes.build_calib_case`` and profiled with
  ``launch/hlo_analysis.payload_profile``;
* ``round`` — full RoundEngine round programs (blocking and overlap at
  depth 0/1/2), lowered with donated state so the donation-aliasing,
  no-host-callback and no-degenerate-replica-group rules run against
  exactly the programs production caches;
* ``cache`` — the compile-cache key space of a full schedule, enumerated
  statically by ``core/engine.enumerate_program_keys`` (zero compiles).

Each point produces a fingerprint (rule verdicts + collective counts /
bytes + donation pairs + program count) and the set is diffed against the
committed ``analysis/audit_baseline.json``; any regression fails with a
readable per-rule diff.  Driven by ``python -m repro.launch.audit``
(which pins the 8-device sim before jax initializes — import this module
only from a process that already did).
"""

from __future__ import annotations

import json
import os

from repro.analysis import rules as R
from repro.analysis import source_lint

SCHEMA = "audit_fingerprint/v1"
BASELINE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "audit_baseline.json")

ARCH = "starcoder2-3b"

# (label, policy, mesh dims) — dp: 4 workers x 2-way model sharding;
# fsdp: 2 pods as workers, buckets chunked over (data, model).
MESHES = (("dp4x2", "dp", (4, 2)), ("fsdp2x2x2", "fsdp", (2, 2, 2)))


def _mesh_of(dims):
    from repro.launch.mesh import make_debug_mesh

    dims = tuple(dims)
    if len(dims) == 2:
        return make_debug_mesh(dims[0], dims[1])
    return make_debug_mesh(dims[1], dims[2], pods=dims[0])


def matrix() -> dict[str, dict]:
    """key -> config for every audited point (JSON-serializable)."""
    out: dict[str, dict] = {}

    def add(key, **cfg):
        out[key] = dict(cfg, key=key)

    for mlabel, policy, dims in MESHES:
        base = dict(kind="sync", arch=ARCH, policy=policy, mesh=list(dims),
                    wire="auto", quantize=False, sync="blocking")
        add(f"sync:{mlabel}:tree:blocking", **dict(base, layout="tree"))
        for q in (False, True):
            tag = ":q" if q else ""
            add(f"sync:{mlabel}:flat:blocking{tag}",
                **dict(base, layout="flat", quantize=q))
            add(f"sync:{mlabel}:flat_sharded:blocking{tag}",
                **dict(base, layout="flat_sharded", quantize=q))
            add(f"sync:{mlabel}:flat_sharded:partial{tag}",
                **dict(base, layout="flat_sharded", sync="partial",
                       quantize=q))
        # the overlap halves, quantized (the production overlap config)
        add(f"sync:{mlabel}:flat_sharded:begin:q",
            **dict(base, layout="flat_sharded", sync="begin", quantize=True))
        add(f"sync:{mlabel}:flat_sharded:apply:q",
            **dict(base, layout="flat_sharded", sync="apply", quantize=True))
        # int8-on-every-wire ring (implies quantize; flat layouts only)
        add(f"sync:{mlabel}:flat_sharded:blocking:ring-int8",
            **dict(base, layout="flat_sharded", wire="ring-int8",
                   quantize=True))

    # round programs: dp mesh only (the fsdp sync paths are covered above;
    # round lowering is the expensive half of the matrix)
    rbase = dict(kind="round", arch=ARCH, policy="dp", mesh=[4, 2],
                 wire="auto", donate=True, h=2)
    add("round:dp4x2:tree:blocking", **dict(rbase, layout="tree",
                                            quantize=False, sync="blocking"))
    add("round:dp4x2:flat_sharded:blocking:q",
        **dict(rbase, layout="flat_sharded", quantize=True, sync="blocking"))
    for d in (0, 1, 2):
        add(f"round:dp4x2:flat_sharded:overlap:d{d}:q",
            **dict(rbase, layout="flat_sharded", quantize=True,
                   sync="overlap", overlap_depth=d))

    # compile-cache key spaces (static; no lowering)
    cbase = dict(kind="cache", h_base=4, total_steps=3000, workers=8)
    add("cache:blocking:w8", **dict(cbase, sync="blocking"))
    add("cache:partial:w8", **dict(cbase, sync="partial"))
    for d in (0, 1, 2):
        add(f"cache:overlap:d{d}:w8", **dict(cbase, sync="overlap",
                                             overlap_depth=d))
    return out


# --------------------------------------------------------------------------
# lowering one point
# --------------------------------------------------------------------------

def _run_cfg(cfg):
    from repro.configs.base import RunConfig

    return RunConfig(sharding=cfg["policy"],
                     sync_quantize=bool(cfg.get("quantize")),
                     sync_wire=cfg.get("wire", "auto"))


def _model_cfg(cfg):
    from repro.configs import registry

    return registry.get_smoke_config(cfg["arch"])


def _lower_sync(cfg) -> dict:
    import jax

    from repro.launch import hlo_analysis as H
    from repro.launch.shapes import build_calib_case

    mesh = _mesh_of(cfg["mesh"])
    case = build_calib_case(_model_cfg(cfg), "train_4k", mesh,
                            policy=cfg["policy"], run_cfg=_run_cfg(cfg),
                            fn_kind="sync", layout=cfg["layout"],
                            sync=cfg["sync"])
    with mesh:
        compiled = jax.jit(case.fn, in_shardings=case.in_shardings,
                           out_shardings=case.out_shardings
                           ).lower(*case.args).compile()
    hlo = compiled.as_text()
    rec = H.payload_profile(hlo, n_leaves=case.meta["n_leaves"])
    rec["n_buckets"] = case.meta["n_buckets"]
    rec["workers"] = case.meta["w"]
    rec["host_callback_lines"] = H.host_callbacks(hlo)
    rec["degenerate_collectives"] = H.degenerate_collectives(hlo)
    return rec


def _lower_round(cfg, donate: bool | None = None) -> dict:
    import jax

    from repro.launch import hlo_analysis as H
    from repro.launch.shapes import build_round_case

    mesh = _mesh_of(cfg["mesh"])
    donate = cfg.get("donate", False) if donate is None else donate
    case = build_round_case(_model_cfg(cfg), mesh, policy=cfg["policy"],
                            run_cfg=_run_cfg(cfg), h=cfg.get("h", 2),
                            layout=cfg["layout"], sync=cfg["sync"],
                            overlap_depth=cfg.get("overlap_depth", 0))
    # mirror RoundEngine._program: overlap rounds donate the pending too
    donate_argnums = (0, 1) if cfg["sync"] == "overlap" else (0,)
    jit_kw = {"donate_argnums": donate_argnums} if donate else {}
    with mesh:
        compiled = jax.jit(case.fn, in_shardings=case.in_shardings,
                           out_shardings=case.out_shardings,
                           **jit_kw).lower(*case.args).compile()
    hlo = compiled.as_text()
    n_leaves = len(jax.tree.leaves(case.args[0]["params"]))
    rec = H.payload_profile(hlo, n_leaves=n_leaves)
    rec["workers"] = case.meta["w"]
    rec["host_callback_lines"] = H.host_callbacks(hlo)
    rec["degenerate_collectives"] = H.degenerate_collectives(hlo)
    aliases = H.donation_aliases(hlo)
    rec["donation_pairs"] = len(aliases)
    # the floor is the STATE leaves only: losing a params/opt alias doubles
    # device memory, but a donated overlap pending may legitimately fail to
    # alias (at depth 0 the input pending stays live across the begin/apply
    # splice, so XLA keeps it).  Deliberately independent of how THIS
    # lowering donated, so the self-test's dropped-donation mutant still
    # owes the config's floor.
    rec["expected_alias_min"] = len(jax.tree.leaves(case.args[0]))
    return rec


def _enumerate_cache(cfg) -> dict:
    from repro.configs.base import RunConfig
    from repro.core import schedules
    from repro.core.engine import enumerate_program_keys, program_bound
    from repro.optim.lr import make_lr_fn

    run_cfg = RunConfig(h_base=cfg["h_base"], total_steps=cfg["total_steps"])
    lr_fn = make_lr_fn(run_cfg)
    keys = enumerate_program_keys(run_cfg, lr_fn, sync=cfg["sync"],
                                  overlap_depth=cfg.get("overlap_depth", 0),
                                  workers=cfg["workers"])
    h_max = max(h for _, h in schedules.rounds(run_cfg, lr_fn))
    limit = program_bound(h_max) + (1 if cfg["sync"] == "overlap" else 0)
    return {"program_keys": [list(k) for k in keys],
            "program_count": len(keys), "program_limit": limit,
            "h_max": h_max}


_FINGERPRINT_FIELDS = (
    "collective_counts", "bytes_on_wire", "payload_all_reduce_ops",
    "amax_fold_ops", "amax_fold_bytes", "reduce_scatter_ops",
    "all_gather_ops", "collective_permute_ops", "payload_bytes_by_dtype",
    "payload_ops_by_dtype", "n_buckets", "n_leaves", "workers",
    "donation_pairs", "expected_alias_min", "program_count",
    "program_limit",
)


def audit_one(cfg: dict) -> dict:
    """Lower (or statically enumerate) one config and produce its
    fingerprint entry: rule verdicts + the measured surface."""
    kind = cfg["kind"]
    if kind == "sync":
        rec = _lower_sync(cfg)
    elif kind == "round":
        rec = _lower_round(cfg)
    elif kind == "cache":
        rec = _enumerate_cache(cfg)
    else:
        raise ValueError(f"unknown audit kind {kind!r}")
    verdicts = R.evaluate(cfg, rec)
    entry = {"config": cfg, "rules": verdicts,
             "rules_failed": R.failed(verdicts)}
    for f in _FINGERPRINT_FIELDS:
        if f in rec:
            entry[f] = rec[f]
    return entry


def run_audit(keys=None) -> dict:
    m = matrix()
    if keys:
        unknown = [k for k in keys if k not in m]
        if unknown:
            raise KeyError(f"unknown audit config(s) {unknown}; "
                           f"see --list for the matrix")
        m = {k: m[k] for k in keys}
    return {"schema": SCHEMA,
            "configs": {k: audit_one(cfg) for k, cfg in sorted(m.items())}}


# --------------------------------------------------------------------------
# baseline diff
# --------------------------------------------------------------------------

_MONOTONE_UP_IS_BAD = (
    "payload_all_reduce_ops", "reduce_scatter_ops", "all_gather_ops",
    "collective_permute_ops", "amax_fold_ops", "bytes_on_wire",
    "program_count",
)


def diff_baseline(fresh: dict, baseline: dict):
    """(regressions, notes): per-rule / per-counter comparison of a fresh
    audit against the committed baseline.  Regressions fail CI; notes are
    improvements or additions that warrant --update-baseline."""
    regressions, notes = [], []
    bcfg = baseline.get("configs", {})
    fcfg = fresh.get("configs", {})
    for key in sorted(bcfg):
        if key not in fcfg:
            regressions.append(f"{key}: config dropped from the audit matrix")
            continue
        b, f = bcfg[key], fcfg[key]
        for rule in sorted(b.get("rules", {})):
            bv = b["rules"][rule]
            fv = f.get("rules", {}).get(rule)
            if fv is None:
                regressions.append(f"{key}: rule {rule} no longer evaluated")
                continue
            if bv["ok"] and not fv["ok"]:
                for viol in fv["violations"] or ["(no detail)"]:
                    regressions.append(f"{key}: {rule}: {viol}")
            elif not bv["ok"] and fv["ok"]:
                notes.append(f"{key}: {rule} now passes")
        for field in _MONOTONE_UP_IS_BAD:
            if field in b and field in f:
                if f[field] > b[field]:
                    regressions.append(
                        f"{key}: {field} grew {b[field]} -> {f[field]}")
                elif f[field] < b[field]:
                    notes.append(
                        f"{key}: {field} shrank {b[field]} -> {f[field]}")
        bd = set(b.get("payload_ops_by_dtype", {}))
        fd = set(f.get("payload_ops_by_dtype", {}))
        if fd - bd:
            regressions.append(
                f"{key}: new payload dtype(s) on the wire: {sorted(fd - bd)}")
        if "donation_pairs" in b:
            if f.get("donation_pairs", 0) < b["donation_pairs"]:
                regressions.append(
                    f"{key}: donation_pairs fell {b['donation_pairs']} -> "
                    f"{f.get('donation_pairs', 0)}")
    for key in sorted(set(fcfg) - set(bcfg)):
        notes.append(f"{key}: new config (not in baseline; "
                     "run --update-baseline to commit it)")
    return regressions, notes


def load_baseline(path: str | None = None) -> dict:
    with open(path or BASELINE_PATH, encoding="utf-8") as fh:
        return json.load(fh)


# --------------------------------------------------------------------------
# mutation self-test — the rules must have teeth
# --------------------------------------------------------------------------

_INJECTED_AR = ("  %mut = f32[999424]{0} all-reduce(f32[999424]{0} %p), "
                "replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add\n")

_BAD_SOURCE = '''
def check(x):
    assert x > 0, x
    if x > 10:
        raise Exception("too big")
    return {"schema": "bogus_record/v1", "x": x}
'''

_CLEAN_SOURCE = '''
from repro.errors import ConfigError


def check(x):
    if x <= 0:
        raise ConfigError(f"x must be positive, got {x}")
    return {"schema": "controller_trace/v1", "x": x}
'''


def self_test() -> list[str]:
    """Prove each rule trips on a deliberately broken program.  Returns
    failure strings (empty = every mutation was caught and every clean
    fixture passed)."""
    import jax

    from repro.launch import hlo_analysis as H

    failures: list[str] = []

    # 1. injected payload all-reduce must trip collective-budget (and the
    #    mutant's f32 payload must trip wire-payload-dtype)
    cfg = matrix()["sync:dp4x2:flat_sharded:blocking:q"]
    mesh = _mesh_of(cfg["mesh"])
    from repro.launch.shapes import build_calib_case

    case = build_calib_case(_model_cfg(cfg), "train_4k", mesh,
                            policy=cfg["policy"], run_cfg=_run_cfg(cfg),
                            fn_kind="sync", layout=cfg["layout"],
                            sync=cfg["sync"])
    with mesh:
        hlo = jax.jit(case.fn, in_shardings=case.in_shardings,
                      out_shardings=case.out_shardings
                      ).lower(*case.args).compile().as_text()

    def profile(text):
        rec = H.payload_profile(text, n_leaves=case.meta["n_leaves"])
        rec["n_buckets"] = case.meta["n_buckets"]
        rec["workers"] = case.meta["w"]
        return rec

    clean = R.evaluate(cfg, profile(hlo))
    if R.failed(clean):
        failures.append(f"clean sync program fails rules: {R.failed(clean)}")
    mutated = R.evaluate(cfg, profile(hlo + _INJECTED_AR))
    if mutated["collective-budget"]["ok"]:
        failures.append("injected payload all-reduce NOT caught by "
                        "collective-budget")
    if mutated["wire-payload-dtype"]["ok"]:
        failures.append("injected f32 payload NOT caught by "
                        "wire-payload-dtype")

    # 2. dropped donation must trip donation-aliasing
    rcfg = matrix()["round:dp4x2:flat_sharded:blocking:q"]
    with_donation = R.evaluate(rcfg, _lower_round(rcfg, donate=True))
    if not with_donation["donation-aliasing"]["ok"]:
        failures.append("donated round fails donation-aliasing: "
                        + "; ".join(
                            with_donation["donation-aliasing"]["violations"]))
    without = R.evaluate(rcfg, _lower_round(rcfg, donate=False))
    if without["donation-aliasing"]["ok"]:
        failures.append("dropped donation NOT caught by donation-aliasing")

    # 3. the source lint must flag a bare assert, a generic raise and an
    #    unregistered schema — and pass the typed-error rewrite
    bad = {v.rule for v in source_lint.lint_source(_BAD_SOURCE, "fixture.py")}
    for rule in ("bare-assert", "raise-generic", "unregistered-schema"):
        if rule not in bad:
            failures.append(f"lint fixture NOT caught by {rule}")
    clean_lint = source_lint.lint_source(_CLEAN_SOURCE, "fixture.py")
    if clean_lint:
        failures.append("clean lint fixture flagged: "
                        + "; ".join(v.render() for v in clean_lint))
    return failures
