"""Central registry of audit-record schema identifiers.

Every versioned record the repo emits (controller traces, bench
rows, serving weight manifests, audit fingerprints, ...) tags itself
with a ``"<name>/v<N>"`` string. This module is the single source of
truth for which identifiers exist: the source lint
(``repro.analysis.source_lint``) flags any ``*/vN`` literal in
``src/repro/`` that is not registered here, so a typo'd or ad-hoc
schema tag cannot ship silently.

Adding a new record kind = add one entry here (with a one-line note of
where it is produced) and use the constant from the producing module.
"""

from __future__ import annotations

import re

# name -> where it is produced / what it tags.
SCHEMAS: dict[str, str] = {
    "controller_trace/v1": "core/controller.py — adaptive controller per-round decision trace",
    "bench_sync/v1": "launch/autotune.py — sync-plan bench rows (BENCH_sync.json)",
    "bench_sync_trajectory/v1": "launch/autotune.py — CI perf-trajectory append artifact",
    "serving_weights/v1": "launch/weights.py — published hot-swap weight manifests",
    "fig2_ab_verdict/v1": "benchmarks/fig2_generalization.py — adaptive-vs-QSR A/B verdict",
    "audit_fingerprint/v1": "analysis/audit.py — static HLO audit fingerprints + baseline",
}

# A schema tag is the *full* string literal, e.g. "controller_trace/v1".
SCHEMA_RE = re.compile(r"[a-z0-9_]+/v\d+")


def is_registered(tag: str) -> bool:
    return tag in SCHEMAS


def looks_like_schema(text: str) -> bool:
    """True if ``text`` is exactly a schema-shaped tag (used by the lint
    to decide which string literals must be registered)."""
    return bool(SCHEMA_RE.fullmatch(text))
