"""Static-analysis layer: lower-time HLO auditing and source linting.

Two layers, both pure analysis — nothing here executes a collective:

* ``repro.analysis.rules`` + ``repro.analysis.audit`` — a declarative
  rule registry evaluated against the AOT-lowered HLO of every
  supported (layout x sync x wire x depth x mesh) configuration, with a
  committed fingerprint baseline (``audit_baseline.json``) that CI
  diffs against.
* ``repro.analysis.source_lint`` — an AST pass over ``src/repro/``
  that flags the ``python -O`` bare-assert hazard class, generic
  ``raise Exception``, and unregistered audit-record schema strings.

Driven by ``python -m repro.launch.audit``.
"""

from repro.analysis.schemas import SCHEMAS, is_registered  # noqa: F401
