"""AST source lint for ``src/repro/`` library code.

Three hazard classes, each of which has bitten this repo before:

* ``bare-assert`` — ``assert`` used for runtime validation in library
  code. Stripped under ``python -O``, turning misconfigurations into
  silent corruption (fixed piecemeal in PRs 4/5/7 via ``TopologyError``,
  ``PendingSyncError``, ``CheckpointError``; this lint closes the class).
  A line may opt out with a ``# lint: allow-assert`` comment — reserved
  for asserts that restate an invariant already enforced upstream and
  that sit on a hot trace path.
* ``raise-generic`` — ``raise Exception(...)`` / ``raise
  AssertionError(...)`` / ``raise BaseException(...)`` where the repo
  has a typed error hierarchy (``repro.errors`` and the subsystem
  errors next to their modules).
* ``unregistered-schema`` — a ``"<name>/vN>"`` record-schema string
  literal that is not registered in ``repro.analysis.schemas.SCHEMAS``.

Tests are exempt (only ``src/repro`` is walked); the schema registry
itself is exempt from the schema rule.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable

from repro.analysis.schemas import SCHEMAS, looks_like_schema

ALLOW_ASSERT_MARK = "lint: allow-assert"
_GENERIC_RAISES = ("Exception", "AssertionError", "BaseException")

LINT_RULES = {
    "bare-assert": "assert used for runtime validation (stripped under python -O)",
    "raise-generic": "raise Exception/AssertionError where a repo error class exists",
    "unregistered-schema": "*/vN schema literal missing from analysis/schemas.SCHEMAS",
}


@dataclasses.dataclass(frozen=True)
class LintViolation:
    path: str
    line: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _raised_name(node: ast.Raise) -> str | None:
    exc = node.exc
    if isinstance(exc, ast.Call):
        exc = exc.func
    if isinstance(exc, ast.Name):
        return exc.id
    return None


def lint_source(
    text: str,
    path: str = "<memory>",
    *,
    registered: Iterable[str] | None = None,
    skip_schema_rule: bool = False,
) -> list[LintViolation]:
    """Lint one module's source text; returns violations in line order."""
    registered = set(SCHEMAS if registered is None else registered)
    lines = text.splitlines()
    tree = ast.parse(text, filename=path)
    out: list[LintViolation] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            raw = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
            if ALLOW_ASSERT_MARK in raw:
                continue
            out.append(
                LintViolation(
                    path,
                    node.lineno,
                    "bare-assert",
                    "bare assert in library code; raise a typed error from "
                    "repro.errors (asserts vanish under python -O)",
                )
            )
        elif isinstance(node, ast.Raise):
            name = _raised_name(node)
            if name in _GENERIC_RAISES:
                out.append(
                    LintViolation(
                        path,
                        node.lineno,
                        "raise-generic",
                        f"raise {name}: use a typed error class "
                        "(repro.errors or a subsystem error)",
                    )
                )
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            if not skip_schema_rule and looks_like_schema(node.value):
                if node.value not in registered:
                    out.append(
                        LintViolation(
                            path,
                            node.lineno,
                            "unregistered-schema",
                            f'schema tag "{node.value}" is not registered in '
                            "repro.analysis.schemas.SCHEMAS",
                        )
                    )
    out.sort(key=lambda v: (v.line, v.rule))
    return out


def _iter_py_files(root: str) -> Iterable[str]:
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def lint_paths(paths: Iterable[str]) -> list[LintViolation]:
    out: list[LintViolation] = []
    for p in paths:
        with open(p, encoding="utf-8") as fh:
            text = fh.read()
        skip_schema = os.path.basename(p) == "schemas.py"
        out.extend(lint_source(text, p, skip_schema_rule=skip_schema))
    return out


def repo_src_root() -> str:
    """The src/repro directory this installed package lives in."""
    import repro

    # repro is a namespace package: no __init__.py, so __file__ is None
    return os.path.abspath(list(repro.__path__)[0])


def lint_repo(src_root: str | None = None) -> list[LintViolation]:
    root = repo_src_root() if src_root is None else src_root
    return lint_paths(_iter_py_files(root))
