"""Declarative rule registry over lowered sync/round programs.

Each rule is a named, self-describing predicate evaluated against a
``(config, record)`` pair — ``config`` describes one point of the
supported matrix (kind x layout x sync program x wire x mesh), ``record``
is what static analysis extracted from that point's AOT-lowered HLO
(``launch/hlo_analysis.payload_profile`` for sync programs, plus
donation/callback/replica-group detail for round programs, plus the
statically-enumerated compile-cache key space).  Nothing here executes a
collective: every verdict is available at lower time.

These rules ARE the repo's communication-efficiency acceptance claims —
"one reduce_scatter + one all_gather per dtype bucket, zero payload
all-reduces, int8 on every ring hop, ≤ ceil(log2 Hmax)+1 programs" — in
one place: ``launch/audit.py`` evaluates them over the whole matrix
against a committed baseline, ``launch/sync_compare.py`` attaches their
verdicts to every record it prints, and the lowering tests in
tests/test_sharded.py / test_ring_sync.py / test_quantized_sharded.py
assert through them instead of through per-test regex forks.

Record keys consumed here (see ``payload_profile``): ``n_buckets``,
``workers``, ``reduce_scatter_ops``, ``all_gather_ops``,
``payload_all_reduce_ops``, ``amax_fold_ops``, ``amax_fold_bytes``,
``collective_permute_ops``, ``payload_ops_by_dtype``, ``all_reduce_ops``,
``n_leaves``; round records add ``donation_pairs`` /
``expected_alias_min`` / ``host_callback_lines`` /
``degenerate_collectives``; cache records use ``program_keys`` /
``program_limit``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

Config = dict
Record = dict


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    description: str
    applies: Callable[[Config], bool]
    check: Callable[[Config, Record], list[str]]


RULES: dict[str, Rule] = {}


def register(rule: Rule) -> Rule:
    RULES[rule.name] = rule
    return rule


def evaluate(config: Config, record: Record) -> dict[str, dict]:
    """All registered rules against one (config, record) point:
    {rule: {"applies": bool, "ok": bool, "violations": [str]}}.
    A rule that does not apply is vacuously ok."""
    out = {}
    for name, rule in sorted(RULES.items()):
        applies = bool(rule.applies(config))
        violations = rule.check(config, record) if applies else []
        out[name] = {"applies": applies, "ok": not violations,
                     "violations": violations}
    return out


def failed(verdicts: dict[str, dict]) -> list[str]:
    return [n for n, v in sorted(verdicts.items()) if not v["ok"]]


# --------------------------------------------------------------------------
# collective-budget — the op-count side of the layout claims
# --------------------------------------------------------------------------

def _budget_applies(cfg: Config) -> bool:
    return cfg.get("kind") == "sync" and cfg.get("layout") in (
        "tree", "flat", "flat_sharded")


def _check_budget(cfg: Config, rec: Record) -> list[str]:
    v: list[str] = []
    nb = rec.get("n_buckets") or 0
    w = cfg.get("workers") or rec.get("workers") or 0
    layout = cfg["layout"]
    quantize = bool(cfg.get("quantize"))
    wire = cfg.get("wire", "auto")
    program = cfg.get("sync", "blocking")

    def expect(field, want, cmp="=="):
        got = rec.get(field, 0)
        ok = got == want if cmp == "==" else got <= want if cmp == "<=" \
            else got >= want
        if not ok:
            v.append(f"{field}: expected {cmp} {want}, lowered {got}")

    if layout == "tree":
        # the motivation for the flat layouts: the tree sync pays one
        # all-reduce per pytree leaf (or more, under model sharding)
        if not quantize:
            expect("all_reduce_ops", rec.get("n_leaves", 0), ">=")
        return v

    if wire == "ring-int8":
        # the ring replaces the one-shot RS entirely: W-1 re-quantizing
        # ppermute hops per bucket, nothing payload-sized all-reduced
        expect("reduce_scatter_ops", 0)
        expect("payload_all_reduce_ops", 0)
        if w and nb:
            expect("collective_permute_ops", (w - 1) * nb, ">=")
        if rec.get("collective_counts", {}).get("all-to-all", 0):
            v.append("all-to-all ops in a ring sync")
        return v

    if layout == "flat":
        # GSPMD worker mean: one payload all-reduce per dtype bucket.
        # Quantized, GSPMD adds its own bucket-sized scale collectives —
        # the cost the RS domain removes — so only a lower bound holds
        # there; exact counts are pinned by the committed audit baseline.
        expect("payload_all_reduce_ops", nb, ">=" if quantize else "==")
        expect("reduce_scatter_ops", 0)
        expect("collective_permute_ops", 0)
        if rec.get("collective_counts", {}).get("all-to-all", 0):
            v.append("all-to-all ops in a flat sync")
        return v

    # flat_sharded, wire=auto: the explicit RS+AG pair per bucket; the only
    # all-reduces allowed are scale-fold-sized (the quantized amax pmax; a
    # partial sync adds per-bucket arrived-count folds)
    expect("payload_all_reduce_ops", 0)
    if program in ("blocking", "partial"):
        expect("reduce_scatter_ops", nb)
        expect("all_gather_ops", nb)
    elif program == "begin":
        expect("reduce_scatter_ops", nb)
        expect("all_gather_ops", 0)
    elif program == "apply":
        expect("reduce_scatter_ops", 0)
        expect("all_gather_ops", nb)
    expect("collective_permute_ops", 0)
    if rec.get("collective_counts", {}).get("all-to-all", 0):
        v.append("all-to-all ops in a sharded sync")
    fold_allow = (1 if quantize else 0) + (nb + 1 if program == "partial" else 0)
    expect("amax_fold_ops", fold_allow, "<=")
    return v


register(Rule(
    "collective-budget",
    "per-bucket RS/AG counts; zero payload all-reduces on sharded paths "
    "(only the tiny scale/count folds allowed); W-1 ppermute hops per "
    "bucket under ring",
    _budget_applies,
    _check_budget,
))


# --------------------------------------------------------------------------
# wire-payload-dtype — the dtype side: what actually rides a quantized wire
# --------------------------------------------------------------------------

def _wire_dtype_name(w: int) -> str:
    from repro.core.sync import wire_dtype

    return {"int8": "s8", "int16": "s16", "int32": "s32"}[
        np.dtype(wire_dtype(w)).name]


def _wire_applies(cfg: Config) -> bool:
    return (cfg.get("kind") == "sync" and bool(cfg.get("quantize"))
            and cfg.get("layout") == "flat_sharded")


def _check_wire(cfg: Config, rec: Record) -> list[str]:
    v: list[str] = []
    w = cfg.get("workers") or rec.get("workers") or 0
    got = set(rec.get("payload_ops_by_dtype", {}))
    if cfg.get("wire") == "ring-int8":
        want = {"s8"}
        label = "every collective-permute hop must carry s8"
    else:
        want = {_wire_dtype_name(w)} if w else set()
        label = f"exact-sum codes travel in wire_dtype({w})"
    for dt in ("f32", "bf16", "f16", "f64"):
        if dt in got:
            v.append(f"float payload {dt} on a quantized wire "
                     f"({rec['payload_ops_by_dtype'][dt]} ops)")
    if want and got != want:
        v.append(f"payload dtypes {sorted(got)} != expected {sorted(want)} "
                 f"({label})")
    return v


register(Rule(
    "wire-payload-dtype",
    "s8-only on every collective-permute hop under ring; no float payloads "
    "under any quantized mode (codes travel in wire_dtype(W))",
    _wire_applies,
    _check_wire,
))


# --------------------------------------------------------------------------
# donation-aliasing — donated state buffers must actually alias outputs
# --------------------------------------------------------------------------

register(Rule(
    "donation-aliasing",
    "input-output aliasing present for donated state buffers (silent "
    "donation loss doubles device memory)",
    lambda cfg: cfg.get("kind") == "round" and bool(cfg.get("donate")),
    lambda cfg, rec: (
        [f"only {rec.get('donation_pairs', 0)} input-output alias pairs in "
         f"the compiled round; expected >= {rec.get('expected_alias_min', 0)} "
         "(donated state leaves)"]
        if rec.get("donation_pairs", 0) < rec.get("expected_alias_min", 0)
        else []),
))


# --------------------------------------------------------------------------
# compile-cache-bound — the H-bucket program-count guarantee, statically
# --------------------------------------------------------------------------

def _check_cache(cfg: Config, rec: Record) -> list[str]:
    keys = rec.get("program_keys", [])
    limit = rec.get("program_limit", 0)
    v = []
    if len(keys) != len({tuple(k) for k in keys}):
        v.append(f"duplicate compile-cache keys enumerated: {keys}")
    if len(keys) > limit:
        v.append(f"{len(keys)} distinct round programs for the schedule, "
                 f"bound is {limit}: {keys}")
    return v


register(Rule(
    "compile-cache-bound",
    "statically enumerated (hp, pending, depth, W) key space stays within "
    "ceil(log2 Hmax)+1 (+1 pending-free first round under overlap)",
    lambda cfg: cfg.get("kind") == "cache",
    _check_cache,
))


# --------------------------------------------------------------------------
# program hygiene — no host round-trips, no do-nothing collectives
# --------------------------------------------------------------------------

register(Rule(
    "no-host-callback",
    "round/sync programs must not round-trip through the host (python "
    "callbacks, infeed/outfeed): one host hop per round serializes the "
    "overlap pipeline and breaks multi-process runs",
    lambda cfg: cfg.get("kind") in ("sync", "round"),
    lambda cfg, rec: [f"host round-trip in lowered program: {ln}"
                      for ln in rec.get("host_callback_lines", [])],
))

register(Rule(
    "no-degenerate-replica-group",
    "no collective whose replica groups are all singletons (moves nothing "
    "between devices — pure launch overhead from a partitioner regression)",
    lambda cfg: cfg.get("kind") in ("sync", "round"),
    lambda cfg, rec: [f"degenerate replica groups: {ln}"
                      for ln in rec.get("degenerate_collectives", [])],
))
