"""Local-gradient runtime (paper Alg. 2) + the data-parallel baseline (Alg. 1).

Worker replicas are an explicit leading axis `W` on params/optimizer state,
sharded over the worker mesh axes (DESIGN.md §2) so replicas diverge between
syncs.  A local step is a vmapped per-worker loss/grad + an elementwise
optimizer update (no cross-worker collective by construction); sync is a
W-axis mean -> one all-reduce every H steps.  `train_round` fuses H local
steps (lax.scan) + sync into one jitted program — the unit the dry-run lowers.

Param layouts: by default state mirrors the model pytree; with a
`core.flat.FlatParamSpace` the same runtime carries params/optimizer state
as a few dtype-bucketed [W, N] buffers (see core/flat.py) — one collective
per bucket at sync, one fused optimizer kernel per bucket per step.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.sync import make_sync, worker_mean
from repro.models.common import scan_unroll
from repro.models import api
from repro.optim.optimizers import make_optimizer

Pytree = Any


def replicate_for_workers(tree: Pytree, w: int) -> Pytree:
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (w,) + x.shape), tree)


def init_state(cfg, run_cfg, params_single: Pytree, w: int) -> Pytree:
    """Build runtime state with a leading worker axis W."""
    opt = make_optimizer(run_cfg)
    params = replicate_for_workers(params_single, w)
    state = {"params": params, "opt": opt.init(params)}
    if run_cfg.sync_quantize or run_cfg.outer_momentum > 0.0:
        state["anchor"] = params_single
        if run_cfg.outer_momentum > 0.0:
            state["outer_mu"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params_single)
    return state


def make_loss(cfg, run_cfg):
    mod = api.get_module(cfg)
    if cfg.n_experts:
        from repro.models import moe as _moe
        _moe.set_dispatch_shards(getattr(run_cfg, "moe_dispatch_shards", 1))
        mode = getattr(run_cfg, "moe_dispatch", "auto")
        _moe.set_dispatch(mode, _moe._DISPATCH_MESH)
    remat = run_cfg.remat
    pol = getattr(run_cfg, "remat_policy", "full")
    if remat and pol in ("save_collectives", "dots"):
        remat = pol
    kw = {}
    if (getattr(run_cfg, "seq_shard_activations", False)
            and cfg.family in ("dense", "moe", "vlm")):
        from jax.sharding import PartitionSpec as P

        def con(h):  # [B, S, D] inside the per-worker vmap
            try:
                return jax.lax.with_sharding_constraint(
                    h, P(None, "model", None))
            except Exception:
                return h  # no mesh in scope (single-device CPU tests)
        kw["act_constraint"] = con
    return partial(mod.loss_fn, cfg, remat=remat, **kw)


def make_local_step(cfg, run_cfg, *, with_metrics: bool = False, spec=None):
    """One per-worker optimizer step: NO cross-worker communication.

    state leaves have leading worker axis W; batch leaves have leading W.
    With `with_metrics=True` the step returns (state, (loss, grad_norm))
    where grad_norm is the worker-mean global gradient L2 norm — computed
    in-graph so the RoundEngine can log it without a second backward pass.

    With `spec` (a core.flat.FlatParamSpace) params/opt are flat dtype
    buckets {bucket: [W, N]}: the loss sees the unflattened view (pure
    slices/reshapes) and gradients are taken w.r.t. the flat buffers
    directly — the transpose of a slice is a disjoint scatter, so each
    element's gradient is bitwise the per-leaf gradient — and the optimizer
    runs one fused update per bucket instead of one per leaf.
    """
    tree_loss_fn = make_loss(cfg, run_cfg)
    if spec is None:
        loss_fn = tree_loss_fn
    else:
        def loss_fn(bufs, batch):
            return tree_loss_fn(spec.unflatten(bufs), batch)
    opt = make_optimizer(run_cfg)

    mb = getattr(run_cfg, "microbatch", 1)

    def _value_and_grad(params, batch):
        """Per-worker loss/grad, optionally microbatched (grad accumulation
        over `mb` sequential chunks — peak activation memory / mb)."""
        if mb <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        chunks = jax.tree.map(
            lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch)

        def body(acc, chunk):
            loss, g = jax.value_and_grad(loss_fn)(params, chunk)
            acc_loss, acc_g = acc
            return (acc_loss + loss / mb,
                    jax.tree.map(lambda a, b: a + b / mb, acc_g, g)), None

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))
        (loss, grads), _ = jax.lax.scan(body, zero, chunks,
                                        unroll=scan_unroll())
        return loss, grads

    def local_step(state, batch, lr):
        w = jax.tree.leaves(batch)[0].shape[0]
        if w == 1:
            # single replica (fsdp pod-worker): skip vmap so explicit
            # shard_map regions (MoE dispatch) can run inside the loss
            loss, g = _value_and_grad(
                jax.tree.map(lambda x: x[0], state["params"]),
                jax.tree.map(lambda x: x[0], batch))
            losses = loss[None]
            grads = jax.tree.map(lambda x: x[None], g)
        else:
            losses, grads = jax.vmap(_value_and_grad)(
                state["params"], batch)
        # optimizer update is elementwise -> applies across the W axis as-is
        params, opt_state = opt.update(state["params"], state["opt"], grads, lr)
        new_state = {**state, "params": params, "opt": opt_state}
        if not with_metrics:
            return new_state, jnp.mean(losses)
        sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)),
                         axis=tuple(range(1, g.ndim)))
                 for g in jax.tree.leaves(grads))       # [W]
        return new_state, (jnp.mean(losses), jnp.mean(jnp.sqrt(sq)))

    return local_step


def make_train_round(cfg, run_cfg):
    """(state, batches [H,W,...], lrs [H]) -> (state, mean_loss).

    The paper-faithful communication round: H local steps, then one
    parameter-average sync."""
    local_step = make_local_step(cfg, run_cfg)
    sync = make_sync(run_cfg)

    def round_fn(state, batches, lrs):
        def body(st, xs):
            batch, lr = xs
            st, loss = local_step(st, batch, lr)
            return st, loss

        state, losses = jax.lax.scan(body, state, (batches, lrs),
                                     unroll=scan_unroll())
        return sync(state), jnp.mean(losses)

    return round_fn


def make_parallel_step(cfg, run_cfg):
    """Data-parallel baseline (paper Alg. 1): gradients are averaged over the
    global batch every step (GSPMD inserts the gradient all-reduce).

    state has NO worker axis; batch leaves are [B_global, ...] sharded over
    the data axes."""
    loss_fn = make_loss(cfg, run_cfg)
    opt = make_optimizer(run_cfg)

    def step(state, batch, lr):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        params, opt_state = opt.update(state["params"], state["opt"], grads, lr)
        return {"params": params, "opt": opt_state}, loss

    return step


def init_parallel_state(cfg, run_cfg, params_single: Pytree) -> Pytree:
    opt = make_optimizer(run_cfg)
    return {"params": params_single, "opt": opt.init(params_single)}
