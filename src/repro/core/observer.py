r"""AsyncObserver: eval/checkpoint off the round loop's critical path.

QSR's payoff is wall-clock — communication hidden behind local steps — yet a
round loop that stops to `jax.device_get` a state snapshot, run eval, and
write a checkpoint re-serializes exactly the latency the overlapped sync
removes.  This module is the other half of `--sync overlap`: observers run
on a background host thread, fed by `RoundEngine.synced_view(state)` (the
pure consensus view — the in-flight pipeline is untouched), so the training
stream never blocks on host I/O.

## The pipeline

    round loop:  [ steps | RS ]  [ steps | AG·apply ... RS ]  [ steps | ...
                        \ synced_view (pure, async dispatch)
    observer:            [ device_get | eval | ckpt write ]      host thread

`submit(step, snapshot)` is O(1) on the round loop's thread: it hands the
*device* arrays over and returns — the expensive `jax.device_get`
(checkpoint/io.py `stage`) and whatever the handler does (eval metrics,
`ckpt_io.save`) happen on the worker.  Because XLA dispatch is async, the
snapshot's computation itself (the deferred gather/apply of `synced_view`)
also overlaps the next round's compute; the worker's device_get is the
first point anything blocks on it.

## Double buffering

At most one snapshot is in flight (being processed) and one queued.  A
submit that finds the queue slot full REPLACES the queued snapshot
(latest-wins) instead of blocking: the training stream never waits for a
slow observer, and the `dropped` counter records how many intermediate
snapshots were superseded — an observer that cannot keep up sees every
*latest* state, not every state.  `drain()` blocks until everything
submitted has been handled (end of run, or a forced sync point); handler
exceptions are re-raised there and by `close()`, never swallowed.
"""
from __future__ import annotations

import threading
from typing import Any, Callable


def fanout(*handlers: Callable[[int, Any], None]) -> Callable[[int, Any], None]:
    """Compose observer handlers: one AsyncObserver feeding several
    consumers — e.g. the checkpoint writer AND a serving
    `WeightSubscriber`/`publish_weights` (launch/weights.py) — so the
    snapshot is staged (device_get) exactly once and every consumer sees
    the identical host tree.  Handlers run in order on the worker thread;
    the first exception propagates (surfaced at drain/close like any
    handler error), so a broken publisher cannot silently eat the
    checkpoint write behind it — order the critical consumer first."""
    def handler(step: int, snapshot: Any) -> None:
        for h in handlers:
            h(step, snapshot)
    return handler


class AsyncObserver:
    """Background worker for eval/checkpoint observers (double-buffered).

    handler(step, snapshot) runs on the worker thread; `snapshot` is
    whatever was submitted — typically a host pytree staged from
    `engine.synced_view(state)` via `checkpoint.io.stage` (the default
    `stage=` hook), so device transfer cost lands on the worker too.
    """

    def __init__(self, handler: Callable[[int, Any], None], *,
                 stage: Callable[[Any], Any] | None = None,
                 merge: Callable[[Any, Any], Any] | None = None):
        from repro.checkpoint import io as ckpt_io
        self._handler = handler
        self._stage = ckpt_io.stage if stage is None else stage
        self._merge = merge
        self._cv = threading.Condition()
        self._queued: tuple[int, Any] | None = None
        self._busy = False
        self._closed = False
        self._error: BaseException | None = None
        self.submitted = 0
        self.processed = 0
        self.dropped = 0
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="repro-observer")
        self._thread.start()

    # -- round-loop side ---------------------------------------------------

    def submit(self, step: int, snapshot: Any) -> None:
        """Hand a (device) snapshot to the worker and return immediately.
        Never blocks on observer work: if the previous snapshot is still
        queued it is superseded (latest-wins; the optional `merge` hook can
        fold must-not-drop flags of the superseded snapshot — e.g. a
        pending checkpoint request — into the newer one)."""
        with self._cv:
            if self._closed:
                raise RuntimeError("observer is closed")
            self._reraise()
            if self._queued is not None:
                self.dropped += 1
                if self._merge is not None:
                    snapshot = self._merge(self._queued[1], snapshot)
            self._queued = (step, snapshot)
            self.submitted += 1
            self._cv.notify_all()

    def drain(self) -> None:
        """Block until every submitted snapshot has been handled; re-raise
        the first handler error if any."""
        with self._cv:
            self._cv.wait_for(lambda: (self._queued is None
                                       and not self._busy)
                              or self._error is not None)
            self._reraise()

    def close(self) -> None:
        """drain(), then stop the worker thread.  Idempotent."""
        with self._cv:
            if self._closed and not self._thread.is_alive():
                self._reraise()
                return
            self._cv.wait_for(lambda: (self._queued is None
                                       and not self._busy)
                              or self._error is not None)
            self._closed = True
            self._cv.notify_all()
        self._thread.join()
        self._reraise()

    def stats(self) -> dict:
        return {"submitted": self.submitted, "processed": self.processed,
                "dropped": self.dropped}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- worker side -------------------------------------------------------

    def _reraise(self):
        if self._error is not None:
            err, self._error = self._error, None
            self._closed = True
            raise err

    def _loop(self):
        while True:
            with self._cv:
                self._cv.wait_for(lambda: self._queued is not None
                                  or self._closed)
                if self._queued is None:          # closed, queue empty
                    return
                step, snap = self._queued
                self._queued = None
                self._busy = True
            try:
                self._handler(step, self._stage(snap))
            except BaseException as e:            # surfaced at drain/close
                with self._cv:
                    self._error = e
                    self._busy = False
                    self._queued = None
                    self._cv.notify_all()
                return
            with self._cv:
                self.processed += 1
                self._busy = False
                self._cv.notify_all()
