"""Synchronization (model-averaging) transforms applied every H steps.

Paper-faithful sync (Alg. 2 line 15): the global iterate is the plain mean of
worker replicas; *optimizer state is not averaged* (Local AdamW keeps local
moments — matching the paper's implementation).

Beyond-paper options (recorded separately in EXPERIMENTS.md §Perf):
  * outer Nesterov momentum on the sync delta (DiLoCo-style),
  * int8-quantized sync deltas (8x cross-pod DCI traffic reduction).
Both require an `anchor` (the params at the previous sync) carried in state.

Layouts (`make_sync(run_cfg, spec=...)`):
  * tree (spec=None) — state mirrors the model pytree; the worker mean
    lowers to one all-reduce per leaf and every quantize/momentum op
    round-trips HBM separately.
  * flat (spec=FlatParamSpace) — state holds one [W, N] buffer per dtype
    bucket (core/flat.py); the mean is one all-reduce per bucket, and the
    quantize + momentum + anchor math runs as one fused pass
    (kernels/sync_update.py).  Per-tensor quantization scales are preserved
    via the spec's segment reductions, keeping the two layouts bitwise-equal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def worker_mean(tree):
    """Mean over the leading worker axis, broadcast back — lowers to a single
    all-reduce over the worker mesh axes under GSPMD (per leaf; per dtype
    bucket when `tree` is a FlatParamSpace bucket dict)."""
    def one(x):
        m = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree.map(one, tree)


def _guarded_scale(amax):
    """int8 scale from a max-|delta| statistic.  Guarded: an all-zero delta
    keeps scale 1 so the round-trip is exactly zero.  (The previous
    `amax + 1e-12` additive guard systematically shrank dequantized values
    by amax/(amax+1e-12) — a 50% bias when amax ~ 1e-12.)"""
    return jnp.where(amax > 0.0, amax, 1.0)


def _quantize_delta(delta):
    """Symmetric per-tensor int8 quantization of the sync delta."""
    def one(d):
        a = _guarded_scale(jnp.max(jnp.abs(d)))
        q = jnp.clip(jnp.round(d / a * 127.0), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * (a / 127.0)
    return jax.tree.map(one, delta)


def flat_delta_scales(spec, bucket: str, p, anchor):
    """Per-tensor int8 scales for one flat bucket, spread to elements [N].

    Identical statistics to the tree path: max|p - anchor| over the worker
    axis and every element of each leaf (max is exact, so the segment
    reduction matches per-leaf `jnp.max` bitwise)."""
    d = jnp.max(jnp.abs(p.astype(jnp.float32)
                        - anchor.astype(jnp.float32)[None]), axis=0)
    return spec.spread(bucket, _guarded_scale(spec.segment_max(bucket, d)))


def make_sync(run_cfg, spec=None):
    """Returns sync(state) -> state.  state = {"params", "opt", "anchor"?,
    "outer_mu"?}; params carry a leading worker axis.  With `spec` (a
    core.flat.FlatParamSpace) the state is flat: params {bucket: [W, N]},
    anchor/outer_mu {bucket: [N]}."""
    quantize = run_cfg.sync_quantize
    mom = run_cfg.outer_momentum
    outer_lr = 1.0

    def sync_flat(state):
        params = state["params"]
        if not quantize and mom == 0.0:
            return {**state, "params": worker_mean(params)}
        anchor = state["anchor"]
        new_state = dict(state)
        new_params, new_anchor = {}, {}
        new_mu = {} if mom > 0.0 else None
        for b in spec.buckets:
            p, a = params[b], anchor[b]
            scale = flat_delta_scales(spec, b, p, a) if quantize else None
            mu = state["outer_mu"][b] if mom > 0.0 else None
            p2, a2, mu2 = kops.sync_flat_update(p, a, scale=scale, mu=mu,
                                                momentum=mom)
            new_params[b], new_anchor[b] = p2, a2
            if mom > 0.0:
                new_mu[b] = mu2
        new_state["params"], new_state["anchor"] = new_params, new_anchor
        if mom > 0.0:
            new_state["outer_mu"] = new_mu
        return new_state

    def sync(state):
        params = state["params"]
        if not quantize and mom == 0.0:
            return {**state, "params": worker_mean(params)}

        anchor = state["anchor"]  # [no worker axis]
        # per-worker delta from the anchor
        delta = jax.tree.map(
            lambda p, a: p.astype(jnp.float32) - a.astype(jnp.float32)[None],
            params, anchor)
        if quantize:
            delta = _quantize_delta(delta)
        mean_delta = jax.tree.map(lambda d: jnp.mean(d, axis=0), delta)

        new_state = dict(state)
        if mom > 0.0:
            mu = jax.tree.map(
                lambda m, d: mom * m + d, state["outer_mu"], mean_delta)
            step_dir = jax.tree.map(      # Nesterov
                lambda m, d: mom * m + d, mu, mean_delta)
            new_state["outer_mu"] = mu
        else:
            step_dir = mean_delta
        new_anchor = jax.tree.map(
            lambda a, s: (a.astype(jnp.float32) + outer_lr * s).astype(a.dtype),
            anchor, step_dir)
        new_state["anchor"] = new_anchor
        new_state["params"] = jax.tree.map(
            lambda a, p: jnp.broadcast_to(a[None], p.shape).astype(p.dtype),
            new_anchor, params)
        return new_state

    return sync_flat if spec is not None else sync
