"""Synchronization (model-averaging) transforms applied every H steps.

Paper-faithful sync (Alg. 2 line 15): the global iterate is the plain mean of
worker replicas; *optimizer state is not averaged* (Local AdamW keeps local
moments — matching the paper's implementation).

Beyond-paper options (recorded separately in EXPERIMENTS.md §Perf):
  * outer Nesterov momentum on the sync delta (DiLoCo-style),
  * int8-quantized sync deltas (8x cross-pod DCI traffic reduction).
Both require an `anchor` (the params at the previous sync) carried in state.

Layouts (`make_sync(run_cfg, spec=...)`):
  * tree (spec=None) — state mirrors the model pytree; the worker mean
    lowers to one all-reduce per leaf and every quantize/momentum op
    round-trips HBM separately.
  * flat (spec=FlatParamSpace) — state holds one [W, N] buffer per dtype
    bucket (core/flat.py); the mean is one all-reduce per bucket, and the
    quantize + momentum + anchor math runs as one fused pass
    (kernels/sync_update.py).  Per-tensor quantization scales are preserved
    via the spec's segment reductions, keeping the two layouts bitwise-equal.
  * flat_sharded (spec=ShardedFlatSpace carrying a mesh) — the worker mean
    decomposes into its two halves, written as explicit collectives: one
    `psum_scatter` (reduce_scatter — each worker reduces the contiguous
    1/W chunk it owns) and one `all_gather` (rebuild the consensus) per
    dtype bucket.  Without a mesh the same state layout runs the flat path
    above on the padded buffers, bitwise-equal to tree/flat.

The two halves are also exposed separately (`make_sync_begin` /
`make_sync_apply`) so the RoundEngine's `--sync overlap` mode can issue the
reduce at the round boundary and defer the gather/apply past the first local
steps of the next round (core/engine.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops


def worker_mean(tree):
    """Mean over the leading worker axis, broadcast back — lowers to a single
    all-reduce over the worker mesh axes under GSPMD (per leaf; per dtype
    bucket when `tree` is a FlatParamSpace bucket dict)."""
    def one(x):
        m = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree.map(one, tree)


def _guarded_scale(amax):
    """int8 scale from a max-|delta| statistic.  Guarded: an all-zero delta
    keeps scale 1 so the round-trip is exactly zero.  (The previous
    `amax + 1e-12` additive guard systematically shrank dequantized values
    by amax/(amax+1e-12) — a 50% bias when amax ~ 1e-12.)"""
    return jnp.where(amax > 0.0, amax, 1.0)


def _quantize_delta(delta):
    """Symmetric per-tensor int8 quantization of the sync delta."""
    def one(d):
        a = _guarded_scale(jnp.max(jnp.abs(d)))
        q = jnp.clip(jnp.round(d / a * 127.0), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * (a / 127.0)
    return jax.tree.map(one, delta)


def flat_delta_scales(spec, bucket: str, p, anchor):
    """Per-tensor int8 scales for one flat bucket, spread to elements [N].

    Identical statistics to the tree path: max|p - anchor| over the worker
    axis and every element of each leaf (max is exact, so the segment
    reduction matches per-leaf `jnp.max` bitwise)."""
    d = jnp.max(jnp.abs(p.astype(jnp.float32)
                        - anchor.astype(jnp.float32)[None]), axis=0)
    return spec.spread(bucket, _guarded_scale(spec.segment_max(bucket, d)))


def _q_roundtrip(d, scale):
    """int8 quantize/dequantize one bucket delta [W, N] with elementwise
    scales [N] — the same math the fused kernel and the tree path run."""
    q = jnp.clip(jnp.round(d / scale[None] * 127.0), -127, 127)
    return q.astype(jnp.int8).astype(jnp.float32) * (scale[None] / 127.0)


# --------------------------------------------------------------------------
# The decomposed sync: reduce (scatter leg) | gather + outer update + apply
# --------------------------------------------------------------------------

def _axt(axes: tuple[str, ...]):
    """Mesh-axis tuple -> PartitionSpec entry."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def _use_collectives(spec) -> bool:
    """True when `spec` is a mesh-carrying ShardedFlatSpace with a real
    worker axis — the explicit reduce_scatter/all_gather decomposition."""
    return (getattr(spec, "mesh", None) is not None
            and bool(getattr(spec, "worker_axes", ())))


def _rs_mean(spec, x, w: int):
    """[W, N] bucket -> worker-mean chunks [W, N/W] via ONE reduce_scatter
    over the worker axes: device (worker i, shard s) ends up owning the i-th
    contiguous 1/W sub-chunk of shard s's mean."""
    from repro.models.common import shard_map_compat

    wt, st = _axt(spec.worker_axes), _axt(spec.shard_axes)

    def body(d):
        s = jax.lax.psum_scatter(d, spec.worker_axes, scatter_dimension=1,
                                 tiled=True)
        return s / w

    return shard_map_compat(body, spec.mesh, in_specs=P(wt, st),
                            out_specs=P(wt, st))(x)


def _ag_mean(spec, pending):
    """Inverse leg: gather the worker-owned chunks [W, N/W] back into the
    full consensus [N] (replicated over workers) via ONE all_gather."""
    from repro.models.common import shard_map_compat

    wt, st = _axt(spec.worker_axes), _axt(spec.shard_axes)

    def body(s):
        return jax.lax.all_gather(s, spec.worker_axes, axis=1, tiled=True)

    out = shard_map_compat(body, spec.mesh, in_specs=P(wt, st),
                           out_specs=P(None, st))(pending)
    return out[0]


def make_sync_begin(run_cfg, spec=None):
    """First half of the sync: the reduce.  begin(state) -> pending, a pure
    function of the pre-sync state (no state mutation).

    pending per bucket/leaf, in f32: the worker-mean params (plain sync) or
    the worker-mean (de)quantized delta from the anchor (quantize/momentum
    sync).  Under a mesh-carrying ShardedFlatSpace the mean is an explicit
    psum_scatter over the worker axes — one reduce_scatter per dtype bucket
    on the wire — and pending stays worker-sharded [W, N/W]; the matching
    all_gather lives in make_sync_apply (the deferrable leg)."""
    quantize = run_cfg.sync_quantize
    mom = run_cfg.outer_momentum
    coll = _use_collectives(spec)

    def mean_w(x):
        return _rs_mean(spec, x, x.shape[0]) if coll else jnp.mean(x, axis=0)

    def begin(state):
        params = state["params"]
        if not quantize and mom == 0.0:
            return jax.tree.map(
                lambda p: mean_w(p.astype(jnp.float32)), params)
        anchor = state["anchor"]
        delta = jax.tree.map(
            lambda p, a: p.astype(jnp.float32) - a.astype(jnp.float32)[None],
            params, anchor)
        if quantize:
            if spec is None:
                delta = _quantize_delta(delta)
            else:
                # per-tensor scales via the spec's segment reductions; under
                # a mesh GSPMD lowers the max/segment ops with its own small
                # collectives — only the delta mean itself is the RS leg
                delta = {b: _q_roundtrip(
                             d, flat_delta_scales(spec, b, params[b],
                                                  anchor[b]))
                         for b, d in delta.items()}
        return jax.tree.map(mean_w, delta)

    return begin


def make_sync_apply(run_cfg, spec=None):
    """Second half of the sync: gather + outer update + apply.

    apply(state, pending, entry_params=None) -> state.
      * entry_params=None — exact mode: params become the consensus
        directly; composed right after begin() this is the blocking sync,
        and deferred one program later with no steps in between (overlap
        depth 0) it stays bitwise the blocking trajectory.
      * entry_params given (the params begin() saw) — correction mode for
        overlap depth > 0: each worker keeps the local progress it made
        while the reduce was in flight, x_i <- x_i + (consensus - entry_i).
    Under a mesh-carrying ShardedFlatSpace the gather is an explicit
    all_gather over the worker axes — the deferred leg of the decomposed
    all-reduce."""
    quantize = run_cfg.sync_quantize
    mom = run_cfg.outer_momentum
    coll = _use_collectives(spec)

    def gather(x):
        return _ag_mean(spec, x) if coll else x

    def to_params(consensus, params, entry):
        if entry is None:
            return jax.tree.map(
                lambda c, p: jnp.broadcast_to(c[None], p.shape
                                              ).astype(p.dtype),
                consensus, params)
        return jax.tree.map(
            lambda c, p, e: (p.astype(jnp.float32)
                             + (c[None] - e.astype(jnp.float32))
                             ).astype(p.dtype),
            consensus, params, entry)

    def apply(state, pending, entry_params=None):
        params = state["params"]
        mean = jax.tree.map(gather, pending)
        if not quantize and mom == 0.0:
            return {**state, "params": to_params(mean, params, entry_params)}
        new_state = dict(state)
        if mom > 0.0:
            mu = jax.tree.map(lambda m, d: mom * m + d,
                              state["outer_mu"], mean)
            step = jax.tree.map(lambda m, d: mom * m + d, mu, mean)
            new_state["outer_mu"] = mu
        else:
            step = mean
        new_anchor = jax.tree.map(
            lambda a, s: (a.astype(jnp.float32) + s).astype(a.dtype),
            state["anchor"], step)
        new_state["anchor"] = new_anchor
        new_state["params"] = to_params(new_anchor, params, entry_params)
        return new_state

    return apply


def make_sync(run_cfg, spec=None):
    """Returns sync(state) -> state.  state = {"params", "opt", "anchor"?,
    "outer_mu"?}; params carry a leading worker axis.  With `spec` (a
    core.flat.FlatParamSpace) the state is flat: params {bucket: [W, N]},
    anchor/outer_mu {bucket: [N]}.  A mesh-carrying ShardedFlatSpace
    composes the two explicit halves back-to-back: the blocking sync is then
    one reduce_scatter + one all_gather per bucket instead of a full
    all-reduce."""
    if _use_collectives(spec):
        begin = make_sync_begin(run_cfg, spec)
        apply_ = make_sync_apply(run_cfg, spec)

        def sync_sharded(state):
            return apply_(state, begin(state))

        return sync_sharded

    quantize = run_cfg.sync_quantize
    mom = run_cfg.outer_momentum
    outer_lr = 1.0

    def sync_flat(state):
        params = state["params"]
        if not quantize and mom == 0.0:
            return {**state, "params": worker_mean(params)}
        anchor = state["anchor"]
        new_state = dict(state)
        new_params, new_anchor = {}, {}
        new_mu = {} if mom > 0.0 else None
        for b in spec.buckets:
            p, a = params[b], anchor[b]
            scale = flat_delta_scales(spec, b, p, a) if quantize else None
            mu = state["outer_mu"][b] if mom > 0.0 else None
            p2, a2, mu2 = kops.sync_flat_update(p, a, scale=scale, mu=mu,
                                                momentum=mom)
            new_params[b], new_anchor[b] = p2, a2
            if mom > 0.0:
                new_mu[b] = mu2
        new_state["params"], new_state["anchor"] = new_params, new_anchor
        if mom > 0.0:
            new_state["outer_mu"] = new_mu
        return new_state

    def sync(state):
        params = state["params"]
        if not quantize and mom == 0.0:
            return {**state, "params": worker_mean(params)}

        anchor = state["anchor"]  # [no worker axis]
        # per-worker delta from the anchor
        delta = jax.tree.map(
            lambda p, a: p.astype(jnp.float32) - a.astype(jnp.float32)[None],
            params, anchor)
        if quantize:
            delta = _quantize_delta(delta)
        mean_delta = jax.tree.map(lambda d: jnp.mean(d, axis=0), delta)

        new_state = dict(state)
        if mom > 0.0:
            mu = jax.tree.map(
                lambda m, d: mom * m + d, state["outer_mu"], mean_delta)
            step_dir = jax.tree.map(      # Nesterov
                lambda m, d: mom * m + d, mu, mean_delta)
            new_state["outer_mu"] = mu
        else:
            step_dir = mean_delta
        new_anchor = jax.tree.map(
            lambda a, s: (a.astype(jnp.float32) + outer_lr * s).astype(a.dtype),
            anchor, step_dir)
        new_state["anchor"] = new_anchor
        new_state["params"] = jax.tree.map(
            lambda a, p: jnp.broadcast_to(a[None], p.shape).astype(p.dtype),
            new_anchor, params)
        return new_state

    return sync_flat if spec is not None else sync
