"""Synchronization (model-averaging) transforms applied every H steps.

Paper-faithful sync (Alg. 2 line 15): the global iterate is the plain mean of
worker replicas; *optimizer state is not averaged* (Local AdamW keeps local
moments — matching the paper's implementation).

Beyond-paper options (recorded separately in EXPERIMENTS.md §Perf):
  * outer Nesterov momentum on the sync delta (DiLoCo-style),
  * int8-quantized sync deltas (8x cross-pod DCI traffic reduction).
Both require an `anchor` (the params at the previous sync) carried in state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def worker_mean(tree):
    """Mean over the leading worker axis, broadcast back — lowers to a single
    all-reduce over the worker mesh axes under GSPMD."""
    def one(x):
        m = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree.map(one, tree)


def _quantize_delta(delta, anchor_dtype):
    """Symmetric per-tensor int8 quantization of the sync delta."""
    def one(d):
        a = jnp.max(jnp.abs(d)) + 1e-12
        q = jnp.clip(jnp.round(d / a * 127.0), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * (a / 127.0)
    return jax.tree.map(one, delta)


def make_sync(run_cfg):
    """Returns sync(state) -> state.  state = {"params", "opt", "anchor"?,
    "outer_mu"?}; params carry a leading worker axis."""
    quantize = run_cfg.sync_quantize
    mom = run_cfg.outer_momentum
    outer_lr = 1.0

    def sync(state):
        params = state["params"]
        if not quantize and mom == 0.0:
            return {**state, "params": worker_mean(params)}

        anchor = state["anchor"]  # [no worker axis]
        # per-worker delta from the anchor
        delta = jax.tree.map(
            lambda p, a: p.astype(jnp.float32) - a.astype(jnp.float32)[None],
            params, anchor)
        if quantize:
            delta = _quantize_delta(delta, None)
        mean_delta = jax.tree.map(lambda d: jnp.mean(d, axis=0), delta)

        new_state = dict(state)
        if mom > 0.0:
            mu = jax.tree.map(
                lambda m, d: mom * m + d, state["outer_mu"], mean_delta)
            step_dir = jax.tree.map(      # Nesterov
                lambda m, d: mom * m + d, mu, mean_delta)
            new_state["outer_mu"] = mu
        else:
            step_dir = mean_delta
        new_anchor = jax.tree.map(
            lambda a, s: (a.astype(jnp.float32) + outer_lr * s).astype(a.dtype),
            anchor, step_dir)
        new_state["anchor"] = new_anchor
        new_state["params"] = jax.tree.map(
            lambda a, p: jnp.broadcast_to(a[None], p.shape).astype(p.dtype),
            new_anchor, params)
        return new_state

    return sync
