"""Synchronization (model-averaging) transforms applied every H steps.

Paper-faithful sync (Alg. 2 line 15): the global iterate is the plain mean of
worker replicas; *optimizer state is not averaged* (Local AdamW keeps local
moments — matching the paper's implementation).

Beyond-paper options (recorded separately in EXPERIMENTS.md §Perf):
  * outer Nesterov momentum on the sync delta (DiLoCo-style),
  * int8-quantized sync deltas (README §Quantized sync: the wire carries
    quantized integer codes, cutting cross-pod DCI bytes per sync).
Both require an `anchor` (the params at the previous sync) carried in state.

## The RS-domain quantization rule

All quantized paths mean the integer *codes* q = clip(round(d/s*127)) and
dequantize once, after the mean: `step = (Σ_i q_i / W) * (s / 127)`.  Σq is a
sum of integers — exact in ANY summation order (|Σ| < 2^24) — so the worker
mean is bitwise-identical whether it runs as a local `jnp.mean`, a GSPMD
all-reduce, an explicit reduce_scatter, or a multi-process gloo collective.
That is what lets the three layouts (and real multi-host execution,
launch/multihost.py) stay bitwise-equal under quantization, which a mean of
dequantized f32 values (the previous formulation) cannot guarantee.

Per-tensor scales are max statistics, also exact under any fold: on the
sharded layout each device computes *shard-local partial amaxes* per tensor
and one tiny `pmax` over the whole mesh folds them ([Σ #leaves] floats — the
only collective besides the RS/AG legs; no GSPMD per-element scale
collectives).

Layouts (`make_sync(run_cfg, spec=...)`):
  * tree (spec=None) — state mirrors the model pytree; the worker mean
    lowers to one all-reduce per leaf and every quantize/momentum op
    round-trips HBM separately.
  * flat (spec=FlatParamSpace) — state holds one [W, N] buffer per dtype
    bucket (core/flat.py); the mean is one all-reduce per bucket, and the
    quantize + momentum + anchor math runs as one fused pass
    (kernels/sync_update.py).  Per-tensor quantization scales are preserved
    via the spec's segment reductions, keeping the two layouts bitwise-equal.
  * flat_sharded (spec=ShardedFlatSpace carrying a mesh) — the worker mean
    decomposes into its two halves, written as explicit collectives: one
    `psum_scatter` (reduce_scatter) and one `all_gather` per dtype bucket.
    Quantized, the two legs carry the integer codes in the exact
    accumulation dtype (int16 while W*127 < 2^15, else int32) — half the
    f32 wire bytes — and the amax fold above replaces the GSPMD scale
    collectives.  Without a mesh the same state layout runs the flat path
    above on the padded buffers, bitwise-equal to tree/flat.

## Wire modes (README §Wire modes)

`run_cfg.sync_wire` picks what the quantized payload looks like on a wire:
"auto" keeps the exact Σq contract above (codes travel in `wire_dtype(W)`,
int16/int32, so the on-wire sum never overflows); "ring-int8" replaces the
one-shot reduce_scatter with a W-hop re-quantizing `ppermute` ring that
keeps int8 on every hop at the price of measured (never assumed) per-hop
requantization noise — see the ring section below.

The two halves are also exposed separately (`make_sync_begin` /
`make_sync_apply`) so the RoundEngine's `--sync overlap` mode can issue the
reduce at the round boundary and defer the gather/apply past the first local
steps of the next round (core/engine.py).  Quantized pending syncs are
`{"q": codes-mean-or-sum, "scale": per-element scales}` — the apply leg
dequantizes and runs the outer update in one fused pass
(kernels/sync_update.py `sync_apply_update`).

## Partial participation (`--sync partial`, README §Elastic training)

`partial=True` variants of the two halves take a per-round membership mask
m ∈ {0,1}^W: the mean runs over the workers that ARRIVED, Σ_i m_i x_i / |P|
with |P| = Σ m.  Absent lanes are masked out of the delta BEFORE the scale
statistic and the quantizer, so (a) the per-tensor amax is exactly the
participant amax (|0| never raises a max), (b) an absent worker's codes are
exactly 0 (contributing nothing to Σq), and (c) the mean stays exact in the
integer-code domain: Σ_{i∈P} q_i is an integer sum in any collective order,
divided by |P| once at apply time — bitwise identical to a W'=|P| run over
the participant rows (tests/test_elastic.py, multihost --mode partial).
With m = 1 everywhere the partial sync is bitwise the blocking sync for
power-of-two W (x·1.0 is exact, and Σ/W — true IEEE division — matches
jnp.mean's multiply-by-reciprocal lowering exactly iff the divisor is a
power of two; for other |P| the partial path itself, m = 1 on the
participant rows, is the bitwise reference).  The exact apply broadcasts the consensus to
ALL W lanes — absent workers re-anchor to consensus the moment they rejoin,
which is what makes local-gradient training naturally fault-tolerant: a
worker lost mid-round costs only its local steps since the last boundary.
The ring wire does not compose with partial masks (the running-mean fold
bakes W into every hop) and raises.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.errors import ConfigError, LayoutError
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def worker_mean(tree):
    """Mean over the leading worker axis, broadcast back — lowers to a single
    all-reduce over the worker mesh axes under GSPMD (per leaf; per dtype
    bucket when `tree` is a FlatParamSpace bucket dict)."""
    def one(x):
        m = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.broadcast_to(m, x.shape).astype(x.dtype)
    return jax.tree.map(one, tree)


def _guarded_scale(amax):
    """int8 scale from a max-|delta| statistic.  Guarded: an all-zero delta
    keeps scale 1 so the round-trip is exactly zero.  (The previous
    `amax + 1e-12` additive guard systematically shrank dequantized values
    by amax/(amax+1e-12) — a 50% bias when amax ~ 1e-12.)"""
    return jnp.where(amax > 0.0, amax, 1.0)


def _quantize_codes(d, scale):
    """Integer codes of a delta under elementwise (broadcastable) scales:
    clip(round(d/s*127)) ∈ [-127, 127], kept in f32 (integer-valued — the
    domain every quantized worker mean runs in)."""
    return jnp.clip(jnp.round(d / scale * 127.0), -127.0, 127.0)


def _quantize_delta(delta):
    """Symmetric per-tensor int8 round-trip of a delta pytree — the
    reference a single worker's wire codes dequantize to (property-tested in
    tests/test_quantize_props.py)."""
    def one(d):
        a = _guarded_scale(jnp.max(jnp.abs(d)))
        q = _quantize_codes(d, a)
        return q.astype(jnp.int8).astype(jnp.float32) * (a / 127.0)
    return jax.tree.map(one, delta)


def flat_delta_scales(spec, bucket: str, p, anchor, mask=None):
    """Per-tensor int8 scales for one flat bucket, spread to elements [N].

    Identical statistics to the tree path: max|p - anchor| over the worker
    axis and every element of each leaf (max is exact, so the segment
    reduction matches per-leaf `jnp.max` bitwise).  A membership `mask`
    ([W] f32) zeroes absent lanes' deltas first, so the statistic is
    exactly the participant amax (|0| never raises a max)."""
    d = jnp.abs(p.astype(jnp.float32) - anchor.astype(jnp.float32)[None])
    if mask is not None:
        d = d * mask[:, None]
    d = jnp.max(d, axis=0)
    return spec.spread(bucket, _guarded_scale(spec.segment_max(bucket, d)))


def partial_segment_amax(d, seg, n_segments: int):
    """Shard-local per-tensor partial amax of one bucket block: d [W_loc,
    n_blk] delta rows, seg [n_blk] local segment ids -> [n_segments] f32.
    Segments absent from this shard report the max-identity (-inf); a max
    fold over all shards (np.maximum / lax.pmax) therefore reconstructs the
    full-tensor amax *exactly* — max is exact, so shard-local partials fold
    to bitwise the unsharded statistic for arbitrary splits (property-tested
    in tests/test_quantize_props.py)."""
    return jax.ops.segment_max(jnp.max(jnp.abs(d), axis=0), seg,
                               num_segments=n_segments)


def wire_dtype(w: int, accum: int | None = None):
    """Smallest integer dtype that holds the on-wire accumulation of int8
    codes exactly — the RS/AG payload type for quantized sharded sync.

    `accum` is the number of codes summed *at once on the wire*: the one-shot
    reduce_scatter folds all W workers in one collective (accum=W, the
    default), so the payload must hold Σq = ±W·127; the re-quantizing ring
    (`--wire ring-int8`) never sums on the wire — each hop carries one freshly
    quantized partial MEAN (accum=1), so int8 always suffices mid-hop."""
    accum = w if accum is None else accum
    if accum <= 1:
        return jnp.int8
    return jnp.int16 if accum * 127 < 2 ** 15 else jnp.int32


# --------------------------------------------------------------------------
# The decomposed sync: reduce (scatter leg) | gather + outer update + apply
# --------------------------------------------------------------------------

def _axt(axes: tuple[str, ...]):
    """Mesh-axis tuple -> PartitionSpec entry (the shared normalization)."""
    from repro.core.flat import axis_entry
    return axis_entry(axes)


def _use_collectives(spec) -> bool:
    """True when `spec` is a mesh-carrying ShardedFlatSpace with a real
    worker axis — the explicit reduce_scatter/all_gather decomposition."""
    return (getattr(spec, "mesh", None) is not None
            and bool(getattr(spec, "worker_axes", ())))


def _rs_mean(spec, x, w: int, mask=None):
    """[W, N] bucket -> worker-mean chunks [W, N/W] via ONE reduce_scatter
    over the worker axes: device (worker i, shard s) ends up owning the i-th
    contiguous 1/W sub-chunk of shard s's mean.  With a membership `mask`
    ([W] f32) the mean runs over the participants only: absent lanes are
    zeroed before the reduce and the divisor is |P| = Σ mask."""
    from repro.models.common import shard_map_compat

    wt, st = _axt(spec.worker_axes), _axt(spec.shard_axes)

    if mask is None:
        def body(d):
            s = jax.lax.psum_scatter(d, spec.worker_axes,
                                     scatter_dimension=1, tiled=True)
            return s / w

        return shard_map_compat(body, spec.mesh, in_specs=P(wt, st),
                                out_specs=P(wt, st))(x)

    def body(d, m):
        cnt = jax.lax.psum(m[0], spec.worker_axes)
        s = jax.lax.psum_scatter(d * m[0], spec.worker_axes,
                                 scatter_dimension=1, tiled=True)
        return s / cnt

    return shard_map_compat(body, spec.mesh, in_specs=(P(wt, st), P(wt)),
                            out_specs=P(wt, st))(x, mask)


def _ag_mean(spec, pending):
    """Inverse leg: gather the worker-owned chunks [W, N/W] back into the
    full consensus [N] (replicated over workers) via ONE all_gather."""
    from repro.models.common import shard_map_compat

    wt, st = _axt(spec.worker_axes), _axt(spec.shard_axes)

    def body(s):
        return jax.lax.all_gather(s, spec.worker_axes, axis=1, tiled=True)

    out = shard_map_compat(body, spec.mesh, in_specs=P(wt, st),
                           out_specs=P(None, st))(pending)
    return out[0]


def _rs_quantized_begin(spec, params, anchor, mask=None):
    """The RS-domain quantized reduce, all dtype buckets in ONE shard_map.

    Per device: local delta block, shard-local partial amaxes per tensor,
    one tiny `pmax` over the whole mesh (a [Σ #leaves]-float fold — the only
    scale collective), int8 codes, then ONE psum_scatter per bucket carrying
    the codes in the exact accumulation dtype (`wire_dtype`).  Returns
    pending {"q": {bucket: [W, N/W] int}, "scale": {bucket: [N] f32}} — "q"
    holds the *sum* Σq (still to be divided by W at apply time).

    With a membership `mask` ([W] f32) each absent lane's delta is zeroed
    BEFORE the amax and the quantizer: scales come from participants only,
    absent codes are exactly 0, so the psum_scatter yields Σ_{i∈P} q_i and
    the pending gains {"count": |P|} for the apply-time division."""
    from repro.models.common import shard_map_compat

    wt, st = _axt(spec.worker_axes), _axt(spec.shard_axes)
    buckets = spec.buckets
    nseg = {b: spec.bucket_leaves(b) for b in buckets}
    seg = {b: jnp.asarray(spec.segment_ids(b)) for b in buckets}
    w = jax.tree.leaves(params)[0].shape[0]
    wdt = wire_dtype(w)

    def body(p, a, sg, *m):
        d = {b: p[b].astype(jnp.float32) - a[b].astype(jnp.float32)[None]
             for b in buckets}
        if m:
            d = {b: d[b] * m[0][0] for b in buckets}
        part = jnp.concatenate(
            [partial_segment_amax(d[b], sg[b], nseg[b]) for b in buckets])
        full = jax.lax.pmax(part, spec.worker_axes + spec.shard_axes)
        off, scales = 0, {}
        for b in buckets:
            per_leaf = _guarded_scale(full[off:off + nseg[b]])
            off += nseg[b]
            # clamped gather == spec.spread: pad ids read the last leaf's
            # scale, harmless — pad deltas are exactly zero
            scales[b] = per_leaf[sg[b]]
        qs = {b: jax.lax.psum_scatter(
                  _quantize_codes(d[b], scales[b][None]).astype(wdt),
                  spec.worker_axes, scatter_dimension=1, tiled=True)
              for b in buckets}
        return qs, scales

    in_specs = [{b: P(wt, st) for b in buckets},
                {b: P(st) for b in buckets},
                {b: P(st) for b in buckets}]
    out_specs = ({b: P(wt, st) for b in buckets},
                 {b: P(st) for b in buckets})
    args = [params, anchor, seg]
    if mask is not None:
        in_specs.append(P(wt))
        args.append(mask)
    qs, scales = shard_map_compat(body, spec.mesh,
                                  in_specs=tuple(in_specs),
                                  out_specs=out_specs)(*args)
    out = {"q": qs, "scale": scales}
    if mask is not None:
        out["count"] = jnp.sum(mask)
    return out


def _ag_codes(spec, qs):
    """Gather leg of the quantized sync: the worker-owned Σq chunks [W, N/W]
    back to the full [N] code sums via ONE all_gather per bucket (one
    shard_map; the payload stays in the integer wire dtype)."""
    from repro.models.common import shard_map_compat

    wt, st = _axt(spec.worker_axes), _axt(spec.shard_axes)

    def body(s):
        return {b: jax.lax.all_gather(s[b], spec.worker_axes, axis=1,
                                      tiled=True) for b in s}

    out = shard_map_compat(body, spec.mesh,
                           in_specs=({b: P(wt, st) for b in qs},),
                           out_specs={b: P(None, st) for b in qs})(qs)
    return {b: out[b][0] for b in out}


# --------------------------------------------------------------------------
# The re-quantizing int8 ring (`--wire ring-int8`)
# --------------------------------------------------------------------------
#
# The exact Σq contract forces wire_dtype(W) — int16/int32 — onto the
# reduce_scatter: partial sums of int8 codes overflow int8.  The ring mode
# drops the exact-sum contract instead: the W-hop ppermute ring maintains the
# running partial MEAN, whose magnitude never exceeds the largest
# contributor's delta, and re-quantizes it to int8 with a fresh shard-local
# scalar scale at every hop — int8 payload on every wire, 2-4x fewer bytes.
# The price is per-hop requantization noise (at most half a level, scale/254,
# per hop); it is MEASURED, not assumed: benchmarks/sde_drift.py runs the
# exact-vs-ring A/B and launch/autotune.py records the drift next to the
# bytes.  Cross-layout/cross-process claims are therefore tolerance-based
# (`ring_tolerance`), never bitwise — deliberately beyond-exact semantics.

WIRE_MODES = ("auto", "ring-int8")


def check_wire(run_cfg) -> str:
    """Validate + return the wire mode.  ring-int8 rides the quantized sync
    machinery (codes + anchor), so it requires sync_quantize."""
    wire = getattr(run_cfg, "sync_wire", "auto")
    if wire not in WIRE_MODES:
        raise ValueError(f"unknown sync_wire {wire!r}; pick from {WIRE_MODES}")
    if wire == "ring-int8" and not run_cfg.sync_quantize:
        raise ValueError("sync_wire='ring-int8' requires sync_quantize=True "
                         "(the ring carries int8 codes of the delta)")
    return wire


def ring_tolerance(w: int, amax, rounds: int = 1):
    """Worst-case |ring mean - exact mean| bound after `rounds` syncs whose
    per-tensor delta amax never exceeded `amax`.

    Per sync: hop k's requantization errs at most s_k/254 <= amax/254 per
    element, attenuated by the remaining mean folds to k/W of that at the
    end; summed over hops plus the final (gather-leg) quantize:
        err <= amax/254 * (Σ_{k=1..W-1} k/W + 1) = amax/254 * (W+1)/2
    Errors across rounds add at most linearly (each round's params feed the
    next delta).  A 2x safety factor absorbs the f32 rounding of the
    fold itself."""
    return float(amax) * (w + 1) / 254.0 * rounds * 2.0


def _linear_worker_index(mesh, axes: tuple[str, ...]):
    """Traced linear index of this device along the worker axes, row-major
    over the tuple — matching how `ppermute` linearizes multi-axis names."""
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _ring_quantized_begin(spec, params, anchor):
    """The int8 ring reduce, all dtype buckets in ONE shard_map.

    Per device the bucket block [1, n_loc] splits into W contiguous
    sub-chunks; worker j seeds the partial destined for worker (j-1) mod W
    and the ring rotates W-1 times, each hop carrying ONE freshly int8-
    quantized partial mean + its f32 scalar scale (jax.lax.ppermute over the
    worker axes — `hlo_analysis` sees W-1 s8 collective-permutes per bucket
    and zero int16/int32 payloads).  The arriving partial is dequantized and
    folded with the local sub-chunk by the fused per-hop requant pass
    (kernels `ring_combine` / `ring_quantize_codes`).  After the last hop
    worker j owns the full W-mean of sub-chunk j, quantized one final time
    for the (deferrable) int8 all_gather leg.

    Returns pending {"q": {bucket: [W, N/W] int8 mean codes},
    "scale": {bucket: [W, S] f32 per-chunk scales}} — unlike the exact path
    the codes already ARE the mean (no /W at apply time) and the scales are
    per ring chunk, not per tensor."""
    from repro.models.common import shard_map_compat

    wt, st = _axt(spec.worker_axes), _axt(spec.shard_axes)
    buckets = spec.buckets
    w = jax.tree.leaves(params)[0].shape[0]
    perm = [(j, (j + 1) % w) for j in range(w)]
    waxes = spec.worker_axes

    def body(p, a):
        i = _linear_worker_index(spec.mesh, waxes)
        qs, ss = {}, {}
        for b in buckets:
            d = p[b].astype(jnp.float32) - a[b].astype(jnp.float32)[None]
            n_loc = d.shape[1]
            if n_loc % w != 0:  # spec pads to W*S chunks
                raise LayoutError(
                    f"ring bucket {b!r}: shard length {n_loc} not divisible "
                    f"by {w} workers")
            dc = d[0].reshape(w, n_loc // w)
            # seed: the partial destined for worker (i-1) mod W
            acc = jnp.take(dc, (i - 1) % w, axis=0)
            s = _guarded_scale(jnp.max(jnp.abs(acc)))
            q = kops.ring_quantize_codes(acc, s)
            for k in range(1, w):
                q = jax.lax.ppermute(q, waxes, perm)
                s = jax.lax.ppermute(s, waxes, perm)
                acc, amax = kops.ring_combine(
                    q, s, jnp.take(dc, (i - 1 - k) % w, axis=0), k)
                s = _guarded_scale(amax)
                q = kops.ring_quantize_codes(acc, s)
            qs[b] = q[None]
            ss[b] = jnp.reshape(s, (1, 1))
        return qs, ss

    in_specs = ({b: P(wt, st) for b in buckets},
                {b: P(st) for b in buckets})
    out_specs = ({b: P(wt, st) for b in buckets},
                 {b: P(wt, st) for b in buckets})
    qs, ss = shard_map_compat(body, spec.mesh, in_specs=in_specs,
                              out_specs=out_specs)(params, anchor)
    return {"q": qs, "scale": ss}


def _ag_ring(spec, pending):
    """Gather leg of the ring sync: ONE int8 all_gather per bucket brings
    every worker's mean sub-chunk (and its scalar scale) to all workers;
    codes are spread back to per-element scales locally — nothing but int8
    payloads and scalar-sized f32 scales ever cross a wire.  Returns
    (step_in {bucket: [N] f32 mean codes}, scales {bucket: [N] f32})."""
    from repro.models.common import shard_map_compat

    wt, st = _axt(spec.worker_axes), _axt(spec.shard_axes)
    buckets = list(pending["q"])

    def body(qs, ss):
        step, scl = {}, {}
        for b in buckets:
            qg = jax.lax.all_gather(qs[b], spec.worker_axes, axis=1,
                                    tiled=True)              # [1, n_loc] s8
            sg = jax.lax.all_gather(ss[b], spec.worker_axes, axis=1,
                                    tiled=True)              # [1, W] f32
            w = sg.shape[1]
            step[b] = qg.astype(jnp.float32)
            scl[b] = jnp.repeat(sg[0], qg.shape[1] // w)[None]
        return step, scl

    in_specs = ({b: P(wt, st) for b in buckets},
                {b: P(wt, st) for b in buckets})
    out_specs = ({b: P(None, st) for b in buckets},
                 {b: P(None, st) for b in buckets})
    step, scl = shard_map_compat(body, spec.mesh, in_specs=in_specs,
                                 out_specs=out_specs)(pending["q"],
                                                      pending["scale"])
    return ({b: step[b][0] for b in buckets}, {b: scl[b][0] for b in buckets})


def ring_codes_host(d, w: int | None = None):
    """Mesh-less emulation of the int8 ring over one bucket delta d [W, N]
    (S=1 chunking), identical per-hop arithmetic to `_ring_quantized_begin`:
    chunk c's partial seeds at worker (c+1) mod W and folds each visitor's
    contribution through the same fused requant pass.  Returns
    (q [W, ceil(N/W)] int8 mean codes, s [W] f32 per-chunk scales) — the
    host reference the drift A/B (benchmarks/sde_drift.py) and the multihost
    tolerance assertions run against."""
    w = d.shape[0] if w is None else w
    n = d.shape[1]
    pad = (-n) % w
    if pad:
        d = jnp.pad(d, ((0, 0), (0, pad)))  # zero delta: exact under requant
    dc = d.reshape(w, w, d.shape[1] // w)   # [worker, chunk, chunk_len]
    qs, ss = [], []
    for c in range(w):
        j0 = (c + 1) % w
        acc = dc[j0, c]
        s = _guarded_scale(jnp.max(jnp.abs(acc)))
        q = kops.ring_quantize_codes(acc, s)
        for k in range(1, w):
            acc, amax = kops.ring_combine(q, s, dc[(j0 + k) % w, c], k)
            s = _guarded_scale(amax)
            q = kops.ring_quantize_codes(acc, s)
        qs.append(q)
        ss.append(s)
    return jnp.stack(qs), jnp.stack(ss)


def _ring_host_begin(spec, params, anchor):
    """Mesh-less ring pending for the flat layouts: per bucket
    {"q": [W, C] int8, "scale": [W] f32} with C = ceil(N/W)."""
    out_q, out_s = {}, {}
    for b in spec.buckets:
        d = (params[b].astype(jnp.float32)
             - anchor[b].astype(jnp.float32)[None])
        out_q[b], out_s[b] = ring_codes_host(d)
    return {"q": out_q, "scale": out_s}


def _ring_host_gather(pending, anchor):
    """Flatten mesh-less ring pending back to per-element (step_in, scales)
    matching `_ag_ring`'s output — same fused apply path either way."""
    step, scl = {}, {}
    for b in pending["q"]:
        q, s = pending["q"][b], pending["scale"][b]
        n = anchor[b].shape[0]
        step[b] = q.reshape(-1)[:n].astype(jnp.float32)
        scl[b] = jnp.repeat(s, q.shape[1])[:n]
    return step, scl


def pending_specs(run_cfg, spec):
    """PartitionSpec tree of the pending sync (`make_sync_begin`'s output)
    under a mesh-carrying ShardedFlatSpace — what a program that *threads*
    the pending across its boundary (the RoundEngine's overlap round,
    launch/shapes.py's lowering case) declares as the in/out sharding.

    The reduce_scatter leg leaves the pending worker-sharded: each device
    owns the 1/W sub-chunk of its shard it reduced, so payloads sit at
    [W, N/W] over (worker_axes, shard_axes).  Quantized pending carries the
    integer code-sums at that sharding plus the per-element scales, which
    are shard-local only ([N] over shard_axes).  Ring pending differs: the
    scales are per ring chunk — one scalar per (worker, shard) device — so
    they share the payload's (worker_axes, shard_axes) sharding."""
    wt, st = _axt(spec.worker_axes), _axt(spec.shard_axes)
    payload = {b: P(wt, st) for b in spec.buckets}
    if run_cfg.sync_quantize:
        if check_wire(run_cfg) == "ring-int8":
            return {"q": payload, "scale": dict(payload)}
        return {"q": payload, "scale": {b: P(st) for b in spec.buckets}}
    return payload


def make_sync_begin(run_cfg, spec=None, partial: bool = False):
    """First half of the sync: the reduce.  begin(state) -> pending, a pure
    function of the pre-sync state (no state mutation).

    pending per bucket/leaf: the worker-mean params in f32 (plain sync), the
    worker-mean delta from the anchor (momentum-only sync), or — quantized —
    {"q": worker-mean integer codes, "scale": per-element scales}.  Under a
    mesh-carrying ShardedFlatSpace the mean is an explicit psum_scatter over
    the worker axes — one reduce_scatter per dtype bucket on the wire,
    carrying integer codes when quantized — and pending stays worker-sharded
    [W, N/W] (codes as the un-divided sum Σq); the matching all_gather lives
    in make_sync_apply (the deferrable leg).

    partial=True: begin(state, mask) with mask [W] f32 ∈ {0,1} — the mean
    runs over the participants only (module docstring §Partial
    participation).  Plain/momentum pendings arrive already divided by |P|;
    quantized pendings carry the undivided Σ_{i∈P} q_i plus {"count": |P|}
    for the apply-time division (the exact integer-code domain)."""
    quantize = run_cfg.sync_quantize
    mom = run_cfg.outer_momentum
    wire = check_wire(run_cfg)
    coll = _use_collectives(spec)
    if wire == "ring-int8" and spec is None:
        raise ValueError("sync_wire='ring-int8' needs a flat layout "
                         "(--param-layout flat | flat_sharded): the ring "
                         "chunks a bucket, not a pytree leaf")
    if wire == "ring-int8" and partial:
        raise ValueError("sync_wire='ring-int8' does not compose with "
                         "partial participation: the running-mean ring "
                         "bakes W into every hop — use wire='auto'")

    def mean_w(x, mask=None):
        if coll:
            return _rs_mean(spec, x, x.shape[0], mask)
        if mask is None:
            return jnp.mean(x, axis=0)
        shape = (mask.shape[0],) + (1,) * (x.ndim - 1)
        return jnp.sum(x * mask.reshape(shape), axis=0) / jnp.sum(mask)

    def begin(state, mask=None):
        params = state["params"]
        if not quantize and mom == 0.0:
            return jax.tree.map(
                lambda p: mean_w(p.astype(jnp.float32), mask), params)
        anchor = state["anchor"]
        if wire == "ring-int8":
            return (_ring_quantized_begin(spec, params, anchor) if coll
                    else _ring_host_begin(spec, params, anchor))
        if quantize and coll:
            return _rs_quantized_begin(spec, params, anchor, mask)
        delta = jax.tree.map(
            lambda p, a: p.astype(jnp.float32) - a.astype(jnp.float32)[None],
            params, anchor)
        if mask is not None and not coll:
            # zero absent lanes BEFORE the scale statistic and the quantizer
            # (the collective paths mask inside their shard_map bodies)
            delta = jax.tree.map(
                lambda d: d * mask.reshape((mask.shape[0],)
                                           + (1,) * (d.ndim - 1)), delta)
        if quantize:
            if spec is None:
                scales = jax.tree.map(
                    lambda d: _guarded_scale(jnp.max(jnp.abs(d))), delta)
            else:
                scales = {b: flat_delta_scales(spec, b, params[b], anchor[b],
                                               mask)
                          for b in spec.buckets}
            if mask is None:
                qmean = jax.tree.map(
                    lambda d, s: jnp.mean(_quantize_codes(d, s[None] if
                                                          jnp.ndim(s) else s),
                                          axis=0),
                    delta, scales)
                return {"q": qmean, "scale": scales}
            qsum = jax.tree.map(
                lambda d, s: jnp.sum(_quantize_codes(d, s[None] if
                                                     jnp.ndim(s) else s),
                                     axis=0),
                delta, scales)
            return {"q": qsum, "scale": scales, "count": jnp.sum(mask)}
        if coll:
            return jax.tree.map(lambda d: mean_w(d, mask), delta)
        if mask is not None:   # delta already masked above
            return jax.tree.map(
                lambda d: jnp.sum(d, axis=0) / jnp.sum(mask), delta)
        return jax.tree.map(lambda d: jnp.mean(d, axis=0), delta)

    if partial:
        def begin_partial(state, mask):
            return begin(state, mask)
        return begin_partial
    return begin


def make_sync_apply(run_cfg, spec=None, partial: bool = False):
    """Second half of the sync: gather + outer update + apply.

    apply(state, pending, entry_params=None) -> state.
      * entry_params=None — exact mode: params become the consensus
        directly; composed right after begin() this is the blocking sync,
        and deferred one program later with no steps in between (overlap
        depth 0) it stays bitwise the blocking trajectory.
      * entry_params given (the params begin() saw) — correction mode for
        overlap depth > 0: each worker keeps the local progress it made
        while the reduce was in flight, x_i <- x_i + (consensus - entry_i).
    Under a mesh-carrying ShardedFlatSpace the gather is an explicit
    all_gather over the worker axes — the deferred leg of the decomposed
    all-reduce; quantized it carries the integer code sums, divided by W and
    dequantized here (fused with the outer Nesterov + anchor update in one
    kernels/sync_update.py `sync_apply_update` pass per bucket).

    partial=True pendings (make_sync_begin(..., partial=True)) carry the
    participant count when quantized: the code sums divide by |P| =
    pending["count"] instead of W.  The exact apply (entry_params=None)
    still broadcasts the consensus to ALL W lanes — absent workers
    re-anchor to consensus on rejoin."""
    quantize = run_cfg.sync_quantize
    mom = run_cfg.outer_momentum
    wire = check_wire(run_cfg)
    coll = _use_collectives(spec)
    del partial  # pendings self-describe via their "count" entry

    def gather(x):
        return _ag_mean(spec, x) if coll else x

    def to_params(consensus, params, entry):
        if entry is None:
            return jax.tree.map(
                lambda c, p: jnp.broadcast_to(c[None], p.shape
                                              ).astype(p.dtype),
                consensus, params)
        return jax.tree.map(
            lambda c, p, e: (p.astype(jnp.float32)
                             + (c[None] - e.astype(jnp.float32))
                             ).astype(p.dtype),
            consensus, params, entry)

    def apply(state, pending, entry_params=None):
        params = state["params"]
        if not quantize and mom == 0.0:
            mean = jax.tree.map(gather, pending)
            return {**state, "params": to_params(mean, params, entry_params)}
        new_state = dict(state)
        if quantize:
            if wire == "ring-int8":
                # the ring already holds the MEAN (no /W); scales arrive per
                # ring chunk and spread to elements with the gather
                step_in, scales = (_ag_ring(spec, pending) if coll else
                                   _ring_host_gather(pending, state["anchor"]))
            elif coll:
                div = pending.get("count")
                if div is None:
                    div = jax.tree.leaves(params)[0].shape[0]
                qmean = {b: q.astype(jnp.float32) / div
                         for b, q in _ag_codes(spec, pending["q"]).items()}
                scales = pending["scale"]
                step_in = qmean
            else:
                cnt = pending.get("count")
                step_in = (pending["q"] if cnt is None else jax.tree.map(
                    lambda q: q / cnt, pending["q"]))
                scales = pending["scale"]
        else:
            step_in = jax.tree.map(gather, pending)
            scales = None
        mu_in = state["outer_mu"] if mom > 0.0 else None
        if spec is not None:
            new_anchor = {}
            new_mu = {} if mom > 0.0 else None
            for b in spec.buckets:
                a2, mu2 = kops.sync_apply_update(
                    step_in[b], state["anchor"][b],
                    scale=scales[b] if quantize else None,
                    mu=mu_in[b] if mom > 0.0 else None, momentum=mom)
                new_anchor[b] = a2
                if mom > 0.0:
                    new_mu[b] = mu2
        else:
            ls, treedef = jax.tree.flatten(step_in)
            la = treedef.flatten_up_to(state["anchor"])
            lsc = treedef.flatten_up_to(scales) if quantize else [None] * len(ls)
            lmu = treedef.flatten_up_to(mu_in) if mom > 0.0 else [None] * len(ls)
            outs = [kref.sync_apply_update(s, a, scale=sc, mu=m, momentum=mom)
                    for s, a, sc, m in zip(ls, la, lsc, lmu)]
            new_anchor = jax.tree.unflatten(treedef, [o[0] for o in outs])
            new_mu = (jax.tree.unflatten(treedef, [o[1] for o in outs])
                      if mom > 0.0 else None)
        new_state["anchor"] = new_anchor
        if mom > 0.0:
            new_state["outer_mu"] = new_mu
        new_state["params"] = to_params(new_anchor, params, entry_params)
        return new_state

    return apply


def make_sync(run_cfg, spec=None):
    """Returns sync(state) -> state.  state = {"params", "opt", "anchor"?,
    "outer_mu"?}; params carry a leading worker axis.  With `spec` (a
    core.flat.FlatParamSpace) the state is flat: params {bucket: [W, N]},
    anchor/outer_mu {bucket: [N]}.  A mesh-carrying ShardedFlatSpace
    composes the two explicit halves back-to-back: the blocking sync is then
    one reduce_scatter + one all_gather per bucket instead of a full
    all-reduce (quantized: integer-code payloads + one tiny amax pmax).
    A mesh-less flat spec runs the one-pass fused kernel instead."""
    quantize = run_cfg.sync_quantize
    mom = run_cfg.outer_momentum
    wire = check_wire(run_cfg)

    if spec is not None and not _use_collectives(spec) and wire != "ring-int8":
        def sync_flat(state):
            params = state["params"]
            if not quantize and mom == 0.0:
                return {**state, "params": worker_mean(params)}
            anchor = state["anchor"]
            new_state = dict(state)
            new_params, new_anchor = {}, {}
            new_mu = {} if mom > 0.0 else None
            for b in spec.buckets:
                p, a = params[b], anchor[b]
                scale = flat_delta_scales(spec, b, p, a) if quantize else None
                mu = state["outer_mu"][b] if mom > 0.0 else None
                p2, a2, mu2 = kops.sync_flat_update(p, a, scale=scale, mu=mu,
                                                    momentum=mom)
                new_params[b], new_anchor[b] = p2, a2
                if mom > 0.0:
                    new_mu[b] = mu2
            new_state["params"], new_state["anchor"] = new_params, new_anchor
            if mom > 0.0:
                new_state["outer_mu"] = new_mu
            return new_state

        return sync_flat

    # tree layout and the mesh-carrying sharded layout compose the two
    # explicit halves back-to-back (identical op sequence to the fused flat
    # kernel, so the layouts stay bitwise-equal)
    begin = make_sync_begin(run_cfg, spec)
    apply_ = make_sync_apply(run_cfg, spec)

    def sync_composed(state):
        return apply_(state, begin(state))

    return sync_composed


def make_sync_partial(run_cfg, spec=None):
    """Partial-participation sync: sync(state, mask) -> state, the two
    halves composed with a membership mask (module docstring §Partial
    participation).  Every layout runs the composed begin/apply — there is
    no fused partial kernel — so the mask semantics are identical across
    tree/flat/flat_sharded, and an all-ones mask is bitwise the composed
    blocking sync (which the flat fused kernel is proven equal to)."""
    begin = make_sync_begin(run_cfg, spec, partial=True)
    apply_ = make_sync_apply(run_cfg, spec, partial=True)

    def sync_partial(state, mask):
        return apply_(state, begin(state, mask))

    return sync_partial


SYNC_PROGRAMS = ("blocking", "partial", "begin", "apply")


def sync_program(run_cfg, spec=None, program: str = "blocking"):
    """The lowering seam for static analysis: one callable per sync
    sub-program, named.  `blocking` and `partial` are the whole-sync
    callables; `begin`/`apply` are the overlap halves — `begin` is the
    scatter leg a round boundary launches, `apply` the gather leg hidden
    behind the next round's first local steps.  The audit CLI
    (launch/audit.py) AOT-lowers each of these per (layout, wire, mesh)
    and evaluates the declarative rule registry against the HLO; nothing
    here executes."""
    if program == "blocking":
        return make_sync(run_cfg, spec=spec)
    if program == "partial":
        return make_sync_partial(run_cfg, spec=spec)
    if program == "begin":
        return make_sync_begin(run_cfg, spec=spec)
    if program == "apply":
        return make_sync_apply(run_cfg, spec=spec)
    raise ConfigError(
        f"unknown sync program {program!r}; pick from {SYNC_PROGRAMS}")
