"""Closed-loop adaptive controller: co-schedule H, batch size, and overlap
depth from the engine's in-graph telemetry (`--schedule adaptive`).

QSR sets H from the learning rate alone — H = (alpha/eta)^2 — but every
round the RoundEngine already measures, in-graph, the three quantities the
rule's derivation reasons about: the round loss, the worker-mean gradient
norm, and the pre-sync worker divergence `mean_i ||x_i - x_bar||`.  This
module closes the loop.  At each round *boundary* (decisions never move
mid-round — the same discipline as `membership_epoch`) the controller:

* **H** — starts from the QSR prior (`schedules.get_h`, kind "adaptive"
  returns exactly the quadratic rule, so warmup pinning and final-round
  truncation hold unchanged) and corrects it by the measured divergence.
  The SDE picture behind QSR says pre-sync divergence grows like
  `kappa * eta * sqrt(H)` for a noise level `kappa`.  Two EMAs of the
  measured kappa run at different time constants: a fast one (the signal)
  and a slow one seeded at the first post-warmup round (the reference —
  the drift trend the quadratic rule is currently calibrated to).  When
  the fast signal runs hotter than its own trend the workers are drifting
  faster than the rule assumes and H shrinks below quadratic; cooler, and
  H extends modestly beyond it:

      H = clip(prior * (kappa_ref / kappa_ema)^2,  prior/4,  prior*4)

  Comparing the signal to its trend (rather than to a frozen calibration
  constant) keeps a smooth run near the QSR prior — the correction only
  bites on genuine deviations, and an early-training transient cannot
  bias every later round.

  still floored at h_base and truncated at the horizon, like every kind.

* **batch** — per Lau et al. 2024 (Communication-Efficient Adaptive Batch
  Size Strategies for Distributed Local Gradient Methods, PAPERS.md), batch
  size should co-adapt with the sync period: small batches early, when
  progress is gradient-dominated and noise is cheap (it is what large-H
  local steps exploit), growing as gradient noise starts to dominate.  The
  signal is the per-step loss improvement EMA: when it decays below
  `batch_growth_frac` of the best improvement seen, the per-worker batch
  doubles (monotone — a ratchet, never shrinking), up to the engine's
  allocated `b_loc`.  Batch changes ride a `batch_epoch()` — a round-
  boundary, MembershipEpoch-style audit record — and cost **zero
  recompiles**: the engine's effective batch is a *traced* lane count
  (data/synthetic.py `effective_batch_view`), so the compiled round
  programs are untouched (tests/test_controller.py asserts the compile
  budget stays the H-bucket bound).

* **overlap depth** — chosen on the measured staleness/walltime frontier
  from benchmarks/table4_walltime.py (the `overlap` section's s/round
  rows, or any {depth: s_per_round} mapping).  Depth d runs the next
  round's first d steps on stale params; the controller allows d where the
  predicted extra drift `d * kappa_ema * eta` stays within `stale_frac` of
  the round's own divergence budget, then takes the fastest allowed depth.
  Only consulted when the engine runs `sync="overlap"`; depth moves at
  round boundaries through `engine.set_overlap_depth` (at most one compile
  per (bucket, depth) pair ever, depth's own small cache axis).

Every decision is appended to an in-memory trace and can be persisted as
`controller_trace.json` (schema "controller_trace/v1") — the stream the
fig2 A/B benchmark, the regression tests, and the CI `controller` job all
consume.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Callable

from repro.core import schedules

TRACE_SCHEMA = "controller_trace/v1"


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Knob bounds and feedback gains.  Defaults are deliberately gentle:
    the controller should refine QSR, not fight it."""
    # H correction: clip of (kappa_ref / kappa_ema)^2 applied to the prior
    h_correction_bounds: tuple[float, float] = (0.25, 4.0)
    # EMA weights for the divergence-rate signal kappa = div / (eta sqrt(H)):
    # the fast EMA is the signal, the slow one the reference trend the H
    # correction compares it against
    kappa_ema: float = 0.5
    kappa_ema_slow: float = 0.15
    # batch: start at b_loc / batch_start_div (largest pow2 divisor <= it),
    # double when the improvement EMA falls below batch_growth_frac * best
    batch_start_div: int = 2
    batch_growth_frac: float = 0.35
    imp_ema: float = 0.5
    # overlap depth: allowed when d * kappa_ema * eta <= stale_frac * the
    # round's own predicted divergence kappa_ref * eta * sqrt(h)
    stale_frac: float = 0.5
    depth_choices: tuple[int, ...] = (0, 1, 2)


def _pow2_divisor_at_most(b: int, target: int) -> int:
    """Largest divisor of b that is a power of two and <= target (>= 1)."""
    d = 1
    while d * 2 <= target and b % (d * 2) == 0:
        d *= 2
    return d


class AdaptiveController:
    """One instance per run.  Drive it as a pair around each round:

        h = ctrl.begin_round(t)          # decide + apply knobs to engine
        state, m = eng.run_round(state, t, h, lr_fn)
        ctrl.end_round(t, h, m)          # feed back measured telemetry

    `engine` is optional: without one the controller still produces the H
    stream (pure decisions, unit-testable); with one it also drives the
    batch knob (`engine.batch_epoch`, engines built with
    `adaptive_batch=True`) and — under sync="overlap" with a `frontier` —
    the overlap depth (`engine.set_overlap_depth`).
    """

    def __init__(self, run_cfg, lr_fn: Callable[[int], float], *,
                 engine=None, cfg: ControllerConfig | None = None,
                 frontier: dict[int, float] | None = None):
        if run_cfg.schedule != "adaptive":
            raise ValueError(
                f"AdaptiveController drives schedule='adaptive', run_cfg "
                f"has {run_cfg.schedule!r}")
        self.run_cfg, self.lr_fn = run_cfg, lr_fn
        self.cfg = cfg or ControllerConfig()
        self.engine = engine
        # {depth: s_per_round} — the measured walltime frontier
        # (benchmarks/table4_walltime.py); depths outside depth_choices are
        # ignored, depth 0 is always a candidate
        self.frontier = ({int(k): float(v) for k, v in frontier.items()
                          if int(k) in self.cfg.depth_choices}
                         if frontier else None)
        self._adaptive_batch = bool(engine is not None
                                    and getattr(engine, "adaptive_batch",
                                                False))
        self._adaptive_depth = bool(
            engine is not None and self.frontier
            and getattr(engine, "sync_mode", "blocking") == "overlap")
        b_loc = getattr(engine, "b_loc", 1) if engine is not None else 1
        self.batch_lanes = (_pow2_divisor_at_most(
            b_loc, max(1, b_loc // self.cfg.batch_start_div))
            if self._adaptive_batch else b_loc)
        self.b_loc = b_loc
        # feedback state
        self.kappa_ref: float | None = None     # slow EMA (the trend)
        self.kappa: float | None = None         # fast EMA of div/(eta sqrt h)
        self.imp: float | None = None           # EMA per-step loss drop
        self.best_imp: float = 0.0
        self.last_loss: float | None = None
        self.overlap_depth = (getattr(engine, "overlap_depth", 0)
                              if engine is not None else 0)
        self.trace: list[dict] = []
        self._open: dict | None = None          # row awaiting end_round

    # -- decision ---------------------------------------------------------

    def _eta(self, t: int) -> float:
        return float(self.lr_fn(max(t, self.run_cfg.warmup_steps)))

    def _decide_h(self, t: int) -> tuple[int, int, float, list[str]]:
        prior = schedules.get_h(self.run_cfg, t, self.lr_fn)
        reasons = []
        corr = 1.0
        if t < self.run_cfg.warmup_steps:
            # §2 warmup pin: the prior is already pinned; telemetry from
            # warmup rounds is not trusted to steer H
            reasons.append("warmup-pin")
        elif self.kappa_ref is None or not self.kappa:
            reasons.append("calibrating")
        else:
            lo, hi = self.cfg.h_correction_bounds
            corr = min(max((self.kappa_ref / self.kappa) ** 2, lo), hi)
            reasons.append("div-corrected")
        h = max(self.run_cfg.h_base, int(prior * corr))
        h = max(1, min(h, self.run_cfg.total_steps - t))   # truncation (§2)
        return h, prior, corr, reasons

    def _decide_batch(self, t: int, reasons: list[str]) -> int:
        if not self._adaptive_batch:
            return self.batch_lanes
        if (t >= self.run_cfg.warmup_steps and self.imp is not None
                and self.best_imp > 0.0
                and self.imp < self.cfg.batch_growth_frac * self.best_imp
                and self.batch_lanes < self.b_loc):
            self.batch_lanes = min(self.b_loc, self.batch_lanes * 2)
            # ratchet: the grown batch gets a fresh improvement baseline
            self.best_imp = self.imp if self.imp > 0.0 else 0.0
            reasons.append("batch-grow")
        return self.batch_lanes

    def _decide_depth(self, t: int, h: int, reasons: list[str]) -> int:
        if not self._adaptive_depth:
            return self.overlap_depth
        eta = self._eta(t)
        kap = self.kappa if self.kappa else None
        ref = self.kappa_ref if self.kappa_ref else kap
        allowed = {0}
        if kap is not None and ref is not None and kap > 0.0 and eta > 0.0:
            budget = self.cfg.stale_frac * ref * math.sqrt(max(h, 1))
            allowed |= {d for d in self.frontier
                        if d > 0 and d * kap <= budget}
        else:
            reasons.append("depth-hold-calibrating")
        cost = lambda d: self.frontier.get(
            d, 0.0 if d == 0 else float("inf"))
        best = min(sorted(allowed), key=cost)
        if best != self.overlap_depth:
            reasons.append(f"depth->{best}")
            self.overlap_depth = best
        return self.overlap_depth

    def begin_round(self, t: int) -> int:
        """Decide (H, batch lanes, overlap depth) for the round starting at
        step t, apply the batch/depth knobs to the attached engine, and
        return H.  Must alternate with end_round — decisions are round-
        boundary-only by construction."""
        if self._open is not None:
            raise RuntimeError(
                "begin_round called twice without end_round: controller "
                "decisions are round-boundary-only")
        h, prior, corr, reasons = self._decide_h(t)
        lanes = self._decide_batch(t, reasons)
        depth = self._decide_depth(t, h, reasons)
        if self.engine is not None:
            if self._adaptive_batch and self.engine.batch_lanes != lanes:
                self.engine.batch_epoch(lanes)
            if self._adaptive_depth and self.engine.overlap_depth != depth:
                self.engine.set_overlap_depth(depth)
        self._open = {
            "t": int(t), "h": int(h), "h_prior": int(prior),
            "h_correction": round(float(corr), 6),
            "batch_lanes": int(lanes),
            "batch_frac": round(lanes / max(self.b_loc, 1), 6),
            "overlap_depth": int(depth),
            "lr": round(self._eta(t), 8),
            "signals": {
                "kappa_ema": None if self.kappa is None
                else round(self.kappa, 8),
                "kappa_ref": None if self.kappa_ref is None
                else round(self.kappa_ref, 8),
                "imp_ema": None if self.imp is None else round(self.imp, 8),
            },
            "reasons": reasons,
        }
        return h

    # -- feedback ---------------------------------------------------------

    def end_round(self, t: int, h: int, metrics: dict[str, Any]) -> None:
        """Feed back the executed round's telemetry (the engine's metrics
        dict — device scalars or floats for "loss", "grad_norm",
        "divergence")."""
        if self._open is None or self._open["t"] != int(t):
            raise RuntimeError(
                f"end_round({t}) without a matching begin_round "
                f"(open: {None if self._open is None else self._open['t']})")
        loss = float(metrics["loss"])
        div = float(metrics["divergence"])
        gn = float(metrics.get("grad_norm", 0.0))
        eta = self._eta(t)
        # drift intensity: div ~ kappa * eta * sqrt(h)  (the SDE scaling)
        kap = div / max(eta * math.sqrt(max(h, 1)), 1e-12)
        a = self.cfg.kappa_ema
        self.kappa = kap if self.kappa is None else a * kap + (1 - a) * self.kappa
        if self.kappa_ref is not None:
            s = self.cfg.kappa_ema_slow
            self.kappa_ref = s * kap + (1 - s) * self.kappa_ref
        elif t + h > self.run_cfg.warmup_steps:
            self.kappa_ref = self.kappa        # seed the trend post-warmup
        if self.last_loss is not None:
            imp = (self.last_loss - loss) / max(h, 1)
            b = self.cfg.imp_ema
            self.imp = imp if self.imp is None else b * imp + (1 - b) * self.imp
            if t >= self.run_cfg.warmup_steps and self.imp > self.best_imp:
                self.best_imp = self.imp
        self.last_loss = loss
        row = self._open
        self._open = None
        row["measured"] = {"loss": loss, "grad_norm": gn, "divergence": div,
                           "kappa": round(kap, 8)}
        self.trace.append(row)

    # -- trace ------------------------------------------------------------

    def trace_record(self) -> dict:
        """The serializable run record (schema controller_trace/v1)."""
        hs = [r["h"] for r in self.trace]
        return {
            "schema": TRACE_SCHEMA,
            "schedule": self.run_cfg.schedule,
            "config": dataclasses.asdict(self.cfg),
            "b_loc": self.b_loc,
            "adaptive_batch": self._adaptive_batch,
            "adaptive_depth": self._adaptive_depth,
            "frontier": self.frontier,
            "rounds": self.trace,
            "summary": {
                "n_rounds": len(self.trace),
                "steps": int(sum(hs)),
                "h_min": int(min(hs)) if hs else None,
                "h_max": int(max(hs)) if hs else None,
                "final_batch_lanes": int(self.batch_lanes),
                "final_overlap_depth": int(self.overlap_depth),
                "comm_fraction": (len(self.trace) / sum(hs)) if hs else None,
            },
        }

    def write_trace(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.trace_record(), f, indent=1)


def load_frontier(path_or_recs) -> dict[int, float] | None:
    """Parse a {depth: s_per_round} frontier from a table4_walltime JSON
    artifact (its `overlap` section tags rows `blocking_d0`, `overlap_d1`,
    ...) or pass through an already-shaped {depth: s} mapping."""
    recs = path_or_recs
    if isinstance(path_or_recs, str):
        try:
            with open(path_or_recs) as f:
                recs = json.load(f)
        except (OSError, ValueError):
            return None
    if not isinstance(recs, dict):
        return None
    if "overlap" in recs and isinstance(recs["overlap"], dict):
        out = {}
        for tag, row in recs["overlap"].items():
            if tag.endswith("_ring") or "_d" not in tag:
                continue
            try:
                out[int(tag.rsplit("_d", 1)[1])] = float(row["s_per_round"])
            except (KeyError, TypeError, ValueError):
                continue
        return out or None
    try:
        return {int(k): float(v) for k, v in recs.items()} or None
    except (TypeError, ValueError):
        return None
