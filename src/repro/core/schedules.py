"""Synchronization-period schedules — the paper's core contribution.

GetH(s) for every strategy studied in the paper:

  qsr       H = max(H_base, floor((alpha/eta_t)^2))        (eq. 2 — ours)
  constant  H = H_base                                     (baseline ①)
  parallel  H = 1                                          (baseline ②)
  postlocal H = 1 until t0, then H_base                    (Lin et al. 2020, ③)
  inverse   H = max(H_base, floor(beta/eta_t))             (Gu et al. 2023, ④)
  cubic     H = max(H_base, floor((rho/eta_t)^3))          (App. G ablation)
  swap      H = H_base until t0, then local-until-end      (SWAP, App. H)

Related-work baselines (paper §A — optimization-perspective schedules):
  linear_inc  H grows linearly with the round index          (Haddadpour+ 19)
  dec_sqrt    H ~ H0/sqrt(1 + t/T)  (start infrequent, sync more as loss
              curvature grows)                               (Wang&Joshi 19)

Beyond the paper:
  adaptive  open-loop it is the QSR prior exactly; at run time
            core/controller.py AdaptiveController multiplies the prior by a
            divergence correction and co-schedules the effective batch and
            overlap depth from the engine's in-graph telemetry.  get_h here
            returns only the prior so the schedule stays a pure function of
            (run_cfg, t, lr) — every boundary rule below applies unchanged.

All schedules implement the paper's two boundary rules:
  * warmup: H is pinned to the value of the first post-warmup round (§2),
  * truncation: the last round is forced to end at T (H = T - t).
"""
from __future__ import annotations

import math
from typing import Callable, Iterator

LrFn = Callable[[int], float]

# The single source of truth for every H-schedule this repo implements.
# CLI `--schedule` choices, RunConfig docs, and tests all derive from this
# list so a new schedule can't be added in one place and forgotten elsewhere.
SCHEDULE_KINDS: tuple[str, ...] = (
    "qsr", "constant", "parallel", "postlocal", "inverse", "cubic", "swap",
    "linear_inc", "dec_sqrt", "adaptive",
)


def _eta_for_round(run_cfg, t: int, lr_fn: LrFn) -> float:
    # During warmup, use the lr right after warmup (paper §2, "Dealing with
    # Learning Rate Warmup").
    return lr_fn(max(t, run_cfg.warmup_steps))


def get_h(run_cfg, t: int, lr_fn: LrFn) -> int:
    """Synchronization period for the round starting at global step t."""
    total = run_cfg.total_steps
    kind = run_cfg.schedule
    eta = _eta_for_round(run_cfg, t, lr_fn)
    # The warmup pin (§2) applies to the *round*, not just eta: t-dependent
    # schedules (postlocal/swap/linear_inc/dec_sqrt) also see the first
    # post-warmup step while t < warmup_steps.  Truncation below still uses
    # the real t.
    tp = max(t, run_cfg.warmup_steps)
    if kind == "parallel":
        h = 1
    elif kind == "constant":
        h = run_cfg.h_base
    elif kind in ("qsr", "adaptive"):
        # "adaptive" shares the QSR prior; the closed-loop correction lives
        # in core/controller.py and never reaches this pure function
        h = max(run_cfg.h_base, int((run_cfg.alpha / eta) ** 2))
    elif kind == "inverse":
        h = max(run_cfg.h_base, int(run_cfg.beta / eta))
    elif kind == "cubic":
        h = max(run_cfg.h_base, int((run_cfg.rho / eta) ** 3))
    elif kind == "postlocal":
        h = 1 if tp < run_cfg.switch_frac * total else run_cfg.h_base
    elif kind == "swap":
        t0 = int(run_cfg.switch_frac * total)
        h = run_cfg.h_base if tp < t0 else (total - tp)
    elif kind == "linear_inc":
        # Haddadpour et al. 2019: H grows linearly as training proceeds
        h = run_cfg.h_base * (1 + int(4 * tp / max(total, 1)))
    elif kind == "dec_sqrt":
        # Wang & Joshi 2019: start with infrequent sync, decrease H
        h0 = 8 * run_cfg.h_base
        h = max(1, int(h0 / math.sqrt(1.0 + 8.0 * tp / max(total, 1))))
    else:
        raise ValueError(f"unknown schedule {kind!r}; known: {SCHEDULE_KINDS}")
    return max(1, min(h, total - t))  # truncate the final round (§2)


def rounds(run_cfg, lr_fn: LrFn) -> Iterator[tuple[int, int]]:
    """Yield (t_start, H) for every communication round of a run."""
    t = 0
    while t < run_cfg.total_steps:
        h = get_h(run_cfg, t, lr_fn)
        yield t, h
        t += h


def n_rounds(run_cfg, lr_fn: LrFn) -> int:
    return sum(1 for _ in rounds(run_cfg, lr_fn))


def comm_fraction(run_cfg, lr_fn: LrFn) -> float:
    """Communication volume relative to data-parallel (one all-reduce per
    step).  Matches the paper's "Comm." columns (Tables 1-3): each round costs
    one parameter all-reduce; parallel costs one gradient all-reduce per step."""
    return n_rounds(run_cfg, lr_fn) / run_cfg.total_steps


def h_trace(run_cfg, lr_fn: LrFn) -> list[tuple[int, int]]:
    return list(rounds(run_cfg, lr_fn))
