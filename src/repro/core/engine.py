"""RoundEngine: one runtime that owns compilation, state, and data for a
training run — shared by the local-gradient path (paper Alg. 2, any
H-schedule) and the data-parallel baseline (Alg. 1 == the same engine with
H=1 every round).

Why it exists: QSR grows H as (alpha/eta)^2 while the lr decays (PAPER.md
eq. 2), so a real run visits many distinct H values.  Jitting a fresh
`train_round` per raw H makes compile time scale with the *schedule*; this
engine makes it scale with the *hardware* (log of the largest round).

## Bucketing / mask contract

* Every requested H is bucketed up to the next power of two
  `Hp = bucket_pow2(H)`; one round program is compiled per bucket, so a full
  QSR schedule compiles at most `ceil(log2(H_max)) + 1` programs instead of
  one per distinct H.
* A bucketed program scans Hp steps with a per-step validity mask
  (`step i valid iff i < h`).  Each scan step is a `lax.cond` on the mask:
  a masked step skips the local step entirely (state — including the
  optimizer step counter — passes through unchanged, no FLOPs spent),
  contributes 0 to the loss / grad-norm sums, and the round mean divides by
  h, not Hp.  Loss, lr, and sync semantics are therefore exact for any
  h <= Hp, and because the valid-step computation lives in its own cond
  branch it stays bitwise-identical to an unpadded scan over the same
  batches (verified by tests/test_engine.py).
* State buffers are donated to the round program (`donate_argnums=0`) when
  the backend supports it, so params/optimizer memory is reused across
  rounds instead of doubled.

## Data modes

* `data="device"`: batches are synthesized *inside* the jitted round from
  `jax.random.fold_in(seed, global_step)` (data/synthetic.py
  `device_batch_fn`) — no host-side `[H, W, B, S]` stack, no host->device
  transfer per round.
* `data="host"`: the legacy numpy TokenStream path, kept for
  reproducibility tests and real-data loaders.

## Telemetry

Each round returns in-graph metrics (computed in the same program, no extra
device round-trips): the loss and worker-mean global grad norm, each
averaged over the round's valid steps, and the pre-sync worker divergence
`mean_i ||x_i - x_bar||_2` — the quantity the paper's SDE analysis ties to
the generalization benefit of large H.

## Param layouts

`layout="flat"` carries the run state as FlatParamSpace dtype buckets
(core/flat.py) end-to-end: donation still applies (the state is just a
smaller pytree of bigger buffers), telemetry reads norms off the flat
buffers in one reduction per bucket, sync is one all-reduce per bucket, and
the optimizer is one fused kernel per bucket.  Valid-step params match the
tree layout bitwise (tests/test_flat.py); only the reduction *order* inside
scalar metrics differs (per-bucket instead of per-leaf partial sums).

`layout="flat_sharded"` pads each bucket so it splits into per-device
contiguous chunks (core/flat.py ShardedFlatSpace).  Under a sharded mesh
the sync decomposes into one reduce_scatter + one all_gather per bucket
(core/sync.py); in the host loop the same state layout runs the flat path
on the padded buffers, bitwise-equal to tree/flat (tests/test_sharded.py).

## Sync modes

`sync="blocking"` (default): every round ends with the full sync — reduce,
outer update, and broadcast in the round program, exactly Alg. 1/2.

`sync="partial"`: the boundary sync averages over the workers that
*arrived* — each round takes a membership mask `[W]` as a traced argument
(no recompile when participation changes) and the mean divides by |P|, the
participant count, instead of W (core/sync.py make_sync_partial).  A
masked lane still runs the boundary collective (it is alive, just late or
untrusted), so it re-anchors to the participants' consensus at the same
boundary — its round's local progress is excluded from the mean and
discarded, which IS the rejoin rule: the next round it participates it
starts from consensus.  A lane that is *gone* (dead process) instead
leaves through a resize — `membership_epoch(keep_lanes=...)`, or for mesh
worlds the checkpoint + respawn path (launch/multihost.py run_elastic).
Membership may only change at a round boundary, through
`membership_epoch()` — the MembershipEpoch record is the audit trail.  The same call resizes the worker axis itself (lanes leave or
join between rounds): the state is re-padded through the tree layout, the
`ShardedFlatSpace` rebuilt for the new W, and the compile cache — keyed by
(Hp, W) — keeps the old-W programs parked so a reverted membership change
recompiles nothing.

`sync="overlap"`: the round program ends with only the *reduce* half
(core/sync.py make_sync_begin) and hands the engine a pending mean; the
*gather/apply* half runs inside the NEXT round's program, after its first
`overlap_depth` local steps — so the gather leg rides the wire while the
next round's compute is already running.  Depth 0 applies the pending sync
before the next round's first step: every local step then sees bitwise the
params it would under blocking sync (the exactness mode; `flush()` aligns
the final state).  Depth d > 0 lets workers run d steps on their own stale
params and applies the consensus as a correction
`x_i <- x_i + (consensus - x_i_at_boundary)` — local progress is kept, a
beyond-paper staleness/overlap tradeoff recorded in
benchmarks/table4_walltime.py rather than asserted.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core import flat
from repro.core import local_update as LU
from repro.core import schedules
from repro.core.sync import (make_sync, make_sync_apply, make_sync_begin,
                             make_sync_partial)
from repro.data.synthetic import (TokenStream, device_batch_fn,
                                  effective_batch_view, make_train_batch)
from repro.errors import ConfigError
from repro.models import api, common as cm, param as pm

Pytree = Any


class PendingSyncError(RuntimeError):
    """An overlap-mode sync is still in flight where a synced state is
    required.  A real exception, not a bare `assert`: checkpoint/readout
    paths run under `python -O`, which strips asserts — a stripped guard
    would silently hand out (or persist) pre-consensus params."""


class MembershipError(RuntimeError):
    """An illegal worker-set change: membership may only move at a round
    boundary (never with a sync in flight), masks must keep at least one
    participant, and mesh-backed engines resize their worker axis through
    checkpoint + respawn (launch/multihost.py run_elastic), never in-place
    — `jax.distributed` cannot shrink a live process group.  Survives
    `python -O` for the same reason PendingSyncError does."""


@dataclasses.dataclass(frozen=True)
class MembershipEpoch:
    """One round-boundary change of the worker set — the audit record
    `membership_epoch()` appends to `engine.epochs`.

    index:      epoch ordinal (0 = the run's initial membership)
    workers:    worker-axis size W after the change
    membership: the participation mask in force, one float per lane
    resized:    True when the W axis itself changed (lanes joined/left),
                False for a pure participation-mask change
    parked:     compile-cache keys left unreachable by a resize — still
                cached, so reverting to that W recompiles nothing
    """
    index: int
    workers: int
    membership: tuple[float, ...]
    resized: bool
    parked: tuple = ()


@dataclasses.dataclass(frozen=True)
class BatchEpoch:
    """One round-boundary change of the effective per-worker batch — the
    audit record `batch_epoch()` appends to `engine.batch_epochs` (the
    MembershipEpoch of the batch knob).  The effective batch is a *traced*
    lane count over the allocated [W, b_loc, ...] batch buffers
    (data/synthetic.py effective_batch_view), so a BatchEpoch never
    recompiles anything.

    index:       epoch ordinal
    lanes:       effective per-worker batch after the change (divides b_loc)
    b_loc:       the allocated per-worker batch (compiled shape, unchanged)
    round_index: rounds executed when the change landed (the boundary)
    """
    index: int
    lanes: int
    b_loc: int
    round_index: int


# --------------------------------------------------------------------------
# Bucketing
# --------------------------------------------------------------------------

def bucket_pow2(h: int) -> int:
    """Smallest power of two >= h (the compile-cache key)."""
    return 1 if h <= 1 else 1 << (h - 1).bit_length()


def schedule_buckets(run_cfg, lr_fn) -> list[int]:
    """Distinct power-of-two buckets a full schedule visits, ascending."""
    return sorted({bucket_pow2(h) for _, h in schedules.rounds(run_cfg, lr_fn)})


def program_bound(h_max: int) -> int:
    """Compile-cache bound for a run whose largest round is h_max:
    ceil(log2 Hmax)+1 possible power-of-two buckets."""
    return int(math.ceil(math.log2(h_max))) + 1 if h_max > 1 else 1


def max_programs(run_cfg, lr_fn) -> int:
    """Upper bound on compiled round programs for a full schedule."""
    return program_bound(max(h for _, h in schedules.rounds(run_cfg, lr_fn)))


def enumerate_program_keys(run_cfg, lr_fn, *, sync: str = "blocking",
                           mode: str = "bucketed", overlap_depth: int = 0,
                           workers: int = 1) -> list[tuple]:
    """Statically enumerate the compile-cache keys a full schedule visits,
    in first-visit order — the lowering hook behind the static audit's
    compile-cache-bound rule (repro.analysis.rules), with zero compiles.

    Mirrors `RoundEngine._program`'s key derivation exactly: overlap keys
    on (hp, apply_pending, depth, W) — the first round of a run has no
    pending sync, every later round does — everything else on (hp, W).
    For a bucketed run the count must stay within `program_bound(Hmax)`
    (+1 under overlap for the pending-free first-round program)."""
    keys: list[tuple] = []
    pending = False
    for _, h in schedules.rounds(run_cfg, lr_fn):
        hp = bucket_pow2(h) if mode == "bucketed" else h
        key = ((hp, pending, overlap_depth, workers) if sync == "overlap"
               else (hp, workers))
        if key not in keys:
            keys.append(key)
        if sync == "overlap":
            pending = True
    return keys


# --------------------------------------------------------------------------
# In-graph telemetry
# --------------------------------------------------------------------------

def worker_divergence(params: Pytree) -> jax.Array:
    """mean_i ||x_i - x_bar||_2 over the leading worker axis, all leaves."""
    sq = 0.0
    for x in jax.tree.leaves(params):
        xf = x.astype(jnp.float32)
        m = jnp.mean(xf, axis=0, keepdims=True)
        sq = sq + jnp.sum(jnp.square(xf - m), axis=tuple(range(1, xf.ndim)))
    return jnp.mean(jnp.sqrt(sq))


def _metrics(state, losses, gns, denom):
    div = worker_divergence(state["params"])
    return {"loss": jnp.sum(losses) / denom,
            "grad_norm": jnp.sum(gns) / denom,
            "divergence": div}


# --------------------------------------------------------------------------
# Round-program builders (module-level so launch/shapes.py can lower them
# without an engine instance)
# --------------------------------------------------------------------------

def _remap_worker_lanes(tree_state: Pytree, lanes: list[int]) -> Pytree:
    """Tree-layout state with its worker axis re-padded to `lanes` (source
    lane per new slot; repeating a lane clones it — params AND moments, so
    a joined lane starts as a consensus replica).  Anchors, outer momentum,
    and the shared step counter carry no worker axis and pass through."""
    take = lambda x: jnp.stack([x[i] for i in lanes])
    out = dict(tree_state)
    out["params"] = jax.tree.map(take, tree_state["params"])
    out["opt"] = {k: (jax.tree.map(take, v) if k in flat._STACKED else v)
                  for k, v in tree_state["opt"].items()}
    return out


def _masked_body(local_step):
    """Per-step masked executor shared by the bucketed/overlap rounds.

    lax.cond keeps the valid-step computation an isolated XLA
    subcomputation: valid steps stay bitwise-identical to the unpadded
    program (a jnp.where select would perturb fusion at ulp level) and
    masked steps skip their FLOPs instead of computing-and-discarding.
    get_batch is called *inside* the taken branch so device-mode synthesis
    is skipped on masked steps too (a closed-over batch value would be an
    unconditionally-computed cond operand)."""
    def body(st, get_batch, lr, valid):
        def do(st):
            st2, (loss, gn) = local_step(st, get_batch(), lr)
            return st2, loss, gn
        def skip(st):
            return st, jnp.float32(0.0), jnp.float32(0.0)
        st2, loss, gn = jax.lax.cond(valid, do, skip, st)
        return st2, (loss, gn)
    return body


def _lane_viewer(batch_arg: bool, lanes):
    """Per-step batch transform for the round builders: with `batch_arg`
    the effective batch is the traced `lanes` count (a pure gather view,
    applied inside the valid-step cond branch so masked steps skip it);
    without, the identity."""
    if not batch_arg:
        return lambda b: b
    return lambda b: effective_batch_view(b, lanes, axis=1)


def make_bucketed_round(cfg, run_cfg, synth: Callable | None = None,
                        spec=None, *, batch_arg: bool = False):
    """Padded, masked communication round.

    Host data:   fn(state, batches [Hp, W, B, ...], lrs [Hp], mask [Hp])
    Device data: fn(state, t0 scalar, lrs [Hp], mask [Hp])  (synth given)
    -> (state, {"loss", "grad_norm", "divergence"}).

    With `spec` (core.flat.FlatParamSpace) the state is flat dtype buckets
    end-to-end: params/opt {bucket: [W, N]}, the sync one collective per
    bucket, the telemetry one reduction per bucket.

    With `batch_arg` the signature gains a trailing traced int32 scalar
    `lanes` — the *effective* per-worker batch (adaptive-controller knob):
    each step trains on samples [0, lanes) tiled over the allocated b_loc
    slots (data/synthetic.py effective_batch_view — exact batch-`lanes`
    gradients when lanes divides b_loc, bitwise pass-through at
    lanes == b_loc), so the effective batch changes between rounds without
    recompiling.
    """
    local_step = LU.make_local_step(cfg, run_cfg, with_metrics=True,
                                    spec=spec)
    sync = make_sync(run_cfg, spec=spec)
    body = _masked_body(local_step)

    def finish(state, losses, gns, mask):
        denom = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
        m = _metrics(state, losses, gns, denom)
        return sync(state), m

    if synth is None:
        def round_fn(state, batches, lrs, mask, *lanes):
            view = _lane_viewer(batch_arg, lanes[0] if batch_arg else None)
            def step(st, xs):
                batch, lr, valid = xs
                return body(st, lambda: view(batch), lr, valid)
            state, (losses, gns) = jax.lax.scan(
                step, state, (batches, lrs, mask), unroll=cm.scan_unroll())
            return finish(state, losses, gns, mask)
    else:
        def round_fn(state, t0, lrs, mask, *lanes):
            view = _lane_viewer(batch_arg, lanes[0] if batch_arg else None)
            hp = lrs.shape[0]
            def step(st, xs):
                i, lr, valid = xs
                return body(st, lambda: view(synth(t0 + i)), lr, valid)
            state, (losses, gns) = jax.lax.scan(
                step, state, (jnp.arange(hp), lrs, mask),
                unroll=cm.scan_unroll())
            return finish(state, losses, gns, mask)

    return round_fn


def make_partial_round(cfg, run_cfg, synth: Callable | None = None,
                       spec=None, *, batch_arg: bool = False):
    """Bucketed round whose boundary sync averages over ARRIVED workers.

    Host data:   fn(state, membership [W], batches [Hp,...], lrs, mask)
    Device data: fn(state, membership [W], t0 scalar, lrs, mask)
    -> (state, metrics).

    `membership` is a float mask over the worker axis, a *traced* argument:
    the participant set changes round to round without recompiling.  All W
    lanes still run their local steps (a straggler's compute is its own
    loss); only the boundary mean is restricted — Σ masked deltas / |P|,
    exact in the integer-code domain under quantized sync (core/sync.py
    §Partial participation).  The apply then broadcasts the participants'
    consensus to every lane, masked ones included: an excluded round's
    local progress is discarded and the lane re-anchors, so it rejoins
    from consensus.  Lanes whose PROCESS is gone leave through
    membership_epoch resize / run_elastic instead — they cannot run a
    collective at all.
    """
    local_step = LU.make_local_step(cfg, run_cfg, with_metrics=True,
                                    spec=spec)
    sync = make_sync_partial(run_cfg, spec=spec)
    body = _masked_body(local_step)

    def finish(state, membership, losses, gns, mask):
        denom = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
        m = _metrics(state, losses, gns, denom)
        return sync(state, membership), m

    if synth is None:
        def round_fn(state, membership, batches, lrs, mask, *lanes):
            view = _lane_viewer(batch_arg, lanes[0] if batch_arg else None)
            def step(st, xs):
                batch, lr, valid = xs
                return body(st, lambda: view(batch), lr, valid)
            state, (losses, gns) = jax.lax.scan(
                step, state, (batches, lrs, mask), unroll=cm.scan_unroll())
            return finish(state, membership, losses, gns, mask)
    else:
        def round_fn(state, membership, t0, lrs, mask, *lanes):
            view = _lane_viewer(batch_arg, lanes[0] if batch_arg else None)
            hp = lrs.shape[0]
            def step(st, xs):
                i, lr, valid = xs
                return body(st, lambda: view(synth(t0 + i)), lr, valid)
            state, (losses, gns) = jax.lax.scan(
                step, state, (jnp.arange(hp), lrs, mask),
                unroll=cm.scan_unroll())
            return finish(state, membership, losses, gns, mask)

    return round_fn


def make_exact_round(cfg, run_cfg, synth: Callable | None = None, spec=None):
    """Legacy exact-H round (one compile per distinct H) + engine telemetry.

    Same state arithmetic as `local_update.make_train_round`; kept as the
    escape hatch (`--engine legacy`) and the reference the bucketed path is
    tested bitwise against.
    """
    local_step = LU.make_local_step(cfg, run_cfg, with_metrics=True,
                                    spec=spec)
    sync = make_sync(run_cfg, spec=spec)

    def finish_exact(state, losses, gns):
        m = _metrics(state, losses, gns, jnp.float32(losses.shape[0]))
        return sync(state), m

    if synth is None:
        def round_fn(state, batches, lrs):
            def step(st, xs):
                batch, lr = xs
                st, (loss, gn) = local_step(st, batch, lr)
                return st, (loss, gn)
            state, (losses, gns) = jax.lax.scan(step, state, (batches, lrs),
                                                unroll=cm.scan_unroll())
            return finish_exact(state, losses, gns)
    else:
        def round_fn(state, t0, lrs):
            h = lrs.shape[0]
            def step(st, xs):
                i, lr = xs
                st, (loss, gn) = local_step(st, synth(t0 + i), lr)
                return st, (loss, gn)
            state, (losses, gns) = jax.lax.scan(
                step, state, (jnp.arange(h), lrs), unroll=cm.scan_unroll())
            return finish_exact(state, losses, gns)

    return round_fn


def make_overlap_round(cfg, run_cfg, synth: Callable | None = None,
                       spec=None, *, depth: int = 0,
                       apply_pending: bool = True, batch_arg: bool = False):
    """Bucketed round with the sync split across the round boundary.

    Host data:   fn(state, pending?, batches [Hp, ...], lrs [Hp], mask [Hp])
    Device data: fn(state, pending?, t0 scalar, lrs [Hp], mask [Hp])
    -> (state, new_pending, metrics).  `pending?` is present iff
    `apply_pending` (every round but the first).

    The program: run the first min(depth, Hp) local steps on the stale
    (pre-consensus) params, gather+apply the previous round's pending
    reduce (exact assignment at depth 0; correction form otherwise), run
    the remaining steps, and end with only the *reduce* half of this
    round's sync — new_pending, handed to the next program.
    """
    local_step = LU.make_local_step(cfg, run_cfg, with_metrics=True,
                                    spec=spec)
    begin = make_sync_begin(run_cfg, spec=spec)
    apply_ = make_sync_apply(run_cfg, spec=spec)
    body = _masked_body(local_step)

    def round_fn(state, *args):
        if batch_arg:
            *args, lanes = args
        view = _lane_viewer(batch_arg, lanes if batch_arg else None)

        if synth is None:
            def step(st, xs):
                batch, lr, valid = xs
                return body(st, lambda: view(batch), lr, valid)
        else:
            def step(st, xs):
                i, lr, valid = xs
                return body(st, lambda: view(synth(i)), lr, valid)

        def segment(state, xs):
            return jax.lax.scan(step, state, xs, unroll=cm.scan_unroll())

        if apply_pending:
            pending, *rest = args
        else:
            rest = args
        data, lrs, mask = rest
        hp = lrs.shape[0]
        xs = ((data, lrs, mask) if synth is None
              else (data + jnp.arange(hp), lrs, mask))
        d = min(depth, hp) if apply_pending else 0
        take = lambda a, b: jax.tree.map(lambda x: x[a:b], xs)
        losses, gns = [], []
        if apply_pending:
            if d > 0:
                entry = state["params"]
                state, (l1, g1) = segment(state, take(0, d))
                losses.append(l1)
                gns.append(g1)
                state = apply_(state, pending, entry)
            else:
                state = apply_(state, pending)
        state, (l2, g2) = segment(state, take(d, hp))
        losses.append(l2)
        gns.append(g2)
        cat = lambda ps: ps[0] if len(ps) == 1 else jnp.concatenate(ps)
        denom = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
        m = _metrics(state, cat(losses), cat(gns), denom)
        return state, begin(state), m

    return round_fn


# --------------------------------------------------------------------------
# The engine
# --------------------------------------------------------------------------

class RoundEngine:
    """Owns the compile cache, run state, data source, and H-trace of a run.

    mode:   "bucketed" (power-of-two compile cache, masked scan — default) |
            "legacy"   (one program per distinct H — the seed behavior)
    data:   "device" (in-graph fold_in batch synthesis — default) |
            "host"   (numpy TokenStream, batches staged per round)
    layout: "tree" (state mirrors the model pytree — default) |
            "flat" (state is a few dtype-bucketed [W, N] buffers, see
            core/flat.py: one sync all-reduce and one optimizer kernel per
            bucket instead of per leaf; bitwise-equal trajectories) |
            "flat_sharded" (flat buckets padded into `shards` contiguous
            per-device chunks — the FSDP-style layout whose sync lowers to
            reduce_scatter + all_gather under a mesh; bitwise-equal too)
    sync:   "blocking" (round ends fully synced — default) |
            "overlap" (reduce at the boundary, gather/apply deferred past
            the next round's first `overlap_depth` steps; bucketed mode
            only; depth 0 is bitwise the blocking trajectory — see the
            module docstring.  `flush()` applies the last in-flight sync.
            Composes with `mesh=`: the pending reduce is threaded through
            the jitted round programs, its worker-sharded payload living
            on the mesh's devices — across real `jax.distributed`
            processes — between rounds (launch/multihost.py --mode engine
            --sync overlap).  Observers read `synced_view()`; checkpoints
            use `save(flush_pending=True)` or `flush()` — `save` raises
            PendingSyncError rather than persist pre-consensus params.)
    shards: chunk count for layout="flat_sharded" (0 -> workers, or the
            full device count when a mesh is given).
    mesh:   optional jax Mesh (layout="flat_sharded" only): the spec then
            carries the mesh + worker/shard axes (from `policy`), the state
            is laid out onto it at init (global arrays — works across real
            processes, launch/multihost.py), and the sync executes its
            explicit reduce_scatter / all_gather collectives instead of the
            host flat path.  Bitwise-equal to the mesh-less engine for
            quantized sync (integer-code reduction, core/sync.py) and for
            any sync when the worker-axis product is 2.
    policy: sharding policy naming the mesh's worker axes ("dp" | "fsdp");
            only read when a mesh is given.
    batch_fn: host-data override — `fn(step) -> batch [W, B_loc, ...]`
            replacing the built-in TokenStream (e.g. a VisionStream source
            for the paper's ViT runs).  Implies data="host".

    The data-parallel baseline (Alg. 1) is this same engine driven with the
    "parallel" schedule: every round has H=1, so workers sync (average) after
    each step — for SGD this is step-for-step the global-batch baseline.
    """

    def __init__(self, cfg, run_cfg, *, workers: int, b_loc: int, seq: int,
                 seed: int = 0, mode: str = "bucketed", data: str = "device",
                 layout: str = "tree", sync: str = "blocking",
                 overlap_depth: int = 0, shards: int = 0,
                 mesh=None, policy: str = "dp",
                 donate: bool | None = None,
                 batch_fn: Callable | None = None,
                 adaptive_batch: bool = False):
        if mode not in ("bucketed", "legacy"):
            raise ConfigError(f"unknown engine mode {mode!r}")
        if data not in ("device", "host"):
            raise ConfigError(f"unknown data source {data!r}")
        if layout not in ("tree", "flat", "flat_sharded"):
            raise ConfigError(f"unknown param layout {layout!r}")
        if sync not in ("blocking", "overlap", "partial"):
            raise ConfigError(f"unknown sync mode {sync!r}")
        if overlap_depth < 0:
            raise ConfigError(f"overlap_depth must be >= 0, got {overlap_depth}")
        if mesh is not None and layout != "flat_sharded":
            raise ConfigError(
                "a mesh drives the explicit-collective sync: layout=flat_sharded")
        if mesh is not None:
            got = pm.worker_count(policy, mesh)
            if got != workers:
                raise ConfigError(
                    f"policy {policy!r} on this mesh has {got} workers, "
                    f"engine built with {workers}")
        self.mesh, self.policy = mesh, policy
        if sync != "blocking" and mode != "bucketed":
            raise ConfigError(
                "overlap/partial sync runs through the bucketed program")
        if batch_fn is not None and data != "host":
            raise ConfigError("batch_fn is a host-data source; pass data='host'")
        if cfg.family == "vision" and not (data == "host" and batch_fn):
            raise ConfigError(
                "vision configs need data='host' and an image batch_fn")
        if adaptive_batch and mode != "bucketed":
            raise ConfigError(
                "the traced effective-batch lane rides the bucketed programs")
        self.cfg, self.run_cfg = cfg, run_cfg
        self.workers, self.b_loc, self.seq, self.seed = workers, b_loc, seq, seed
        self.mode, self.data, self.layout = mode, data, layout
        self.sync_mode, self.overlap_depth = sync, overlap_depth
        self.shards = shards
        self._pending = None          # overlap mode: in-flight reduce
        self._flush_fn = None
        # elastic membership: participation mask over the worker axis (all
        # lanes arrive by default) + the epoch audit trail.  Only
        # membership_epoch() may change either — and only between rounds.
        self.membership = np.ones(workers, np.float32)
        self.epochs: list[MembershipEpoch] = []
        # adaptive effective batch: the compiled shape is always b_loc; the
        # traced lane count below selects the effective batch per round
        # (batch_epoch() is the only legal change point — a round boundary)
        self.adaptive_batch = adaptive_batch
        self.batch_lanes = b_loc
        self.batch_epochs: list[BatchEpoch] = []
        # donation is a no-op warning on CPU; auto-enable elsewhere
        self.donate = (jax.default_backend() != "cpu") if donate is None else donate
        self.stream = TokenStream(vocab=max(cfg.vocab, 2), seed=seed)
        self._synth = (device_batch_fn(cfg, self.stream, workers, b_loc, seq)
                       if data == "device" else None)
        self._host_batch = batch_fn or (
            lambda step: make_train_batch(self.cfg, self.stream, step,
                                          self.workers, self.b_loc, self.seq))
        self.spec = None                           # FlatParamSpace (layout="flat")
        self._programs: dict[int, Any] = {}
        self.compiles = 0
        self.cache_hits = 0
        self.h_trace: list[tuple[int, int]] = []   # (t_start, h) executed

    # -- state ------------------------------------------------------------

    def _ensure_spec(self, params_single: Pytree | None = None):
        """The FlatParamSpace is recorded once, from the first params seen
        (or the config's abstract params) — after that all flatten/unflatten
        layout ops reuse it."""
        if self.spec is None:
            if params_single is None:
                mod = api.get_module(self.cfg)
                params_single = pm.abstract_params(mod.param_defs(self.cfg),
                                                   jnp.float32)
            if self.layout == "flat_sharded" and self.mesh is not None:
                waxes = pm.worker_mesh_axes(self.policy, self.mesh)
                saxes = tuple(a for a in self.mesh.axis_names
                              if a not in waxes)
                sizes = pm.mesh_axis_sizes(self.mesh)
                shards = self.shards or math.prod(sizes.values())
                self.spec = flat.ShardedFlatSpace(
                    params_single, shards, mesh=self.mesh,
                    worker_axes=waxes, shard_axes=saxes)
            elif self.layout == "flat_sharded":
                self.spec = flat.ShardedFlatSpace(params_single,
                                                  self.shards or self.workers)
            else:
                self.spec = flat.FlatParamSpace(params_single)
        return self.spec

    def init_state(self, params_single: Pytree | None = None) -> Pytree:
        if params_single is None:
            mod = api.get_module(self.cfg)
            params_single = pm.init_params(mod.param_defs(self.cfg),
                                           jax.random.PRNGKey(self.seed),
                                           jnp.float32)
        state = LU.init_state(self.cfg, self.run_cfg, params_single,
                              self.workers)
        if self.layout != "tree":
            state = flat.to_flat_state(self._ensure_spec(params_single), state)
        if self.mesh is not None:
            state = self._to_global(state)
        return state

    def _to_global(self, state: Pytree) -> Pytree:
        """Lay the flat state out onto the engine's mesh as global arrays
        (flat.make_global: works single-process and across real
        `jax.distributed` processes alike)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        sspec = flat.flat_state_specs(self.run_cfg, self.spec.worker_axes,
                                      self.spec)
        # PartitionSpec subclasses tuple (a pytree node): wrap in the opaque
        # NamedSharding so flatten_up_to treats each spec as one leaf
        ns = jax.tree.map(lambda s: NamedSharding(self.mesh, s), sspec,
                          is_leaf=lambda x: isinstance(x, P))
        leaves, td = jax.tree.flatten(state)
        shardings = td.flatten_up_to(ns)
        return jax.tree.unflatten(td, [flat.make_global(x, self.mesh, sh.spec)
                                       for x, sh in zip(leaves, shardings)])

    def params_single(self, state: Pytree) -> Pytree:
        """Worker-0 params as the model pytree, whatever the layout — the
        post-run handoff to eval/serving code."""
        if self._pending is not None:
            raise PendingSyncError(
                "in-flight sync: pass flush(state) or synced_view(state), "
                "not the raw run state")
        params = state["params"]
        if self.layout != "tree":
            params = self._ensure_spec().unflatten(params, lead=1)
        return jax.tree.map(lambda x: x[0], params)

    # -- compilation ------------------------------------------------------

    def _program(self, hp: int, apply_pending: bool = False):
        """Jitted round program for padded length hp.  Cache key: (hp, W) —
        a membership RESIZE moves W and so reaches fresh entries while the
        old-W programs stay parked for an instant revert; a pure mask
        change reuses the same program (membership is a traced argument).
        Overlap mode also keys on whether a pending sync is applied — the
        first round of a run has none — and on the overlap depth, so a
        controller retuning `set_overlap_depth` compiles at most one
        program per (bucket, depth) pair.  The adaptive batch lane count
        is a traced argument and never appears in the key."""
        key = ((hp, apply_pending, self.overlap_depth, self.workers)
               if self.sync_mode == "overlap" else (hp, self.workers))
        if key in self._programs:
            self.cache_hits += 1
            return self._programs[key]
        spec = self._ensure_spec() if self.layout != "tree" else None
        if self.sync_mode == "overlap":
            fn = make_overlap_round(self.cfg, self.run_cfg, self._synth,
                                    spec, depth=self.overlap_depth,
                                    apply_pending=apply_pending,
                                    batch_arg=self.adaptive_batch)
            donate = (0, 1) if apply_pending else (0,)
        elif self.sync_mode == "partial":
            fn = make_partial_round(self.cfg, self.run_cfg, self._synth,
                                    spec, batch_arg=self.adaptive_batch)
            donate = (0,)
        elif self.mode == "bucketed":
            fn = make_bucketed_round(self.cfg, self.run_cfg, self._synth,
                                     spec, batch_arg=self.adaptive_batch)
            donate = (0,)
        else:
            fn = make_exact_round(self.cfg, self.run_cfg, self._synth, spec)
            donate = (0,)
        jit_kw = {"donate_argnums": donate} if self.donate else {}
        self._programs[key] = jax.jit(fn, **jit_kw)
        self.compiles += 1
        return self._programs[key]

    def compile_stats(self) -> dict:
        return {"compiles": self.compiles, "cache_hits": self.cache_hits,
                "programs": sorted(self._programs)}

    # -- execution --------------------------------------------------------

    def run_round(self, state: Pytree, t: int, h: int, lr_fn):
        """Execute the communication round starting at step t with period h.

        Returns (state, metrics) where metrics holds device scalars
        {"loss", "grad_norm", "divergence"} computed in-graph.
        """
        hp = bucket_pow2(h) if self.mode == "bucketed" else h
        # the schedule is only defined on [0, total_steps): query it for the
        # h valid steps and fill the hp - h padded lanes with the last valid
        # value.  Masked steps never apply an lr, but a decay schedule
        # queried past its domain can return negative/NaN values (or raise)
        # — the truncated final round must not poison the padded lanes
        lr_valid = [lr_fn(t + i) for i in range(h)]
        lrs = jnp.asarray(lr_valid + [lr_valid[-1]] * (hp - h), jnp.float32)
        fn = self._program(hp, self._pending is not None)
        args = []
        if self._synth is None:
            # only the h valid steps' batches are real; masked steps never
            # read theirs (lax.cond), so pad by repeating the last batch —
            # this skips the numpy synthesis of the hp - h pad batches (the
            # [Hp, ...] transfer itself is inherent to the fixed-shape
            # program)
            per_step = [self._host_batch(t + i) for i in range(h)]
            per_step += [per_step[-1]] * (hp - h)
            args.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_step))
        else:
            args.append(jnp.int32(t))
        args.append(lrs)
        if self.mode == "bucketed":
            args.append(jnp.arange(hp) < h)
        if self.adaptive_batch:
            args.append(jnp.int32(self.batch_lanes))
        if self.sync_mode == "partial":
            args.insert(0, jnp.asarray(self.membership, jnp.float32))
        if self.sync_mode == "overlap":
            if self._pending is not None:
                args.insert(0, self._pending)
            state, self._pending, metrics = fn(state, *args)
        else:
            state, metrics = fn(state, *args)
        self.h_trace.append((t, h))
        return state, metrics

    def synced_view(self, state: Pytree) -> Pytree:
        """State with the in-flight sync applied, WITHOUT consuming it —
        the consensus an observer (eval, logging) should see mid-run under
        overlap mode.  Pure: the training trajectory is untouched."""
        if self._pending is None:
            return state
        if self._flush_fn is None:
            spec = self._ensure_spec() if self.layout != "tree" else None
            self._flush_fn = jax.jit(make_sync_apply(self.run_cfg, spec))
        return self._flush_fn(state, self._pending)

    def flush(self, state: Pytree) -> Pytree:
        """Apply the in-flight sync, if any (overlap mode): the pending
        reduce from the last round is gathered and applied exactly, leaving
        the state at the synced consensus a blocking round would have.  Call
        before checkpointing or reading out final params."""
        state = self.synced_view(state)
        self._pending = None
        return state

    # -- elastic membership -----------------------------------------------

    def membership_epoch(self, membership: Sequence[float] | None = None, *,
                         state: Pytree | None = None,
                         keep_lanes: Sequence[int] | None = None,
                         grow_to: int | None = None) -> Pytree | None:
        """The ONLY legal place the worker set changes — a round boundary.

        Three shapes of change, each recorded as a MembershipEpoch:

        * `membership_epoch([1, 1, 0, 1])` — participation mask for the
          next rounds (sync="partial" engines): lane 2 keeps training but
          its delta is excluded from the boundary mean, which divides by
          |P|=3.  W unchanged, nothing recompiles (the mask is traced).
        * `membership_epoch(state=st, keep_lanes=(0, 1, 3))` — lanes LEAVE:
          the worker axis shrinks to the kept lanes.  Returns the resized
          state; the flat spec is rebuilt and the (hp, W) compile cache
          reaches fresh entries while the old-W programs stay parked.
        * `membership_epoch(state=st, grow_to=4)` — lanes JOIN: new lanes
          clone lane 0 — the post-sync consensus params (re-anchoring, the
          ISSUE's rejoin rule) AND its optimizer moments (zeros would
          de-bias Adam against the shared step counter).

        Raises MembershipError with a sync in flight (the pending reduce
        was taken over the OLD membership), on an empty mask, or on a
        resize under a live mesh — `jax.distributed` process groups cannot
        shrink in place, so mesh worlds resize through the manifest
        checkpoint + respawn path (launch/multihost.py run_elastic), each
        OS-process generation being one epoch.
        """
        if self._pending is not None:
            raise MembershipError(
                "membership may only change at a round boundary: a sync is "
                "in flight over the old worker set — flush() first")
        resize = keep_lanes is not None or grow_to is not None
        if resize:
            if self.mesh is not None:
                raise MembershipError(
                    "mesh-backed engines resize via checkpoint + respawn "
                    "(launch/multihost.py run_elastic), not in place")
            if state is None:
                raise MembershipError("a resize needs the run state")
            if keep_lanes is not None:
                lanes = [int(i) for i in keep_lanes]
                if not lanes or not all(0 <= i < self.workers
                                        for i in lanes):
                    raise MembershipError(
                        f"keep_lanes {lanes} out of range for "
                        f"W={self.workers}")
            else:
                if grow_to <= self.workers:
                    raise MembershipError(
                        f"grow_to={grow_to} does not grow W={self.workers}")
                lanes = list(range(self.workers)) + \
                    [0] * (grow_to - self.workers)
            state = self._resize_lanes(state, lanes)
            self.membership = np.ones(self.workers, np.float32)
        elif membership is not None:
            mask = np.asarray(membership, np.float32)
            if mask.shape != (self.workers,) or mask.sum() < 1:
                raise MembershipError(
                    f"membership mask must be [{self.workers}] with at "
                    f"least one participant, got {mask!r}")
            self.membership = mask
        parked = tuple(k for k in self._programs
                       if k[-1] != self.workers) if resize else ()
        self.epochs.append(MembershipEpoch(
            index=len(self.epochs), workers=self.workers,
            membership=tuple(float(x) for x in self.membership),
            resized=resize, parked=parked))
        return state

    # -- adaptive round-boundary knobs -------------------------------------

    def batch_epoch(self, lanes: int) -> None:
        """The ONLY legal place the effective per-worker batch changes — a
        round boundary, mirroring membership_epoch.  `lanes` samples are
        consumed per step per worker from the next round on; the compiled
        batch shape stays b_loc (the lane count is a traced argument of
        every program — see data.synthetic.effective_batch_view), so the
        change costs ZERO recompiles beyond the existing H-bucket set.
        `lanes` must divide b_loc for the tiled mean to be an exact
        batch-`lanes` gradient."""
        if not self.adaptive_batch:
            raise MembershipError(
                "batch_epoch needs an adaptive_batch=True engine — the lane "
                "count is only a traced argument of adaptive programs")
        lanes = int(lanes)
        if not 1 <= lanes <= self.b_loc or self.b_loc % lanes:
            raise MembershipError(
                f"batch lanes must divide b_loc={self.b_loc} "
                f"(got {lanes})")
        self.batch_lanes = lanes
        self.batch_epochs.append(BatchEpoch(
            index=len(self.batch_epochs), lanes=lanes, b_loc=self.b_loc,
            round_index=len(self.h_trace)))

    def set_overlap_depth(self, depth: int) -> None:
        """Retune --overlap-depth at a round boundary (overlap engines
        only).  Depth is a compile-cache key component, so each (bucket,
        depth) pair compiles at most once and revisited depths are cache
        hits."""
        if self.sync_mode != "overlap":
            raise MembershipError(
                "overlap depth is only a knob under --sync overlap")
        depth = int(depth)
        if depth < 0:
            raise MembershipError(f"overlap depth must be >= 0, got {depth}")
        self.overlap_depth = depth

    def _resize_lanes(self, state: Pytree, lanes: list[int]) -> Pytree:
        """Re-pad the worker axis to `lanes` (source lane per new slot),
        through the tree layout as the common currency — exactly the
        cross-layout restore route, so the kept lanes stay bitwise.  The
        flat spec, batch synthesizer, and flush program are all rebuilt
        for the new W."""
        spec = self._ensure_spec() if self.layout != "tree" else None
        tree_state = (state if spec is None
                      else flat.to_tree_state(spec, state))
        tree_state = _remap_worker_lanes(tree_state, lanes)
        self.workers = len(lanes)
        self.spec = None
        self._flush_fn = None
        if self.data == "device":
            self._synth = device_batch_fn(self.cfg, self.stream,
                                          self.workers, self.b_loc, self.seq)
        if self.layout == "tree":
            return tree_state
        params_single = jax.tree.map(lambda x: x[0], tree_state["params"])
        return flat.to_flat_state(self._ensure_spec(params_single),
                                  tree_state)

    # -- checkpointing ----------------------------------------------------

    def checkpoint_extra(self) -> dict:
        """The engine-side checkpoint metadata: the H-trace (resume lands on
        a round boundary) + the param-layout record for cross-layout
        restore.  Exposed so async observers (core/observer.py) can capture
        it on the round loop's thread at snapshot time — the trace keeps
        advancing while the background writer runs."""
        spec = self._ensure_spec() if self.layout != "tree" else None
        return {"h_trace": [[t, h] for t, h in self.h_trace],
                "workers": self.workers,
                **ckpt_io.layout_meta(self.layout, spec)}

    def save(self, path: str, state: Pytree, *, step: int,
             flush_pending: bool = False) -> None:
        """Checkpoint state + the engine's step / H-trace so a resumed run
        lands exactly on the next round boundary.  Flat layouts checkpoint
        the buffers directly — one entry per dtype bucket, not per tensor —
        with the layout recorded in the meta side file for cross-layout
        restore (checkpoint/io.py).

        Overlap mode: a checkpoint written mid-overlap must never hold
        pre-consensus params.  With a sync in flight this raises
        PendingSyncError (a real error, not a stripped-under-`python -O`
        assert) unless `flush_pending=True`, which writes the *synced view*
        of `state` — the consensus a blocking round would have produced —
        WITHOUT consuming the in-flight pipeline, so the training stream
        continues overlapped.  `flush()` + save remains the forced-sync
        alternative."""
        if self._pending is not None:
            if not flush_pending:
                raise PendingSyncError(
                    "overlap sync in flight: save(flush_pending=True) "
                    "writes the synced consensus without disturbing the "
                    "pipeline, or flush() first for a forced sync point")
            state = self.synced_view(state)
        ckpt_io.save(path, state, step=step, extra=self.checkpoint_extra())

    def restore(self, path: str, like_state: Pytree) -> tuple[Pytree, int]:
        """Restore into this engine's layout.  A checkpoint written under
        any other param layout (tree <-> flat <-> flat_sharded, or a
        different shard count) is converted on the way in through the tree
        layout as the common currency — flatten/unflatten are exact, so
        resuming across layouts stays bitwise-faithful.

        Refuses a live in-flight sync: restoring over it would silently
        orphan a round's reduce — flush() (or discard the run) first."""
        if self._pending is not None:
            raise PendingSyncError(
                "restore() with an overlap sync in flight would orphan the "
                "pending reduce: flush() the current state first")
        _, meta = ckpt_io.read_meta(path)
        ck_layout = meta.get("layout", "tree")
        ck_shards = meta.get("shards")
        my_shards = (self._ensure_spec().shards
                     if self.layout == "flat_sharded" else None)
        convert = ck_layout != self.layout or ck_shards != my_shards
        ck_spec = None
        if convert:
            # tree-layout engines derive the spec from the live state (its
            # dtypes are authoritative); flat engines already carry one
            tree_state = (like_state if self.layout == "tree"
                          else flat.to_tree_state(self._ensure_spec(),
                                                  like_state))
            if ck_layout == "tree":
                like = tree_state
            else:
                params_single = jax.tree.map(lambda x: x[0],
                                             tree_state["params"])
                ck_spec = (flat.ShardedFlatSpace(params_single,
                                                 ck_shards or 1)
                           if ck_layout == "flat_sharded"
                           else flat.FlatParamSpace(params_single))
                like = flat.to_flat_state(ck_spec, tree_state)
        else:
            like = like_state
        state, step, extra = ckpt_io.restore_with_meta(path, like)
        if convert:
            if ck_spec is not None:
                state = flat.to_tree_state(ck_spec, state)
            if self.layout != "tree":
                state = flat.to_flat_state(self._ensure_spec(), state)
        return state, self._adopt_trace(extra, step)

    def _adopt_trace(self, extra: dict, step) -> int:
        trace = [(int(t), int(h)) for t, h in extra.get("h_trace", [])]
        step = int(step or 0)
        if trace:
            done = trace[-1][0] + trace[-1][1]
            if done != step:     # real error: survives `python -O`
                raise ValueError(
                    f"checkpoint step {step} is not the round boundary "
                    f"implied by its H-trace (ends at {done})")
        self.h_trace = trace
        return step

    def save_sharded(self, path: str, state: Pytree, *, step: int,
                     flush_pending: bool = False, barrier=None) -> None:
        """Per-host shard-file checkpoint (checkpoint/io.py save_sharded):
        this process writes ONLY its addressable shards; process 0 adds the
        manifest naming every shard file.  `barrier` (a zero-arg callable,
        e.g. a cross-process sync) runs after the shard files are durable
        and before the manifest is written, so a manifest never names a
        file that doesn't exist yet.  Same PendingSyncError contract as
        `save`."""
        if self._pending is not None:
            if not flush_pending:
                raise PendingSyncError(
                    "overlap sync in flight: save_sharded(flush_pending="
                    "True) writes the synced consensus without disturbing "
                    "the pipeline, or flush() first")
            state = self.synced_view(state)
        ckpt_io.save_sharded(path, state, step=step,
                             extra=self.checkpoint_extra(), barrier=barrier)

    def restore_elastic(self, path: str, like_state: Pytree) -> tuple[Pytree, int]:
        """Restore a checkpoint written under ANY worker count — and any
        layout / shard count / process count, manifest or monolithic —
        into this engine.  Writer lanes beyond this engine's W are dropped
        (highest first); missing lanes clone the checkpoint's lane 0: at a
        round boundary every *participating* lane holds the post-sync
        consensus, so the clone IS the re-anchoring rule a rejoining
        worker needs (params and moments both — zero moments would
        de-bias Adam against the shared step counter).

        The lane remap runs through the tree layout exactly like the
        cross-layout `restore` route, so surviving lanes stay bitwise."""
        if self._pending is not None:
            raise PendingSyncError(
                "restore_elastic() with an overlap sync in flight would "
                "orphan the pending reduce: flush() first")
        # the writer-geometry `like` built below only needs SHAPES: rebuild
        # the template from host zeros so the lane remap never issues an
        # eager cross-device gather on mesh-global state — under gloo that
        # gather deadlocks whenever one process owns more than one device
        # (2 procs x 2 devices, say).  The restore itself is host-side
        # anyway, and _to_global lays the result back onto the mesh.
        like_state = jax.tree.map(
            lambda x: (np.zeros(x.shape, x.dtype)
                       if isinstance(x, (jax.Array, np.ndarray)) else x),
            like_state)
        manifest = ckpt_io.is_manifest(path)
        _, extra = (ckpt_io.read_manifest_meta(path) if manifest
                    else ckpt_io.read_meta(path))
        ck_layout = extra.get("layout", "tree")
        ck_shards = extra.get("shards")
        ck_w = int(extra.get("workers") or self.workers)
        # a like tree in the WRITER's geometry, built from this engine's
        # state: lanes remapped to ck_w (shapes are all that matter here),
        # then laid out as the writer's layout
        my_tree = (like_state if self.layout == "tree"
                   else flat.to_tree_state(self._ensure_spec(), like_state))
        to_ck = (list(range(ck_w)) if ck_w <= self.workers
                 else list(range(self.workers)) + [0] * (ck_w - self.workers))
        ck_tree = _remap_worker_lanes(my_tree, to_ck)
        ck_spec = None
        if ck_layout != "tree":
            params_single = jax.tree.map(lambda x: x[0], ck_tree["params"])
            ck_spec = (flat.ShardedFlatSpace(params_single, ck_shards or 1)
                       if ck_layout == "flat_sharded"
                       else flat.FlatParamSpace(params_single))
        like = (ck_tree if ck_spec is None
                else flat.to_flat_state(ck_spec, ck_tree))
        rest = (ckpt_io.restore_sharded if manifest
                else ckpt_io.restore_with_meta)
        state, step, extra = rest(path, like)
        if ck_spec is not None:
            state = flat.to_tree_state(ck_spec, state)
        back = (list(range(self.workers)) if ck_w >= self.workers
                else list(range(ck_w)) + [0] * (self.workers - ck_w))
        state = _remap_worker_lanes(state, back)
        if self.layout != "tree":
            state = flat.to_flat_state(self._ensure_spec(), state)
        if self.mesh is not None:
            state = self._to_global(state)
        self.membership = np.ones(self.workers, np.float32)
        return state, self._adopt_trace(extra, step)
