"""FlatParamSpace: the model pytree viewed as a few dtype-bucketed 1-D buffers.

Why: the two hot paths of the local-gradient runtime pay per-*tensor* costs
that a flat view eliminates.

  * Sync (every H steps) is a worker mean over the params pytree — under
    GSPMD that lowers to one all-reduce per leaf: hundreds of small,
    latency-bound collectives on transformer configs.  Over a flat buffer it
    is one all-reduce per dtype bucket (see launch/hlo_analysis
    `collective_counts`, which proves the drop).
  * The fused AdamW Pallas kernel launches once per leaf with per-leaf
    padding to its block size.  Over the flat fp32 bucket it launches once
    per local step, and pays at most one block of padding total.

The spec is recorded once at init: leaves are taken in pytree
(`jax.tree.flatten`) order and grouped into one contiguous 1-D buffer per
leaf dtype ("the dtype-bucket rule": elementwise math and collectives need a
homogeneous element type, and parameter dtypes are few — fp32 and/or bf16 —
so the collective count drops from O(#leaves) to O(#dtypes)).  Flatten and
unflatten are pure reshapes + concatenation/slices, so under XLA they fuse
into layout ops: gradients taken *with respect to the flat buffer* are
element-for-element identical to per-leaf gradients, which is what makes the
flat layout bitwise-equivalent to the tree layout (tests/test_flat.py).

Mirror trees (AdamW moments, SGD momentum, grads) share the params bucket
assignment — their leaves land at the same offsets, in their own dtype — so
`p[off:off+n]`, `m[off:off+n]`, `v[off:off+n]` always describe the same
tensor.

The tree layout remains available (`--param-layout tree`): it is the right
tool when you need per-tensor stats (debugging which layer diverges).

ShardedFlatSpace (`--param-layout flat_sharded`) extends the flat layout the
FSDP way: each dtype bucket is padded so it splits into per-device
*contiguous chunks* — the flat dim is sharded over the mesh axes that do NOT
carry the worker axis, so optimizer state and anchors are stored at 1/S per
device, and the every-H-steps worker mean decomposes into one
`reduce_scatter` (each worker reduces the 1/W chunk it owns) plus one
`all_gather` (rebuild the consensus) per bucket instead of a full
all-reduce.  The gather leg is what the RoundEngine's `--sync overlap` mode
defers into the next round (core/engine.py).  Because the chunk rule is
"pad, then split contiguously", the fsdp policy — whose per-leaf inner
shardings the plain flat layout cannot represent — gets a flat path too:
chunks replace per-tensor shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.errors import LayoutError

Pytree = Any


@dataclasses.dataclass(frozen=True)
class _Leaf:
    """One pytree leaf's placement inside its dtype bucket."""
    bucket: str
    index: int           # segment id within the bucket (bucket-local order)
    offset: int          # element offset within the bucket buffer
    size: int
    shape: tuple[int, ...]


class FlatParamSpace:
    """Bidirectional view between a params pytree and dtype-bucketed buffers.

    Built once from the (abstract or concrete) single-replica params; after
    that, `flatten`/`unflatten` are pure layout ops.  `lead` counts leading
    batch-like axes shared by every leaf (the runtime's worker axis W):
    leaves `[*lead, *shape]` map to buffers `[*lead, N_bucket]`.
    """

    def __init__(self, tree: Pytree):
        leaves, self.treedef = jax.tree.flatten(tree)
        if not leaves:
            raise LayoutError("empty params pytree")
        self._leaves: list[_Leaf] = []
        sizes: dict[str, int] = {}
        order: dict[str, list[int]] = {}
        for i, x in enumerate(leaves):
            b = jnp.dtype(x.dtype).name
            off = sizes.get(b, 0)
            n = int(np.prod(x.shape, dtype=np.int64)) if x.shape else 1
            self._leaves.append(_Leaf(b, len(order.setdefault(b, [])), off, n,
                                      tuple(x.shape)))
            order[b].append(i)
            sizes[b] = off + n
        self.buckets: tuple[str, ...] = tuple(sorted(sizes))
        self.sizes: dict[str, int] = {b: sizes[b] for b in self.buckets}
        self._order = order           # bucket -> leaf indices, offset order
        self._seg: dict[str, np.ndarray] = {}

    # -- introspection -----------------------------------------------------

    @property
    def n_leaves(self) -> int:
        return len(self._leaves)

    def bucket_leaves(self, bucket: str) -> int:
        return len(self._order[bucket])

    def buffer_size(self, bucket: str) -> int:
        """Bucket-buffer length as materialized by `flatten` (the sharded
        subclass pads this up to a multiple of its chunk count)."""
        return self.sizes[bucket]

    def segment_ids(self, bucket: str) -> np.ndarray:
        """int32 [N_bucket]: which leaf (bucket-local index) each element of
        the bucket buffer belongs to — the per-tensor reduction map."""
        if bucket not in self._seg:
            seg = np.empty(self.sizes[bucket], np.int32)
            for i in self._order[bucket]:
                lf = self._leaves[i]
                seg[lf.offset:lf.offset + lf.size] = lf.index
            self._seg[bucket] = seg
        return self._seg[bucket]

    # -- layout ops --------------------------------------------------------

    def flatten(self, tree: Pytree, *, lead: int = 0) -> dict[str, jax.Array]:
        """Pytree (leaves `[*lead, *shape]`, shapes matching the spec) ->
        `{bucket: [*lead, N]}`.  Mirror trees may carry a different dtype
        per leaf (e.g. fp32 moments of bf16 params); within a bucket all
        mirror leaves must agree so the buffer stays homogeneous."""
        leaves, treedef = jax.tree.flatten(tree)
        if treedef != self.treedef:
            raise LayoutError(
                f"pytree structure {treedef} does not match the spec's "
                f"{self.treedef}")
        out = {}
        for b in self.buckets:
            parts = []
            for i in self._order[b]:
                x = leaves[i]
                lf = self._leaves[i]
                if tuple(x.shape[lead:]) != lf.shape:
                    raise LayoutError(
                        f"leaf {i} shape {tuple(x.shape)} (lead={lead}) does "
                        f"not match the spec's {lf.shape}")
                parts.append(jnp.reshape(x, x.shape[:lead] + (lf.size,)))
            out[b] = parts[0] if len(parts) == 1 else \
                jnp.concatenate(parts, axis=lead)
        return out

    def unflatten(self, bufs: dict[str, jax.Array], *, lead: int = 0) -> Pytree:
        """`{bucket: [*lead, N]}` -> pytree of `[*lead, *shape]` leaves."""
        leaves: list[Any] = [None] * len(self._leaves)
        for b in self.buckets:
            buf = bufs[b]
            for i in self._order[b]:
                lf = self._leaves[i]
                sl = jax.lax.slice_in_dim(buf, lf.offset, lf.offset + lf.size,
                                          axis=lead)
                leaves[i] = jnp.reshape(sl, buf.shape[:lead] + lf.shape)
        return jax.tree.unflatten(self.treedef, leaves)

    # -- per-tensor reductions over the flat buffer ------------------------

    def segment_max(self, bucket: str, x: jax.Array) -> jax.Array:
        """Per-leaf max of an `[N]` bucket-shaped array -> `[#leaves]`.
        max is exact (no rounding), so this equals per-tensor `jnp.max`."""
        return jax.ops.segment_max(x, jnp.asarray(self.segment_ids(bucket)),
                                   num_segments=self.bucket_leaves(bucket))

    def spread(self, bucket: str, per_leaf: jax.Array) -> jax.Array:
        """Gather `[#leaves]` per-tensor values back to elements `[N]`."""
        return per_leaf[jnp.asarray(self.segment_ids(bucket))]


class ShardedFlatSpace(FlatParamSpace):
    """FlatParamSpace whose buckets split into per-device contiguous chunks.

    Each dtype bucket is zero-padded to a multiple of `shards` so that it
    divides evenly into `shards` contiguous chunks (FSDP-style).  `shards`
    should be W * S — worker count times the product of the flat-dim mesh
    axes — so both the storage sharding (S chunks) and the sync
    reduce_scatter (each worker owns 1/W of a chunk) land on whole-element
    boundaries.  Padding is invisible to `unflatten` (leaf offsets never
    reach it) and inert in the runtime: pad params/grads/moments start and
    stay exactly zero, pad deltas quantize to zero, and the pad's segment id
    sits outside [0, #leaves) so `segment_max` drops it.

    When built with a `mesh` (plus the worker/shard axis names), the sync
    path (core/sync.py) expresses the worker mean as an explicit
    `psum_scatter` + `all_gather` over `worker_axes` via shard_map — one
    reduce_scatter and one all_gather per bucket on the wire.  Without a
    mesh (single-process tests, the host training loop) the same state
    layout runs the plain-jnp flat path, bitwise-equal to layouts tree/flat.
    """

    def __init__(self, tree: Pytree, shards: int = 1, *, mesh=None,
                 worker_axes: tuple[str, ...] = (),
                 shard_axes: tuple[str, ...] = ()):
        super().__init__(tree)
        if shards < 1:
            raise LayoutError(f"shards must be >= 1, got {shards}")
        self.shards = shards
        self.mesh = mesh
        self.worker_axes = tuple(worker_axes)
        self.shard_axes = tuple(shard_axes)
        self.pad: dict[str, int] = {b: (-n) % shards
                                    for b, n in self.sizes.items()}

    def buffer_size(self, bucket: str) -> int:
        """Padded bucket-buffer length (a multiple of `shards`)."""
        return self.sizes[bucket] + self.pad[bucket]

    def flatten(self, tree: Pytree, *, lead: int = 0) -> dict[str, jax.Array]:
        out = super().flatten(tree, lead=lead)
        for b, x in out.items():
            if self.pad[b]:
                widths = [(0, 0)] * lead + [(0, self.pad[b])]
                out[b] = jnp.pad(x, widths)
        return out

    def segment_ids(self, bucket: str) -> np.ndarray:
        """Like the base map, extended over the pad with id == #leaves —
        out of range for `segment_max` (pad never contaminates a leaf's
        statistic) and clamped by `spread`'s gather (pad elements read the
        last leaf's value, harmless: their delta is exactly zero)."""
        if bucket not in self._seg:
            base = super().segment_ids(bucket)
            if self.pad[bucket]:
                ext = np.full(self.pad[bucket], self.bucket_leaves(bucket),
                              np.int32)
                self._seg[bucket] = np.concatenate([base, ext])
        return self._seg[bucket]


# --------------------------------------------------------------------------
# State shardings for the flat layouts
# --------------------------------------------------------------------------

def axis_entry(axes):
    """Mesh-axis name tuple -> PartitionSpec entry (None / name / tuple) —
    the one normalization every mesh-carrying call site shares."""
    if not isinstance(axes, tuple):
        return axes                       # already a name or None
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def flat_state_specs(run_cfg, waxes, spec):
    """PartitionSpec tree for the flat runtime state.  `waxes` is the worker
    mesh-axis tuple (or an already-normalized PartitionSpec entry).

    Plain flat: the worker axis over the worker mesh axes; the flat dim
    replicated (per-leaf inner shardings don't survive concatenation).
    flat_sharded: the flat dim additionally splits into contiguous chunks
    over the non-worker mesh axes — params AND optimizer moments stored at
    1/S per device, anchors/outer momentum likewise — which is what lets
    the fsdp policy run a flat layout at all."""
    from jax.sharding import PartitionSpec as P
    waxes = axis_entry(waxes)
    flat_dim = axis_entry(getattr(spec, "shard_axes", ()))
    bufs = lambda lead: {b: P(*(lead + (flat_dim,))) for b in spec.buckets}
    wlead, alead = (waxes,), ()
    if run_cfg.optimizer == "sgd":
        opt = {"mu": bufs(wlead), "step": P()}
    else:
        opt = {"m": bufs(wlead), "v": bufs(wlead), "step": P()}
    out = {"params": bufs(wlead), "opt": opt}
    if run_cfg.sync_quantize or run_cfg.outer_momentum > 0.0:
        out["anchor"] = bufs(alead)
        if run_cfg.outer_momentum > 0.0:
            out["outer_mu"] = bufs(alead)
    return out


def make_global(x, mesh, pspec):
    """One host-replicated value -> a global array laid out on `mesh`.
    `make_array_from_callback` builds the buffer from its addressable shards
    only, so the same call works single-process (simulated devices) and
    across real `jax.distributed` processes — every process holds the
    identical host value, each contributes its own shards.  Shared by
    RoundEngine init and the multihost harness so the two stay bitwise
    comparable."""
    from jax.sharding import NamedSharding
    xnp = np.asarray(x)
    return jax.make_array_from_callback(xnp.shape, NamedSharding(mesh, pspec),
                                        lambda idx: xnp[idx])


# --------------------------------------------------------------------------
# Runtime-state conversion (the RoundEngine's layout="flat" entry points)
# --------------------------------------------------------------------------

_STACKED = ("m", "v", "mu")       # optimizer slots carrying the worker axis


def spec_for_params(params_single: Pytree) -> FlatParamSpace:
    return FlatParamSpace(params_single)


def to_flat_state(spec: FlatParamSpace, state: Pytree) -> Pytree:
    """Tree runtime state (local_update.init_state layout) -> flat state:
    params/opt moments become `{bucket: [W, N]}`, the sync anchor and outer
    momentum become `{bucket: [N]}`; scalars ride along unchanged."""
    out = {"params": spec.flatten(state["params"], lead=1)}
    out["opt"] = {k: (spec.flatten(v, lead=1) if k in _STACKED else v)
                  for k, v in state["opt"].items()}
    if "anchor" in state:
        out["anchor"] = spec.flatten(state["anchor"])
    if "outer_mu" in state:
        out["outer_mu"] = spec.flatten(state["outer_mu"])
    return out


def to_tree_state(spec: FlatParamSpace, state: Pytree) -> Pytree:
    """Inverse of `to_flat_state` (bitwise: slices of the concatenation)."""
    out = {"params": spec.unflatten(state["params"], lead=1)}
    out["opt"] = {k: (spec.unflatten(v, lead=1) if k in _STACKED else v)
                  for k, v in state["opt"].items()}
    if "anchor" in state:
        out["anchor"] = spec.unflatten(state["anchor"])
    if "outer_mu" in state:
        out["outer_mu"] = spec.unflatten(state["outer_mu"])
    return out
