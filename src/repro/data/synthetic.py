"""Deterministic synthetic data pipelines.

Token stream: a Markov-chain language (per-seed transition structure) so the
loss is genuinely learnable (not memorizing noise) — train loss decreases and
a held-out split measures generalization.  Vision stream: a noisy teacher-MLP
labeling of random images (paper-style generalization experiments need label
structure + noise).

Sharding follows the paper's Appendix B sampling-without-replacement scheme:
every worker draws disjoint slices of a shared permuted stream; with
`sample_with_replacement=True` workers draw i.i.d. batches (the theory setup).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStream:
    """Order-1 Markov LM over `vocab` symbols with `branch` likely successors."""
    vocab: int
    seed: int = 0
    branch: int = 4

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        # sparse transition table: each symbol has `branch` likely successors
        self.succ = rng.randint(0, self.vocab, size=(self.vocab, self.branch))
        self.noise = 0.1

    def batch(self, step: int, worker: int, batch: int, seq: int,
              *, replacement: bool = True):
        """Returns (tokens, labels) int32 [batch, seq]; labels = next token."""
        seed = (step * 1000003 + worker * 7919 + self.seed) % (2**31)
        rng = np.random.RandomState(seed)
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, 0] = rng.randint(0, self.vocab, size=batch)
        for t in range(seq):
            nxt = self.succ[toks[:, t], rng.randint(0, self.branch, size=batch)]
            flip = rng.rand(batch) < self.noise
            nxt = np.where(flip, rng.randint(0, self.vocab, size=batch), nxt)
            toks[:, t + 1] = nxt
        return (jnp.asarray(toks[:, :-1], jnp.int32),
                jnp.asarray(toks[:, 1:], jnp.int32))


@dataclasses.dataclass
class VisionStream:
    """Teacher-labeled random images with label noise (K-class)."""
    n_classes: int
    image: int = 32
    channels: int = 3
    seed: int = 0
    label_noise: float = 0.1

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        d = self.image * self.image * self.channels
        self.w1 = rng.randn(d, 64).astype(np.float32) / np.sqrt(d)
        self.w2 = rng.randn(64, self.n_classes).astype(np.float32) / 8.0

    def batch(self, step: int, worker: int, batch: int, *, noisy=True):
        seed = (step * 999983 + worker * 31337 + self.seed) % (2**31)
        rng = np.random.RandomState(seed)
        x = rng.randn(batch, self.image, self.image,
                      self.channels).astype(np.float32)
        h = np.tanh(x.reshape(batch, -1) @ self.w1) @ self.w2
        y = h.argmax(-1)
        if noisy and self.label_noise:
            flip = rng.rand(batch) < self.label_noise
            y = np.where(flip, rng.randint(0, self.n_classes, size=batch), y)
        return jnp.asarray(x), jnp.asarray(y, jnp.int32)


def effective_batch_view(batch, lanes, axis: int = 1):
    """Batch-size-as-a-traced-argument: view `batch` (leaves [..., B, ...]
    with the per-worker batch at `axis`) as an *effective* batch of `lanes`
    samples without changing any array shape — samples [0, lanes) are tiled
    to fill the B slots (`idx = arange(B) % lanes`), so when `lanes`
    divides B the mean loss and gradient are EXACTLY those of a
    batch-`lanes` step (each distinct sample weighted B/lanes times, the
    weights cancel in the mean).  `lanes` may be a traced int32 scalar:
    changing the effective batch between rounds recompiles nothing — the
    knob the adaptive controller (core/controller.py) rides.  With
    lanes == B the index is the identity and the gather is a bitwise
    pass-through."""
    def take(x):
        if x.ndim <= axis:
            return x
        idx = jnp.arange(x.shape[axis]) % lanes
        return jnp.take(x, idx, axis=axis)
    return jax.tree.map(take, batch)


def device_batch_fn(cfg, stream: TokenStream, w: int, b_loc: int, seq: int):
    """Jittable on-device batch synthesis: `synth(step) -> batch [W, B, ...]`.

    Runs the same order-1 Markov process as `TokenStream.batch` (identical
    transition table and noise rate) but drives it with counter-based
    `jax.random.fold_in` keys, so it is deterministic in (seed, step) and can
    be traced *inside* the jitted round program — no host-side `jnp.stack`
    of `[H, W, B, S]` arrays and no host->device transfer per round.  The
    draws differ from the numpy stream (different RNG), so the two paths
    yield the same language, not the same batches.
    """
    succ = jnp.asarray(stream.succ, jnp.int32)          # [vocab, branch]
    vocab, branch, noise = stream.vocab, stream.branch, stream.noise
    base = jax.random.PRNGKey(stream.seed)

    def synth(step):
        key = jax.random.fold_in(base, step)
        k0, kb, kf, kn, kv, ka = jax.random.split(key, 6)
        tok0 = jax.random.randint(k0, (w, b_loc), 0, vocab)

        def body(tok, ks):
            kb_i, kf_i, kn_i = ks
            nxt = succ[tok, jax.random.randint(kb_i, (w, b_loc), 0, branch)]
            flip = jax.random.uniform(kf_i, (w, b_loc)) < noise
            nxt = jnp.where(flip,
                            jax.random.randint(kn_i, (w, b_loc), 0, vocab),
                            nxt)
            return nxt, nxt

        keys = (jax.random.split(kb, seq), jax.random.split(kf, seq),
                jax.random.split(kn, seq))
        _, outs = jax.lax.scan(body, tok0, keys)
        labels = jnp.moveaxis(outs, 0, -1)              # [W, B, S]
        tokens = jnp.concatenate([tok0[..., None], labels[..., :-1]], -1)
        batch = {"tokens": tokens.astype(jnp.int32),
                 "labels": labels.astype(jnp.int32)}
        if cfg.family == "vlm":
            batch["prefix_embeds"] = 0.02 * jax.random.normal(
                kv, (w, b_loc, cfg.n_img_tokens, cfg.d_model))
        if cfg.family == "audio":
            batch["frames"] = 0.1 * jax.random.normal(
                ka, (w, b_loc, cfg.enc_seq, cfg.d_model))
        return batch

    return synth


def make_train_batch(cfg, stream: TokenStream, step: int, w: int, b_loc: int,
                     seq: int, rng_extra: int = 0):
    """Stacked per-worker batch [W, B_loc, ...] for the local-gradient runtime."""
    toks, labels = [], []
    for k in range(w):
        t, l = stream.batch(step + rng_extra, k, b_loc, seq)
        toks.append(t)
        labels.append(l)
    batch = {"tokens": jnp.stack(toks), "labels": jnp.stack(labels)}
    if cfg.family == "vlm":
        key = jax.random.PRNGKey(step * 131 + 7)
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            key, (w, b_loc, cfg.n_img_tokens, cfg.d_model))
    if cfg.family == "audio":
        key = jax.random.PRNGKey(step * 131 + 11)
        batch["frames"] = 0.1 * jax.random.normal(
            key, (w, b_loc, cfg.enc_seq, cfg.d_model))
    return batch
