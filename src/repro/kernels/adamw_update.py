"""Fused AdamW update — Pallas TPU kernel.

The innermost loop of every local step in Local AdamW (paper Alg. 2 line 12):
p, m, v are streamed through VMEM in 1D blocks; all five elementwise ops
(two moment updates, bias correction, weight decay, parameter step) fuse
into one pass, so HBM traffic is the roofline minimum (read p,m,v,g; write
p,m,v) instead of one round-trip per op.

Inputs may be any rank (the kernel flattens): under the tree layout the
optimizer invokes this once per pytree leaf, paying up to one _BLOCK of
padding and one kernel launch *per tensor*; under the flat layout
(core/flat.py) it is invoked once per dtype bucket on the [W, N] buffer —
one launch and at most one block of padding for the whole model.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK = 64 * 1024  # 64K elements * (4B fp32 * ~7 tensors) ~ 1.8 MiB VMEM


def _adamw_kernel(p_ref, m_ref, v_ref, g_ref, sc_ref, po_ref, mo_ref, vo_ref,
                  *, beta1, beta2, eps, weight_decay):
    lr = sc_ref[0]
    step = sc_ref[1]
    g = g_ref[...].astype(jnp.float32)
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    upd = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    pf = p_ref[...].astype(jnp.float32)
    po_ref[...] = (pf - lr * (upd + weight_decay * pf)).astype(po_ref.dtype)
    mo_ref[...] = m
    vo_ref[...] = v


@partial(jax.jit,
         static_argnames=("beta1", "beta2", "eps", "weight_decay", "interpret"))
def adamw_update(p, m, v, g, *, lr, beta1, beta2, eps, weight_decay, step,
                 interpret: bool = False):
    """All tensors same shape; m, v fp32. Returns (new_p, new_m, new_v)."""
    shape = p.shape
    n = p.size
    blk = min(_BLOCK, n)
    pad = (-n) % blk
    flat = lambda x: jnp.pad(x.reshape(-1), (0, pad))
    pf, mf, vf, gf = flat(p), flat(m), flat(v), flat(g)
    scalars = jnp.stack([jnp.asarray(lr, jnp.float32),
                         jnp.asarray(step, jnp.float32)])
    grid = ((n + pad) // blk,)
    spec = pl.BlockSpec((blk,), lambda i: (i,))
    po, mo, vo = pl.pallas_call(
        partial(_adamw_kernel, beta1=beta1, beta2=beta2, eps=eps,
                weight_decay=weight_decay),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((2,), lambda i: (0,))],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct(pf.shape, p.dtype),
                   jax.ShapeDtypeStruct(mf.shape, jnp.float32),
                   jax.ShapeDtypeStruct(vf.shape, jnp.float32)],
        interpret=interpret,
    )(pf, mf, vf, gf, scalars)
    unflat = lambda x: x[:n].reshape(shape)
    return unflat(po), unflat(mo), unflat(vo)
