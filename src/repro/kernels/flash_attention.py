"""Flash attention (causal GQA + sliding window + prefix-LM) — Pallas TPU.

Tiling (MXU/VMEM-aware):
  grid = (batch, q_heads, n_q_blocks, n_k_blocks); the innermost grid dim
  walks K blocks while fp32 accumulators (running max / denominator / output)
  persist in VMEM scratch — the classic online-softmax flash schedule.
  Default blocks 128x128: q,k,v tiles are 128x128xbf16 = 32 KiB each and the
  fp32 score tile is 64 KiB — comfortably inside the ~16 MiB VMEM budget, and
  every matmul dim is a multiple of the 128-lane MXU width.

GQA is expressed in the BlockSpec index maps: the kv index map divides the
query-head grid coordinate by the group size, so no head replication ever
materializes in HBM.

`window`/`prefix_len` must be static here (Python ints): the TPU kernel
specializes the mask.

`flash_decode` is the single-query serving variant (q-block = 1): one query
per sequence against the paged/ring KV cache, grid (batch, kv_heads,
k_blocks), the whole GQA group's [g, d] query tile resident per program.
Unlike the training kernel its mask inputs are RUNTIME values — the model
scan feeds per-layer windows as scan xs, continuous batching feeds per-slot
ragged positions, and the ring cache feeds absolute key positions — so they
ride in as int32 operands read inside the kernel rather than specializing
it.  Dispatch: ops.flash_attention routes every sq==1 causal call here.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.errors import ShapeError

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int, prefix_len: int,
                  q_offset: int, block_q: int, block_k: int, n_k: int,
                  kv_len: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i = pl.program_id(2)
    q = q_ref[0, :, 0, :].astype(jnp.float32)          # [bq, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = q @ k.T * scale                                 # [bq, bk]

    q_idx = i * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + q_offset
    k_idx = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = k_idx < kv_len
    if causal:
        ok &= k_idx <= q_idx
    if window > 0:
        ok &= k_idx > q_idx - window
    if prefix_len > 0:
        ok |= k_idx < prefix_len
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, :, 0, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "prefix_len", "q_offset",
                              "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, prefix_len=0,
                    q_offset=0, scale=None, block_q=128, block_k=128,
                    interpret=False):
    """q [B,Sq,Hq,D]; k,v [B,Sk,Hkv,D] -> [B,Sq,Hq,D]."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    if hq % hkv != 0:
        raise ShapeError(f"GQA needs Hq % Hkv == 0, got ({hq}, {hkv})")
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    window = int(window)

    bq = min(block_q, sq)
    bk = min(block_k, sk)
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    n_q = (sq + pad_q) // bq
    n_k = (sk + pad_k) // bk

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        prefix_len=prefix_len, q_offset=q_offset, block_q=bq, block_k=bk,
        n_k=n_k, kv_len=sk)

    out = _call(kernel, q, k, v, b, hq, n_q, n_k, bq, bk, d, g, sq, pad_q,
                interpret)
    return out[:, :sq]


def _call(kernel, q, k, v, b, hq, n_q, n_k, bq, bk, d, g, sq, pad_q,
          interpret):
    from jax.experimental.pallas import tpu as pltpu
    scratch = [pltpu.VMEM((bq, d), jnp.float32),
               pltpu.VMEM((bq,), jnp.float32),
               pltpu.VMEM((bq,), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=(b, hq, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, 1, d), lambda b_, h, i, j: (b_, i, h, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h, i, j: (b_, j, h // g, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h, i, j: (b_, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, d), lambda b_, h, i, j: (b_, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq + pad_q, hq, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)


# --------------------------------------------------------------------------
# Single-query decode kernel (serving hot path)
# --------------------------------------------------------------------------

def _decode_kernel(qoff_ref, win_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, causal: bool,
                   prefix_len: int, n_k: int):
    """One (batch row, kv head) pair's GQA group against one K block.

    The online-softmax accumulators are [g]-shaped (g = query heads per kv
    head): the whole group shares the K/V tiles, so GQA costs one K/V read
    per GROUP instead of per query head.  Mask semantics mirror ref._mask
    exactly; `k_idx` comes from the kpos operand (arange for a dense cache,
    absolute stream positions for a ring buffer, -1 marking padding/empty),
    and the query's absolute position / window arrive as runtime scalars."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32)          # [g, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)          # [bk, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = q @ k.T * scale                                 # [g, bk]

    qpos = qoff_ref[0, 0]                               # absolute query pos
    win = win_ref[0, 0]                                 # per-layer window
    k_idx = jnp.broadcast_to(kpos_ref[0, :][None, :], s.shape)
    valid = k_idx >= 0                                  # -1 = pad / empty
    ok = valid
    if causal:
        ok &= k_idx <= qpos
    ok &= (win <= 0) | (k_idx > qpos - win)
    if prefix_len > 0:
        ok |= valid & (k_idx < prefix_len)              # bidirectional prefix
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, 0, :, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "prefix_len", "scale", "block_k",
                              "interpret"))
def flash_decode(q, k, v, *, causal=True, window=0, prefix_len=0, q_offset=0,
                 scale=None, k_positions=None, block_k=128, interpret=False):
    """Single-query decode: q [B,1,Hq,D] against a KV cache k/v [B,Sk,Hkv,D].

    Unlike `flash_attention`, `window` (scalar) and `q_offset` (scalar or
    per-batch [B] — ragged continuous batching) may be TRACED; they ride in
    as int32 operands.  `k_positions [Sk]` serves the ring-buffer cache:
    absolute stream position per cache row, -1 for empty.  Returns
    [B,1,Hq,D].
    """
    b, sq, hq, d = q.shape
    if sq != 1:
        raise ShapeError(f"flash_decode is the single-query kernel, Sq={sq}")
    _, sk, hkv, _ = k.shape
    if hq % hkv != 0:
        raise ShapeError(f"GQA needs Hq % Hkv == 0, got ({hq}, {hkv})")
    g = hq // hkv
    scale = float(scale) if scale is not None else d ** -0.5

    qoff = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32).reshape(-1),
                            (b,)).reshape(b, 1)
    win = jnp.reshape(jnp.asarray(window, jnp.int32), (1, 1))
    kpos = (jnp.arange(sk, dtype=jnp.int32) if k_positions is None
            else jnp.asarray(k_positions, jnp.int32))

    bk = min(block_k, sk)
    pad_k = (-sk) % bk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad_k), constant_values=-1)
    n_k = (sk + pad_k) // bk
    kpos = kpos.reshape(1, sk + pad_k)
    qg = q.reshape(b, hkv, g, d)     # head h = kv*g + gi, same grouping as ref

    from jax.experimental.pallas import tpu as pltpu
    kernel = functools.partial(_decode_kernel, scale=scale, causal=causal,
                               prefix_len=prefix_len, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, n_k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h, j: (b_, 0)),       # q_offset
            pl.BlockSpec((1, 1), lambda b_, h, j: (0, 0)),        # window
            pl.BlockSpec((1, bk), lambda b_, h, j: (0, j)),       # k positions
            pl.BlockSpec((1, 1, g, d), lambda b_, h, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h, j: (b_, j, h, 0)),
            pl.BlockSpec((1, bk, 1, d), lambda b_, h, j: (b_, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b_, h, j: (b_, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((g, d), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32),
                        pltpu.VMEM((g,), jnp.float32)],
        interpret=interpret,
    )(qoff, win, kpos, qg, k, v)
    return out.reshape(b, 1, hq, d)
