"""Fused flat-buffer sync update — Pallas TPU kernel.

One communication-round sync over a dtype bucket of the FlatParamSpace
(core/flat.py): per-worker delta from the anchor, optional int8
quantize/dequantize (per-tensor scales precomputed and spread to elements),
worker mean, optional Nesterov outer momentum, anchor update, and the
broadcast of the new consensus back to every replica — all in ONE pass
through VMEM.  The tree-layout path runs the same math as ~6 separate jnp
ops, each round-tripping the (model-sized) delta through HBM; here HBM
traffic is the roofline minimum: read p, anchor (+ scale, mu), write p,
anchor (+ mu).

The worker-mean all-reduce itself is GSPMD's (the W axis is sharded over
the worker mesh axes); inside the kernel the W axis is the block's leading
dim, so `jnp.mean(axis=0)` stays a local reduction per shard.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK = 256 * 1024   # elements per (W x blk) tile budget: W*blk <= _BLOCK


def _kernel(refs, *, momentum, quantize, n_in):
    in_refs, out_refs = refs[:n_in], refs[n_in:]
    p_ref, a_ref = in_refs[0], in_refs[1]
    s_ref = in_refs[2] if quantize else None
    mu_ref = in_refs[2 + bool(quantize)] if momentum > 0.0 else None
    po_ref, ao_ref = out_refs[0], out_refs[1]

    af = a_ref[...].astype(jnp.float32)                 # [blk]
    d = p_ref[...].astype(jnp.float32) - af[None]       # [W, blk]
    if quantize:
        # RS-domain rule (core/sync.py): mean the integer codes, dequantize
        # once after — Σq is exact in f32 for any order, which is what keeps
        # this pass bitwise-equal to the sharded layout's reduce_scatter of
        # the same codes.
        s = s_ref[...]
        q = jnp.clip(jnp.round(d / s[None] * 127.0), -127.0, 127.0)
        step = jnp.mean(q, axis=0) * (s / 127.0)
    else:
        step = jnp.mean(d, axis=0)
    if momentum > 0.0:
        mu1 = momentum * mu_ref[...] + step
        step = momentum * mu1 + step                    # Nesterov
        out_refs[2][...] = mu1
    a1 = (af + step).astype(ao_ref.dtype)
    ao_ref[...] = a1
    po_ref[...] = jnp.broadcast_to(a1[None], d.shape).astype(po_ref.dtype)


@partial(jax.jit, static_argnames=("momentum", "interpret"))
def sync_flat_update(p, anchor, *, scale=None, mu=None, momentum: float = 0.0,
                     interpret: bool = False):
    """p [W, N]; anchor [N]; scale [N] or None; mu [N] fp32 iff momentum > 0.
    Returns (new_p, new_anchor, new_mu | None) — see kernels/ref.py oracle."""
    w, n = p.shape
    quantize = scale is not None
    blk = min(n, max(8 * 128, _BLOCK // max(w, 1)))
    pad = (-n) % blk
    pad1 = lambda x, v=0.0: jnp.pad(x, (0, pad), constant_values=v)
    pp = jnp.pad(p, ((0, 0), (0, pad)))
    args = [pp, pad1(anchor)]
    spec2 = pl.BlockSpec((w, blk), lambda i: (0, i))
    spec1 = pl.BlockSpec((blk,), lambda i: (i,))
    in_specs = [spec2, spec1]
    if quantize:
        args.append(pad1(scale, 1.0))   # pad scale 1: guards the pad's 0/0
        in_specs.append(spec1)
    if momentum > 0.0:
        args.append(pad1(mu))
        in_specs.append(spec1)
    out_shape = [jax.ShapeDtypeStruct(pp.shape, p.dtype),
                 jax.ShapeDtypeStruct((n + pad,), anchor.dtype)]
    out_specs = [spec2, spec1]
    if momentum > 0.0:
        out_shape.append(jax.ShapeDtypeStruct((n + pad,), jnp.float32))
        out_specs.append(spec1)

    def body(*refs):
        _kernel(refs, momentum=momentum, quantize=quantize, n_in=len(args))

    out = pl.pallas_call(body, grid=((n + pad) // blk,), in_specs=in_specs,
                         out_specs=out_specs, out_shape=out_shape,
                         interpret=interpret)(*args)
    new_p, new_a = out[0][:, :n], out[1][:n]
    new_mu = out[2][:n] if momentum > 0.0 else None
    return new_p, new_a, new_mu


# --------------------------------------------------------------------------
# The gather-leg apply: dequant + outer Nesterov + anchor in one pass
# --------------------------------------------------------------------------

def _apply_kernel(refs, *, momentum, quantize, n_in):
    in_refs, out_refs = refs[:n_in], refs[n_in:]
    q_ref, a_ref = in_refs[0], in_refs[1]
    s_ref = in_refs[2] if quantize else None
    mu_ref = in_refs[2 + bool(quantize)] if momentum > 0.0 else None

    step = q_ref[...]                                   # [blk] f32
    if quantize:
        step = step * (s_ref[...] / 127.0)
    if momentum > 0.0:
        mu1 = momentum * mu_ref[...] + step
        step = momentum * mu1 + step                    # Nesterov
        out_refs[1][...] = mu1
    out_refs[0][...] = (a_ref[...].astype(jnp.float32)
                        + step).astype(out_refs[0].dtype)


@partial(jax.jit, static_argnames=("momentum", "interpret"))
def sync_apply_update(step_in, anchor, *, scale=None, mu=None,
                      momentum: float = 0.0, interpret: bool = False):
    """step_in [N] f32 (the worker-mean codes qmean when `scale` is given,
    else the mean delta); anchor [N]; scale [N] or None; mu [N] fp32 iff
    momentum > 0.  Returns (new_anchor, new_mu | None) — the deferrable
    gather leg of the sync in one VMEM pass; see kernels/ref.py oracle."""
    (n,) = step_in.shape
    quantize = scale is not None
    blk = min(n, _BLOCK)
    pad = (-n) % blk
    pad1 = lambda x, v=0.0: jnp.pad(x, (0, pad), constant_values=v)
    args = [pad1(step_in), pad1(anchor)]
    spec1 = pl.BlockSpec((blk,), lambda i: (i,))
    in_specs = [spec1, spec1]
    if quantize:
        args.append(pad1(scale, 1.0))
        in_specs.append(spec1)
    if momentum > 0.0:
        args.append(pad1(mu))
        in_specs.append(spec1)
    out_shape = [jax.ShapeDtypeStruct((n + pad,), anchor.dtype)]
    out_specs = [spec1]
    if momentum > 0.0:
        out_shape.append(jax.ShapeDtypeStruct((n + pad,), jnp.float32))
        out_specs.append(spec1)

    def body(*refs):
        _apply_kernel(refs, momentum=momentum, quantize=quantize,
                      n_in=len(args))

    out = pl.pallas_call(body, grid=((n + pad) // blk,), in_specs=in_specs,
                         out_specs=out_specs, out_shape=out_shape,
                         interpret=interpret)(*args)
    new_a = out[0][:n]
    new_mu = out[1][:n] if momentum > 0.0 else None
    return new_a, new_mu


# --------------------------------------------------------------------------
# The per-hop requant pass of the int8 ring (core/sync.py --wire ring-int8)
# --------------------------------------------------------------------------

def _ring_combine_kernel(q_ref, s_ref, x_ref, acc_ref, am_ref, *, k):
    deq = q_ref[...].astype(jnp.float32) * (s_ref[...] / 127.0)
    acc = (jnp.float32(k) * deq + x_ref[...].astype(jnp.float32)) \
        / jnp.float32(k + 1)
    acc_ref[...] = acc
    am_ref[...] = jnp.max(jnp.abs(acc))[None]


@partial(jax.jit, static_argnames=("k", "interpret"))
def ring_combine(q, s, x, k: int, interpret: bool = False):
    """One receive hop of the re-quantizing ring, fused: dequantize the
    incoming int8 codes, fold the local chunk into the running mean, and
    emit the amax the next hop's scale needs — one VMEM pass instead of the
    dequant/mul/add/div/abs/max chain (see kernels/ref.py oracle).

    q [n] int8; s () f32 sender scale; x [n] local chunk.  Returns
    (acc [n] f32, amax () f32)."""
    (n,) = q.shape
    blk = min(n, _BLOCK)
    pad = (-n) % blk
    # pad codes/chunk with zeros: the padded lanes contribute 0 to acc and
    # |0| to the amax fold — both identities
    qq = jnp.pad(q, (0, pad))
    xx = jnp.pad(x, (0, pad))
    grid = (n + pad) // blk
    spec1 = pl.BlockSpec((blk,), lambda i: (i,))
    spec_s = pl.BlockSpec((1,), lambda i: (0,))
    out = pl.pallas_call(
        partial(_ring_combine_kernel, k=k), grid=(grid,),
        in_specs=[spec1, spec_s, spec1],
        out_specs=[spec1, pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n + pad,), jnp.float32),
                   jax.ShapeDtypeStruct((grid,), jnp.float32)],
        interpret=interpret)(qq, jnp.reshape(s, (1,)).astype(jnp.float32), xx)
    return out[0][:n], jnp.max(out[1])


def _ring_quantize_kernel(acc_ref, s_ref, q_ref):
    q_ref[...] = jnp.clip(jnp.round(acc_ref[...] / s_ref[...] * 127.0),
                          -127.0, 127.0).astype(jnp.int8)


@partial(jax.jit, static_argnames=("interpret",))
def ring_quantize(acc, scale, interpret: bool = False):
    """int8 wire codes of a ring partial mean under one guarded scalar
    scale — the send-side half of the per-hop requant pass.  acc [n] f32,
    scale () f32 (already guarded > 0).  Returns q [n] int8."""
    (n,) = acc.shape
    blk = min(n, _BLOCK)
    pad = (-n) % blk
    aa = jnp.pad(acc, (0, pad))
    spec1 = pl.BlockSpec((blk,), lambda i: (i,))
    spec_s = pl.BlockSpec((1,), lambda i: (0,))
    out = pl.pallas_call(
        _ring_quantize_kernel, grid=((n + pad) // blk,),
        in_specs=[spec1, spec_s],
        out_specs=spec1,
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.int8),
        interpret=interpret)(aa, jnp.reshape(scale, (1,)).astype(jnp.float32))
    return out[:n]
