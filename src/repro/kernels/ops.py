"""Jit'd dispatch wrappers for the Pallas kernels.

Backend selection:
  * ``"jnp"``       — pure-jnp reference (default on CPU; identical math to ref.py)
  * ``"pallas"``    — real Pallas lowering (TPU target)
  * ``"interpret"`` — Pallas kernel body interpreted on CPU (used by tests)

Models call these entry points; they never touch pallas_call directly.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.errors import ConfigError
from repro.kernels import ref

_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "jnp")


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in ("jnp", "pallas", "interpret"):
        raise ConfigError(f"unknown kernel backend {name!r}")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    if _BACKEND == "jnp":
        return ref.rms_norm(x, scale, eps)
    from repro.kernels import rmsnorm as _k
    return _k.rms_norm(x, scale, eps=eps, interpret=(_BACKEND == "interpret"))


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    prefix_len: int = 0, q_offset=0, scale: float | None = None,
                    k_positions=None):
    """q [B,Sq,H,D], k/v [B,Sk,Hkv,D] (GQA by head broadcast)."""
    import numpy as _np
    ragged = getattr(q_offset, "ndim", 0) and _np.ndim(q_offset) > 0
    if _BACKEND != "jnp" and q.shape[1] == 1 and causal:
        # the serving hot path: single-query decode runs the q-block=1
        # Pallas kernel, which takes window / q_offset (incl. ragged [B]) /
        # ring k_positions as runtime operands — the cases the training
        # kernel's static masks cannot express.
        from repro.kernels import flash_attention as _k
        return _k.flash_decode(q, k, v, causal=causal, window=window,
                               prefix_len=prefix_len, q_offset=q_offset,
                               scale=scale, k_positions=k_positions,
                               interpret=(_BACKEND == "interpret"))
    traced_window = isinstance(window, jax.core.Tracer)
    if _BACKEND == "jnp" or k_positions is not None or ragged or traced_window:
        # full-sequence ring/ragged shapes — and traced windows from the
        # scan-stacked prefill — stay on the jnp path: the block kernel's
        # masks are static.
        return ref.attention(q, k, v, causal=causal, window=window,
                             prefix_len=prefix_len, q_offset=q_offset,
                             scale=scale, k_positions=k_positions)
    from repro.kernels import flash_attention as _k
    return _k.flash_attention(q, k, v, causal=causal, window=int(window),
                              prefix_len=prefix_len, q_offset=q_offset,
                              scale=scale, interpret=(_BACKEND == "interpret"))


def adamw_update(p, m, v, g, *, lr, beta1, beta2, eps, weight_decay, step):
    """Fused AdamW update for one flat tensor. Returns (new_p, new_m, new_v)."""
    if _BACKEND == "jnp":
        return ref.adamw_update(p, m, v, g, lr=lr, beta1=beta1, beta2=beta2,
                                eps=eps, weight_decay=weight_decay, step=step)
    from repro.kernels import adamw_update as _k
    return _k.adamw_update(p, m, v, g, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
                           weight_decay=weight_decay, step=step,
                           interpret=(_BACKEND == "interpret"))


def sync_flat_update(p, anchor, *, scale=None, mu=None, momentum: float = 0.0):
    """Fused flat-bucket sync (delta -> int8 round-trip -> worker mean ->
    Nesterov -> anchor/params) in one pass. Returns (new_p, new_anchor,
    new_mu | None); see kernels/sync_update.py."""
    if _BACKEND == "jnp":
        return ref.sync_flat_update(p, anchor, scale=scale, mu=mu,
                                    momentum=momentum)
    from repro.kernels import sync_update as _k
    return _k.sync_flat_update(p, anchor, scale=scale, mu=mu,
                               momentum=momentum,
                               interpret=(_BACKEND == "interpret"))


def sync_apply_update(step_in, anchor, *, scale=None, mu=None,
                      momentum: float = 0.0):
    """Fused gather-leg apply for one flat bucket: dequantize the worker-mean
    int8 codes (when `scale` is given), outer Nesterov, anchor update — one
    pass. Returns (new_anchor, new_mu | None); see kernels/sync_update.py."""
    if _BACKEND == "jnp":
        return ref.sync_apply_update(step_in, anchor, scale=scale, mu=mu,
                                     momentum=momentum)
    from repro.kernels import sync_update as _k
    return _k.sync_apply_update(step_in, anchor, scale=scale, mu=mu,
                                momentum=momentum,
                                interpret=(_BACKEND == "interpret"))


def ring_combine(q, s, x, k: int):
    """One receive hop of the re-quantizing int8 ring: dequantize incoming
    codes, fold the local chunk into the running mean, emit the next hop's
    amax — fused (kernels/sync_update.py). Returns (acc, amax)."""
    if _BACKEND == "jnp":
        return ref.ring_combine(q, s, x, k)
    from repro.kernels import sync_update as _k
    return _k.ring_combine(q, s, x, k, interpret=(_BACKEND == "interpret"))


def ring_quantize_codes(acc, scale):
    """Send-side half of the per-hop requant pass: int8 codes of a ring
    partial mean under one guarded scalar scale."""
    if _BACKEND == "jnp":
        return ref.ring_quantize_codes(acc, scale)
    from repro.kernels import sync_update as _k
    return _k.ring_quantize(acc, scale, interpret=(_BACKEND == "interpret"))


def swiglu(x, wg, wi):
    """Fused silu(x@wg)*(x@wi) — the MLP hot spot."""
    if _BACKEND == "jnp":
        return ref.swiglu(x, wg, wi)
    from repro.kernels import swiglu as _k
    return _k.swiglu(x, wg, wi, interpret=(_BACKEND == "interpret"))
