"""Pure-jnp oracles for every Pallas kernel (and the CPU fast path).

These define the semantics the kernels must match bit-for-bit (up to fp
tolerance). Tests sweep shapes/dtypes and assert_allclose kernels vs these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.errors import ShapeError


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (scale.astype(jnp.float32))
    return out.astype(dtype)


def _mask(sq: int, sk: int, *, causal: bool, window: int, prefix_len: int,
          q_offset, k_positions=None) -> jax.Array:
    """Returns [sq,sk] — or [B,sq,sk] when q_offset is a per-batch array
    (ragged continuous-batching decode)."""
    q_offset = jnp.asarray(q_offset)
    if q_offset.ndim == 1:                      # per-batch offsets [B]
        q_offset = q_offset[:, None, None]
        lead = (q_offset.shape[0], sq, sk)
    else:
        lead = (sq, sk)
    q_idx = jnp.arange(sq)[:, None] + q_offset  # absolute position of queries
    if k_positions is not None:
        k_idx = k_positions[None, :]            # ring-buffer absolute positions
        valid = k_idx >= 0
    else:
        k_idx = jnp.arange(sk)[None, :]
        valid = jnp.ones((1, sk), bool)
    ok = jnp.broadcast_to(valid, lead)
    if causal:
        ok &= k_idx <= q_idx
    # `window` may be a traced per-layer value (scan xs); <=0 disables it.
    window = jnp.asarray(window)
    ok &= (window <= 0) | (k_idx > q_idx - window)
    if prefix_len:
        ok |= valid & (k_idx < prefix_len)  # bidirectional prefix (VLM prefix-LM)
    return ok


def attention(q, k, v, *, causal=True, window=0, prefix_len=0, q_offset=0,
              scale=None, k_positions=None):
    """q [B,Sq,Hq,D]; k,v [B,Sk,Hkv,D]; GQA via head-group broadcast."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    if hq % hkv != 0:
        raise ShapeError(f"GQA needs Hq % Hkv == 0, got ({hq}, {hkv})")
    g = hq // hkv
    dtype = q.dtype
    scale = scale if scale is not None else d ** -0.5
    if dtype == jnp.bfloat16:
        # bf16 MAC with f32 accumulation (MXU-native): avoids materializing
        # f32 copies of the (large) K/V tensors — bf16xbf16 products are
        # exact in f32, so this equals the upcast-first formulation.
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q.reshape(b, sq, hkv, g, d), k,
                       preferred_element_type=jnp.float32) * scale
        vf = v
    else:
        qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    m = _mask(sq, sk, causal=causal, window=window, prefix_len=prefix_len,
              q_offset=q_offset, k_positions=k_positions)
    if m.ndim == 3:   # per-batch mask [B,sq,sk] (ragged decode)
        s = jnp.where(m[:, None, None], s, -1e30)
    else:
        s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, sq, hq, d).astype(dtype)


def adamw_update(p, m, v, g, *, lr, beta1, beta2, eps, weight_decay, step):
    """AdamW with bias correction; moments fp32, params kept in input dtype."""
    gf = g.astype(jnp.float32)
    m1 = beta1 * m + (1.0 - beta1) * gf
    v1 = beta2 * v + (1.0 - beta2) * jnp.square(gf)
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    upd = (m1 / bc1) / (jnp.sqrt(v1 / bc2) + eps)
    pf = p.astype(jnp.float32)
    p1 = pf - lr * (upd + weight_decay * pf)
    return p1.astype(p.dtype), m1, v1


def sync_flat_update(p, anchor, *, scale=None, mu=None, momentum=0.0):
    """Fused flat-buffer sync update (core/sync.py flat path; one pass).

    p [W, N] worker replicas of one dtype bucket; anchor [N] params at the
    previous sync; scale [N] per-element (per-tensor, spread) int8 scales —
    None disables quantization; mu [N] fp32 outer-momentum buffer — used iff
    momentum > 0.  Returns (new_p [W, N], new_anchor [N], new_mu [N] | None).
    Elementwise math identical to the per-leaf tree path in core/sync.py, so
    the two layouts stay bitwise-equal (tests/test_flat.py).

    Quantized mean semantics (the RS-domain rule, core/sync.py): the worker
    mean runs over the integer *codes* q ∈ [-127, 127], not the dequantized
    values — Σq is exact in any summation order (integers < 2^24 are exact
    in f32), so the sharded layout's reduce_scatter of the codes is bitwise
    this kernel regardless of collective ordering or backend (gloo,
    in-process XLA, TPU ICI); dequantization happens once, after the mean.
    """
    d = p.astype(jnp.float32) - anchor.astype(jnp.float32)[None]
    if scale is not None:
        q = jnp.clip(jnp.round(d / scale[None] * 127.0), -127.0, 127.0)
        qmean = jnp.mean(q, axis=0)
        step = qmean * (scale / 127.0)
    else:
        step = jnp.mean(d, axis=0)
    new_mu = None
    if momentum > 0.0:
        new_mu = momentum * mu + step
        step = momentum * new_mu + step          # Nesterov
    new_anchor = (anchor.astype(jnp.float32) + step).astype(anchor.dtype)
    new_p = jnp.broadcast_to(new_anchor[None], p.shape).astype(p.dtype)
    return new_p, new_anchor, new_mu


def sync_apply_update(step_in, anchor, *, scale=None, mu=None, momentum=0.0):
    """Fused gather-leg apply: dequant + outer Nesterov + anchor update.

    step_in [N] f32 — the worker-mean integer codes qmean when `scale` is
    given (dequantized here: step = qmean * scale/127), else the worker-mean
    delta itself.  anchor [N]; mu [N] fp32 iff momentum > 0.  Returns
    (new_anchor [N], new_mu [N] | None).  The op sequence after the mean is
    exactly `sync_flat_update`'s, so blocking (fused one-pass) and overlap
    (begin/apply split) trajectories stay bitwise-equal at depth 0.
    """
    step = step_in * (scale / 127.0) if scale is not None else step_in
    new_mu = None
    if momentum > 0.0:
        new_mu = momentum * mu + step
        step = momentum * new_mu + step          # Nesterov
    new_anchor = (anchor.astype(jnp.float32) + step).astype(anchor.dtype)
    return new_anchor, new_mu


def ring_combine(q, s, x, k):
    """One receive hop of the re-quantizing int8 ring (core/sync.py
    `--wire ring-int8`).

    q [n] int8 codes of the incoming partial mean over k contributors, s ()
    the sender's (guarded) scalar scale, x [n] this worker's own chunk of
    the delta.  Folds the local contribution into the running MEAN —
    acc = (k * dequant(q, s) + x) / (k + 1) — whose magnitude never exceeds
    the largest contributor's, so int8 always holds the next hop's codes.
    Returns (acc [n] f32, amax ()) with amax = max|acc|, the statistic the
    next hop's fresh shard-local scale is guarded from.
    """
    deq = q.astype(jnp.float32) * (s / 127.0)
    acc = (jnp.float32(k) * deq + x.astype(jnp.float32)) / jnp.float32(k + 1)
    return acc, jnp.max(jnp.abs(acc))


def ring_quantize_codes(acc, scale):
    """int8 wire codes of a ring partial mean under ONE (guarded) scalar
    scale: clip(round(acc/scale*127)) ∈ [-127, 127], stored as int8 — the
    only payload dtype the ring ever puts on a wire.  Round-trip error is at
    most half a level (scale/254) per hop; tests/test_quantize_props.py
    bounds the K-hop accumulation."""
    return jnp.clip(jnp.round(acc / scale * 127.0),
                    -127.0, 127.0).astype(jnp.int8)


def swiglu(x, wg, wi):
    """silu(x @ wg) * (x @ wi) in fp32, cast back to x.dtype."""
    xf = x.astype(jnp.float32)
    g = xf @ wg.astype(jnp.float32)
    u = xf @ wi.astype(jnp.float32)
    return (jax.nn.silu(g) * u).astype(x.dtype)
