"""Fused SwiGLU activation — Pallas TPU kernel.

Computes silu(x @ wg) * (x @ wi) with one pass over x per output tile:
grid (rows, ff_cols); each program computes a [block_r, block_f] tile of
both gate and up projections on the MXU and fuses the silu/multiply —
the intermediate gate tensor never round-trips HBM.

Tiling: block_r=256 rows x block_f=512 ff-cols with the full d_model
contraction resident: x tile 256xD (D<=8192: 4 MiB bf16) + two weight
tiles Dx512 (8 MiB bf16) + fp32 tile accumulators — inside the ~16 MiB
VMEM budget; every matmul dim is a multiple of the 128-lane MXU width for
all assigned configs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swiglu_kernel(x_ref, wg_ref, wi_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    g = x @ wg_ref[...].astype(jnp.float32)
    u = x @ wi_ref[...].astype(jnp.float32)
    o_ref[...] = (g * jax.nn.sigmoid(g) * u).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_r", "block_f", "interpret"))
def swiglu(x: jax.Array, wg: jax.Array, wi: jax.Array, *, block_r: int = 256,
           block_f: int = 512, interpret: bool = False) -> jax.Array:
    """x [..., D]; wg, wi [D, F] -> silu(x@wg) * (x@wi), shape [..., F]."""
    d, f = wg.shape
    lead = x.shape[:-1]
    n = x.size // d
    x2 = x.reshape(n, d)
    br = min(block_r, n)
    while n % br:
        br -= 1
    bf = min(block_f, f)
    while f % bf:
        bf -= 1
    out = pl.pallas_call(
        _swiglu_kernel,
        grid=(n // br, f // bf),
        in_specs=[pl.BlockSpec((br, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((d, bf), lambda i, j: (0, j)),
                  pl.BlockSpec((d, bf), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((br, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), x.dtype),
        interpret=interpret,
    )(x2, wg, wi)
    return out.reshape(lead + (f,))
