"""Fused RMSNorm forward — Pallas TPU kernel.

Tiling: rows are blocked along the flattened batch/sequence dim; the full
feature dim stays resident in VMEM (d_model <= 8192 -> 8192*4B*block_rows
well under the ~16 MiB VMEM budget at block_rows=256).  Feature dim is
lane-aligned (multiples of 128) for all assigned configs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rms_norm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
             block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x [..., D]; scale [D]."""
    orig_shape = x.shape
    d = x.shape[-1]
    n = x.size // d
    x2 = x.reshape(n, d)
    br = min(block_rows, n)
    while n % br:
        br //= 2
    grid = (n // br,)
    out = pl.pallas_call(
        partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
