"""Pytree checkpointing (msgpack + raw little-endian buffers).

Round-trip-exact for any pytree of jnp arrays / numpy arrays / python
scalars.  Layout: <dir>/state.msgpack (+ step metadata); arrays stored as
{shape, dtype, data-bytes} — no pickle, stable across sessions.

Flat param layouts (core/flat.py) checkpoint their buffers directly: one
entry per dtype bucket instead of one per tensor, so a transformer's
checkpoint holds a handful of contiguous buffers rather than hundreds of
leaves.  `layout_meta` records the layout (and the sharded layout's chunk
count) in the small meta side file; `read_meta` recovers it without
unpacking the state payload, which is what lets the RoundEngine restore a
checkpoint across layouts (tree <-> flat <-> flat_sharded) by rebuilding
the matching spec first (core/engine.py `restore`).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _encode(obj):
    if isinstance(obj, (jax.Array, np.ndarray)):
        a = np.asarray(obj)
        return {b"__nd__": True, b"dtype": a.dtype.str, b"shape": list(a.shape),
                b"data": a.tobytes()}
    return obj


def _decode(obj):
    if isinstance(obj, dict) and (b"__nd__" in obj or "__nd__" in obj):
        g = lambda k: obj.get(k.encode()) if obj.get(k.encode()) is not None else obj.get(k)
        a = np.frombuffer(g("data"), dtype=np.dtype(g("dtype")))
        return a.reshape(g("shape")).copy()
    return obj


def stage(tree: Any) -> Any:
    """Device pytree -> host (numpy) pytree, one `jax.device_get` batch.

    The transfer point of the async observer pipeline (core/observer.py):
    the round loop submits device arrays and the worker thread stages them
    here, so neither the transfer nor the serialization below ever blocks
    training.  Passing already-host values through is a no-op copy."""
    leaves, treedef = jax.tree.flatten(tree)
    return jax.tree.unflatten(treedef, jax.device_get(leaves))


def save(path: str, tree: Any, *, step: int | None = None,
         extra: dict | None = None) -> None:
    """`extra` is free-form msgpack-serializable run metadata (e.g. the
    RoundEngine's H-trace) stored alongside the state."""
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    leaves = jax.device_get(leaves)   # one batch, no-op for host arrays
    payload = {
        "treedef": str(treedef),
        "step": step,
        "extra": extra or {},
        "leaves": [_encode(x) for x in leaves],
    }
    tmp = os.path.join(path, "state.msgpack.tmp")
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, os.path.join(path, "state.msgpack"))
    # small side file so read_meta() never has to unpack the state payload
    tmp = os.path.join(path, "meta.msgpack.tmp")
    with open(tmp, "wb") as f:
        f.write(msgpack.packb({"step": step, "extra": extra or {}},
                              use_bin_type=True))
    os.replace(tmp, os.path.join(path, "meta.msgpack"))


def layout_meta(layout: str, spec=None) -> dict:
    """Param-layout fields for a checkpoint's `extra` dict.

    For flat layouts the state's leaves ARE the dtype-bucket buffers; the
    bucket names/sizes (and the sharded layout's chunk count — a different
    shard count pads differently, so restore must rebuild the writer's
    spec) are what a reader needs to reinterpret or convert them."""
    out: dict = {"layout": layout}
    if spec is not None:
        out["buckets"] = {b: spec.sizes[b] for b in spec.buckets}
        shards = getattr(spec, "shards", None)
        if shards is not None:
            out["shards"] = shards
    return out


def restore(path: str, like: Any) -> tuple[Any, int | None]:
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    tree, step, _ = restore_with_meta(path, like)
    return tree, step


def restore_with_meta(path: str, like: Any) -> tuple[Any, int | None, dict]:
    """Like `restore`, plus the `extra` metadata dict — one file read."""
    with open(os.path.join(path, "state.msgpack"), "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    leaves_like, treedef = jax.tree.flatten(like)
    raw = [_decode(x) for x in payload["leaves"]]
    assert len(raw) == len(leaves_like), (len(raw), len(leaves_like))
    out = []
    for got, want in zip(raw, leaves_like):
        if isinstance(want, (jax.Array, np.ndarray, jnp.ndarray)):
            w = np.asarray(want)
            g = np.asarray(got)
            assert g.shape == w.shape, (g.shape, w.shape)
            out.append(jnp.asarray(g.astype(w.dtype)))
        else:
            out.append(got)
    return (jax.tree.unflatten(treedef, out), payload.get("step"),
            payload.get("extra") or {})


def read_meta(path: str) -> tuple[int | None, dict]:
    """(step, extra) from the small meta side file — e.g. to learn a
    checkpoint's param layout before building the matching `like` tree.
    Falls back to unpacking the full state payload for checkpoints written
    before the side file existed."""
    meta = os.path.join(path, "meta.msgpack")
    src = meta if os.path.exists(meta) else os.path.join(path,
                                                         "state.msgpack")
    with open(src, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    return payload.get("step"), payload.get("extra") or {}


def exists(path: str) -> bool:
    return os.path.exists(os.path.join(path, "state.msgpack"))
