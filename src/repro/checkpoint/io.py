"""Pytree checkpointing (msgpack + raw little-endian buffers).

Round-trip-exact for any pytree of jnp arrays / numpy arrays / python
scalars.  Layout: <dir>/state.msgpack (+ step metadata); arrays stored as
{shape, dtype, data-bytes} — no pickle, stable across sessions.

Flat param layouts (core/flat.py) checkpoint their buffers directly: one
entry per dtype bucket instead of one per tensor, so a transformer's
checkpoint holds a handful of contiguous buffers rather than hundreds of
leaves.  `layout_meta` records the layout (and the sharded layout's chunk
count) in the small meta side file; `read_meta` recovers it without
unpacking the state payload, which is what lets the RoundEngine restore a
checkpoint across layouts (tree <-> flat <-> flat_sharded) by rebuilding
the matching spec first (core/engine.py `restore`).

## Durability

Every file lands via tmp-write + fsync + `os.replace` + directory fsync
(`_write_atomic`): a host crash at ANY instant leaves either the previous
checkpoint or the new one, never a zero-length or torn "atomic" file (the
rename-without-fsync failure mode).  Readers raise `CheckpointError` — a
real exception, not an `assert`, because restore paths run under
`python -O` — on torn payloads, missing shards, or shape/length mismatch.

## Sharded manifest checkpoints (`save_sharded` / `restore_sharded`)

The multi-process form: each process writes ONLY its addressable shards to
its own `shards-<step>-<pid>.msgpack` (so checkpoint bandwidth scales with
process count and no process materializes the full state), and process 0
writes `manifest.msgpack` recording the treedef, per-leaf shapes/dtypes,
and the shard->file map.  The owner of a replicated shard is the lowest
process index holding it — computed from the global sharding, so every
process derives the identical manifest without communicating.  Restore
re-stitches the full state under ANY process count (each reader assembles
from all shard files, then lays the result onto its own mesh), and is
shard-for-shard bitwise vs the monolithic `save` of the same state
(tests/test_manifest_ckpt.py).  Step-stamped shard filenames + the atomic
manifest replace mean a writer killed mid-save leaves the previous
checkpoint fully readable.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint that cannot be restored as claimed: torn/truncated
    payload, missing shard coverage, or a shape/length mismatch against
    the `like` tree.  A real exception (not `assert`) so the guard
    survives `python -O` — the CI smoke leg runs restore under -O."""


def _dtype_tag(dt: np.dtype) -> str:
    # extension dtypes (bfloat16, float8_*) have a `.str` of a raw void
    # tag ("<V2") that np.dtype() round-trips to an uncastable void array;
    # their registered name round-trips correctly instead
    return dt.name if dt.kind == "V" else dt.str


def _encode(obj):
    if isinstance(obj, (jax.Array, np.ndarray)):
        a = np.asarray(obj)
        return {b"__nd__": True, b"dtype": _dtype_tag(a.dtype),
                b"shape": list(a.shape), b"data": a.tobytes()}
    return obj


def _decode(obj):
    if isinstance(obj, dict) and (b"__nd__" in obj or "__nd__" in obj):
        g = lambda k: obj.get(k.encode()) if obj.get(k.encode()) is not None else obj.get(k)
        a = np.frombuffer(g("data"), dtype=np.dtype(g("dtype")))
        return a.reshape(g("shape")).copy()
    return obj


def stage(tree: Any) -> Any:
    """Device pytree -> host (numpy) pytree, one `jax.device_get` batch.

    The transfer point of the async observer pipeline (core/observer.py):
    the round loop submits device arrays and the worker thread stages them
    here, so neither the transfer nor the serialization below ever blocks
    training.  Passing already-host values through is a no-op copy."""
    leaves, treedef = jax.tree.flatten(tree)
    return jax.tree.unflatten(treedef, jax.device_get(leaves))


def _write_atomic(path: str, name: str, data: bytes) -> None:
    """Crash-durable file publish: tmp write + fsync(file) + os.replace +
    fsync(directory).  Without the file fsync, a host crash after the
    rename can surface a zero-length "atomic" file (the rename outlives
    the data in the journal); without the directory fsync, the rename
    itself can be lost.  Either way the previous version, if any, stays
    intact."""
    tmp = os.path.join(path, name + ".tmp")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(path, name))
    dfd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def save(path: str, tree: Any, *, step: int | None = None,
         extra: dict | None = None) -> None:
    """`extra` is free-form msgpack-serializable run metadata (e.g. the
    RoundEngine's H-trace) stored alongside the state."""
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    leaves = jax.device_get(leaves)   # one batch, no-op for host arrays
    payload = {
        "treedef": str(treedef),
        "step": step,
        "extra": extra or {},
        "leaves": [_encode(x) for x in leaves],
    }
    _write_atomic(path, "state.msgpack", msgpack.packb(payload,
                                                       use_bin_type=True))
    # small side file so read_meta() never has to unpack the state payload
    _write_atomic(path, "meta.msgpack",
                  msgpack.packb({"step": step, "extra": extra or {}},
                                use_bin_type=True))


def layout_meta(layout: str, spec=None) -> dict:
    """Param-layout fields for a checkpoint's `extra` dict.

    For flat layouts the state's leaves ARE the dtype-bucket buffers; the
    bucket names/sizes (and the sharded layout's chunk count — a different
    shard count pads differently, so restore must rebuild the writer's
    spec) are what a reader needs to reinterpret or convert them."""
    out: dict = {"layout": layout}
    if spec is not None:
        out["buckets"] = {b: spec.sizes[b] for b in spec.buckets}
        shards = getattr(spec, "shards", None)
        if shards is not None:
            out["shards"] = shards
    return out


def restore(path: str, like: Any) -> tuple[Any, int | None]:
    """Restore into the structure of `like` (shapes/dtypes validated)."""
    tree, step, _ = restore_with_meta(path, like)
    return tree, step


def _read_payload(path: str, name: str) -> dict:
    """Unpack one checkpoint file, mapping a torn/truncated/corrupt payload
    to CheckpointError (msgpack raises half a dozen exception types on bad
    bytes; a crash mid-write plus a missing fsync is exactly how such a
    file appears on disk)."""
    fname = os.path.join(path, name)
    try:
        with open(fname, "rb") as f:
            payload = msgpack.unpackb(f.read(), raw=False,
                                      strict_map_key=False)
    except FileNotFoundError:
        raise
    except Exception as e:
        raise CheckpointError(f"torn or corrupt checkpoint file "
                              f"{fname}: {type(e).__name__}: {e}") from e
    if not isinstance(payload, dict):
        raise CheckpointError(f"torn or corrupt checkpoint file {fname}: "
                              f"payload is {type(payload).__name__}")
    return payload


def restore_with_meta(path: str, like: Any) -> tuple[Any, int | None, dict]:
    """Like `restore`, plus the `extra` metadata dict — one file read.

    Shape/length mismatches against `like` raise CheckpointError — a real
    error, not an `assert`, so the guard survives `python -O` (a stripped
    check would silently restore a mis-shaped state)."""
    payload = _read_payload(path, "state.msgpack")
    leaves_like, treedef = jax.tree.flatten(like)
    raw = [_decode(x) for x in payload.get("leaves") or []]
    if len(raw) != len(leaves_like):
        raise CheckpointError(
            f"checkpoint at {path} holds {len(raw)} leaves, the target "
            f"structure expects {len(leaves_like)}")
    out = []
    for got, want in zip(raw, leaves_like):
        if isinstance(want, (jax.Array, np.ndarray, jnp.ndarray)):
            w = np.asarray(want)
            g = np.asarray(got)
            if g.shape != w.shape:
                raise CheckpointError(
                    f"checkpoint leaf shape {g.shape} does not match the "
                    f"target shape {w.shape}")
            out.append(jnp.asarray(g.astype(w.dtype)))
        else:
            out.append(got)
    return (jax.tree.unflatten(treedef, out), payload.get("step"),
            payload.get("extra") or {})


def read_meta(path: str) -> tuple[int | None, dict]:
    """(step, extra) from the small meta side file — e.g. to learn a
    checkpoint's param layout before building the matching `like` tree.
    Falls back to unpacking the full state payload for checkpoints written
    before the side file existed."""
    meta = os.path.join(path, "meta.msgpack")
    src = meta if os.path.exists(meta) else os.path.join(path,
                                                         "state.msgpack")
    with open(src, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    return payload.get("step"), payload.get("extra") or {}


def try_read_meta(path: str) -> tuple[int | None, dict] | None:
    """`read_meta` for watch loops that race a writer: returns None instead
    of raising when the checkpoint is absent or mid-replace.  Because every
    file lands via `_write_atomic`, a readable meta file is always whole —
    the only transient states a poller can observe are "not there yet" and
    "previous version", both of which the next poll resolves."""
    try:
        return read_meta(path)
    except FileNotFoundError:
        return None
    except Exception:
        return None   # torn byte stream from a pre-atomic writer; retry


def exists(path: str) -> bool:
    return os.path.exists(os.path.join(path, "state.msgpack"))


# --------------------------------------------------------------------------
# Sharded manifest checkpoints (module docstring §Sharded manifest)
# --------------------------------------------------------------------------

def _norm_index(idx, shape) -> tuple:
    """A device's shard index (tuple of slices) as ((start, stop), ...) —
    hashable, msgpack-able, and resolved against the global shape."""
    return tuple(sl.indices(dim)[:2] for sl, dim in zip(idx, shape))


def _shard_owners(x: jax.Array) -> dict:
    """index -> owning process for every shard of a (possibly replicated)
    global array: the LOWEST process index holding a replica.  Derived
    from the global sharding, so every process computes the identical map
    without communicating — that is what lets each process write its shard
    file independently and process 0 name them all in the manifest."""
    owners: dict = {}
    for d, idx in x.sharding.devices_indices_map(x.shape).items():
        key = _norm_index(idx, x.shape)
        if key not in owners or d.process_index < owners[key]:
            owners[key] = d.process_index
    return owners


def _shard_fname(step, pid: int) -> str:
    # step-stamped so a writer killed mid-save never clobbers the shard
    # files the PREVIOUS manifest still names
    return f"shards-{int(step or 0):08d}-{pid:05d}.msgpack"


def save_sharded(path: str, tree: Any, *, step: int | None = None,
                 extra: dict | None = None, barrier=None) -> None:
    """Per-process shard-file checkpoint.  THIS process writes only the
    shards it owns (its addressable shards, minus replicas owned by a
    lower process) to its own file; process 0 then writes the manifest +
    meta side file.  `barrier` — a zero-arg callable, e.g. a cross-process
    sync — runs between the two, so the manifest never names a shard file
    that is not yet durable.  Single-process states (numpy or
    unsharded jax arrays) degenerate to one shard file holding
    everything.  All files land via `_write_atomic`."""
    pid, nproc = jax.process_index(), jax.process_count()
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    man_leaves: list = []           # per-leaf shape/dtype (or inline value)
    fmap: dict = {}                 # fname -> [[leaf_idx, index], ...]
    mine: list = []                 # this process's shard payload
    for li, x in enumerate(leaves):
        if isinstance(x, jax.Array):
            shape, dt = x.shape, np.dtype(x.dtype)
            man_leaves.append({"kind": "array", "shape": list(shape),
                               "dtype": _dtype_tag(dt)})
            local = {_norm_index(s.index, shape): s
                     for s in x.addressable_shards}
            for key, owner in sorted(_shard_owners(x).items()):
                ser = [list(se) for se in key]
                fmap.setdefault(_shard_fname(step, owner), []).append(
                    [li, ser])
                if owner == pid:
                    data = np.ascontiguousarray(
                        np.asarray(local[key].data))
                    mine.append([li, ser, data.tobytes()])
        elif isinstance(x, np.ndarray):
            man_leaves.append({"kind": "array", "shape": list(x.shape),
                               "dtype": _dtype_tag(x.dtype)})
            ser = [[0, n] for n in x.shape]
            fmap.setdefault(_shard_fname(step, 0), []).append([li, ser])
            if pid == 0:
                mine.append([li, ser,
                             np.ascontiguousarray(x).tobytes()])
        else:
            man_leaves.append({"kind": "value", "value": x})
    _write_atomic(path, _shard_fname(step, pid),
                  msgpack.packb({"entries": mine}, use_bin_type=True))
    if barrier is not None:
        barrier()
    if pid == 0:
        _write_atomic(path, "manifest.msgpack", msgpack.packb(
            {"treedef": str(treedef), "step": step, "extra": extra or {},
             "leaves": man_leaves, "files": fmap,
             "process_count": nproc}, use_bin_type=True))
        _write_atomic(path, "meta.msgpack",
                      msgpack.packb({"step": step, "extra": extra or {}},
                                    use_bin_type=True))
        # retire shard files no manifest names anymore (older steps)
        for f in os.listdir(path):
            if (f.startswith("shards-") and f.endswith(".msgpack")
                    and f not in fmap):
                os.unlink(os.path.join(path, f))


def restore_sharded(path: str, like: Any) -> tuple[Any, int | None, dict]:
    """Re-stitch a `save_sharded` checkpoint into the structure of `like`
    — under ANY process count: every reader assembles the full leaves from
    the manifest's shard->file map (a mesh engine then lays them onto its
    own devices).  Raises CheckpointError on a torn manifest/shard file,
    incomplete shard coverage, or a shape/length mismatch."""
    man = _read_payload(path, "manifest.msgpack")
    leaves_like, treedef = jax.tree.flatten(like)
    man_leaves = man.get("leaves") or []
    if len(man_leaves) != len(leaves_like):
        raise CheckpointError(
            f"manifest at {path} holds {len(man_leaves)} leaves, the "
            f"target structure expects {len(leaves_like)}")
    bufs: list = []
    filled = [0] * len(man_leaves)
    for ml, want in zip(man_leaves, leaves_like):
        if ml.get("kind") == "value":
            bufs.append(ml.get("value"))
            continue
        shape = tuple(ml["shape"])
        if isinstance(want, (jax.Array, np.ndarray, jnp.ndarray)):
            w = np.asarray(want)
            if shape != w.shape:
                raise CheckpointError(
                    f"manifest leaf shape {shape} does not match the "
                    f"target shape {w.shape}")
        bufs.append(np.empty(shape, np.dtype(ml["dtype"])))
    for fname in sorted(man.get("files") or {}):
        try:
            shard = _read_payload(path, fname)
        except FileNotFoundError as e:
            raise CheckpointError(
                f"manifest at {path} names a missing shard file "
                f"{fname}") from e
        for li, ser, data in shard.get("entries") or []:
            buf = bufs[li]
            piece = np.frombuffer(data, dtype=buf.dtype).reshape(
                [e - s for s, e in ser])
            buf[tuple(slice(s, e) for s, e in ser)] = piece
            filled[li] += piece.size
    for li, (ml, buf) in enumerate(zip(man_leaves, bufs)):
        if ml.get("kind") != "value" and filled[li] != buf.size:
            raise CheckpointError(
                f"leaf {li}: shard files cover {filled[li]} of "
                f"{buf.size} elements — missing or torn shard file")
    out = []
    for buf, want in zip(bufs, leaves_like):
        if isinstance(want, (jax.Array, np.ndarray, jnp.ndarray)):
            out.append(jnp.asarray(buf.astype(np.asarray(want).dtype)))
        else:
            out.append(buf)
    return (jax.tree.unflatten(treedef, out), man.get("step"),
            man.get("extra") or {})


def is_manifest(path: str) -> bool:
    return os.path.exists(os.path.join(path, "manifest.msgpack"))


def read_manifest_meta(path: str) -> tuple[int | None, dict]:
    """(step, extra) for a manifest checkpoint — from the meta side file
    when present (process 0 writes it with the manifest), else the
    manifest itself."""
    if os.path.exists(os.path.join(path, "meta.msgpack")):
        return read_meta(path)
    man = _read_payload(path, "manifest.msgpack")
    return man.get("step"), man.get("extra") or {}
