import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Roofline extraction for one (arch x shape x mesh).

Two-phase measurement (see EXPERIMENTS.md §Dry-run methodology):
  1. FULL-config compile (scan mode, fast): proves the program lowers +
     compiles on the production mesh and yields memory_analysis().
  2. CALIBRATION compiles: the same program at two reduced depths with every
     scan unrolled (exact HLO costs), fit cost(L)=a*L+b, extrapolate to full
     depth.  Training decomposes into local_step + sync (+ parallel_step
     baseline), which exposes QSR's  coll(step) = local + sync/H  scaling.

Writes one JSON record per invocation:
  PYTHONPATH=src python -m repro.launch.roofline_run --arch X --shape Y \
      [--multi-pod] --out experiments/dryrun/X__Y__MESH.json
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import RunConfig
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import (SHAPES, build_calib_case, build_case,
                                 calib_sizes, with_depth)

_METRICS = ("flops", "bytes_accessed", "collective_bytes_total",
            "dci_bytes")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _compile_case(case, mesh):
    t0 = time.time()
    with mesh:
        jitted = jax.jit(case.fn, in_shardings=case.in_shardings,
                         out_shardings=case.out_shardings)
        compiled = jitted.lower(*case.args).compile()
    stats = hlo_analysis.summarize(compiled, n_devices=mesh.devices.size)
    stats["compile_s"] = round(time.time() - t0, 1)
    return stats


def _flat_metrics(stats):
    out = {m: stats[m] for m in _METRICS}
    for k in _COLL_KINDS:
        out[f"coll:{k}"] = stats["collective_bytes"][k]
    return out


def _extrapolate(m1, m2, l1, l2, lf):
    out = {}
    for k in m1:
        slope = (m2[k] - m1[k]) / (l2 - l1)
        out[k] = max(0.0, slope * lf + (m1[k] - slope * l1))
    return out


def _calibrate(cfg, shape, mesh, policy, run_cfg, fn_kind):
    l1, l2, lf = calib_sizes(cfg)
    os.environ["REPRO_DRYRUN_UNROLL"] = "1"
    try:
        s1 = _compile_case(build_calib_case(with_depth(cfg, l1), shape, mesh,
                                            policy=policy, run_cfg=run_cfg,
                                            fn_kind=fn_kind), mesh)
        s2 = _compile_case(build_calib_case(with_depth(cfg, l2), shape, mesh,
                                            policy=policy, run_cfg=run_cfg,
                                            fn_kind=fn_kind), mesh)
    finally:
        os.environ["REPRO_DRYRUN_UNROLL"] = "0"
    # extrapolate in units of l1 layers (one pattern block / hybrid group)
    ext = _extrapolate(_flat_metrics(s1), _flat_metrics(s2),
                       1.0, l2 / l1, lf / l1)
    ext["calib_compile_s"] = s1["compile_s"] + s2["compile_s"]
    return ext


def run_pair(arch, shape_name, *, multi_pod, policy=None, run_cfg=None,
             calibrate=True, **run_kw):
    from repro.configs import registry as R

    policy = policy or R.get_policy(arch)
    run_cfg = run_cfg or RunConfig(sharding=policy, **run_kw)
    cfg = R.get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    if getattr(run_cfg, "moe_dispatch", "auto") == "shard_map":
        from repro.models import moe as _moe
        _moe.set_dispatch("shard_map", mesh)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "policy": policy,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": mesh.devices.size}

    # ---- phase 1: full-config lowering proof + memory ----
    os.environ["REPRO_DRYRUN_UNROLL"] = "0"
    full = build_case(arch, shape_name, mesh, policy=policy, run_cfg=run_cfg)
    stats = _compile_case(full, mesh)
    rec["full"] = {"fn": full.meta["fn_name"], "compile_s": stats["compile_s"],
                   "per_device_memory": stats["per_device_memory"],
                   "raw_once_per_loop": _flat_metrics(stats),
                   **{k: full.meta.get(k) for k in
                      ("w", "b_loc", "h", "ring", "kv_len")}}

    if not calibrate:
        return rec

    # ---- phase 2: calibrated exact per-step costs ----
    if shape.mode == "train":
        rec["local_step"] = _calibrate(cfg, shape_name, mesh, policy, run_cfg,
                                       "local_step")
        rec["sync"] = _calibrate(cfg, shape_name, mesh, policy, run_cfg,
                                 "sync")
        rec["parallel_step"] = _calibrate(cfg, shape_name, mesh, policy,
                                          run_cfg, "parallel_step")
    else:
        kind = "prefill" if shape.mode == "prefill" else "decode"
        rec[kind] = _calibrate(cfg, shape_name, mesh, policy, run_cfg, kind)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default=None)
    ap.add_argument("--no-calibrate", action="store_true")
    ap.add_argument("--h", type=int, default=None)
    ap.add_argument("--cache-layout", default="batch",
                    choices=["batch", "seq_model"])
    ap.add_argument("--remat", default="1")
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "save_collectives", "dots"])
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--moe-shards", type=int, default=1)
    ap.add_argument("--moe-dispatch", default="auto",
                    choices=["auto", "shard_map"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    try:
        rec = run_pair(args.arch, args.shape, multi_pod=args.multi_pod,
                       policy=args.policy, calibrate=not args.no_calibrate,
                       cache_layout=args.cache_layout,
                       remat=bool(int(args.remat)),
                       remat_policy=args.remat_policy,
                       seq_shard_activations=args.seq_shard,
                       moe_dispatch_shards=args.moe_shards,
                       moe_dispatch=args.moe_dispatch,
                       microbatch=args.microbatch)
        rec["variant"] = {"cache_layout": args.cache_layout,
                          "remat": bool(int(args.remat)),
                          "remat_policy": args.remat_policy,
                          "seq_shard": args.seq_shard,
                          "moe_shards": args.moe_shards,
                          "moe_dispatch": args.moe_dispatch,
                          "microbatch": args.microbatch}
        rec["ok"] = True
    except Exception as e:
        traceback.print_exc()
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "ok": False, "error": repr(e)}
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)
    print(json.dumps({k: v for k, v in rec.items()
                      if k in ("arch", "shape", "mesh", "ok", "error")}))


if __name__ == "__main__":
    main()
