"""Production meshes for TPU v5e.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run pins the host-device count *before* any jax
initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 4, n_model: int = 2, *, pods: int = 0):
    """Small host-device mesh for tests (requires matching
    xla_force_host_platform_device_count)."""
    if pods:
        return jax.make_mesh((pods, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
