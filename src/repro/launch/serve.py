"""Serving driver: batched prefill + decode against any architecture.

CPU-runnable at smoke scale; the same prefill/decode_step programs are what
the dry-run lowers at decode_32k / long_500k shapes.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, param as pm


def generate(cfg, params, prompts: jax.Array, *, gen_len: int,
             max_len: int | None = None, window_override: int = 0,
             temperature: float = 0.0, seed: int = 0, extra: dict | None = None):
    """prompts [B, P] int32 -> tokens [B, P+gen_len]."""
    mod = api.get_module(cfg)
    b, plen = prompts.shape
    max_len = max_len or (plen + gen_len)
    cache = mod.init_cache(cfg, b, max_len, dtype=jnp.float32,
                           window_override=window_override)
    kv_len = None
    for k in ("k", "attn_k"):
        if isinstance(cache, dict) and k in cache:
            kv_len = cache[k].shape[2]
    ring = window_override > 0 and kv_len is not None and kv_len < max_len

    prefix_len = cfg.n_img_tokens if cfg.family == "vlm" else 0
    extra = extra or {}
    logits, cache = mod.prefill(cfg, params, prompts, cache, **extra)

    decode = jax.jit(
        lambda p, tok, c, pos: mod.decode_step(cfg, p, tok, c, pos,
                                               prefix_len=prefix_len,
                                               ring=ring))
    out = [prompts]
    rng = jax.random.PRNGKey(seed)
    tok = None
    for i in range(gen_len):
        if temperature > 0:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        out.append(tok[:, None])
        pos = jnp.asarray(plen + prefix_len + i, jnp.int32)
        logits, cache = decode(params, tok, cache, pos)
    return jnp.concatenate(out, axis=1)


def main():
    from repro.launch import multihost
    multihost.initialize()  # no-op unless REPRO_COORDINATOR is set
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--window", type=int, default=0,
                    help="ring-buffer KV window (long-context serving)")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from repro.configs import registry as R
    cfg = R.get_smoke_config(args.arch) if args.smoke else R.get_config(args.arch)
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0),
                            jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    extra = {}
    if cfg.family == "vlm":
        extra["prefix_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_img_tokens, cfg.d_model))
    if cfg.family == "audio":
        extra["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (args.batch, cfg.enc_seq, cfg.d_model))

    t0 = time.time()
    toks = generate(cfg, params, prompts, gen_len=args.gen,
                    window_override=args.window,
                    temperature=args.temperature, extra=extra)
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("sample:", np.asarray(toks[0])[:args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
