"""Serving driver: batched prefill + decode against any architecture.

Two modes:

  * one-shot batched `generate` (the decode-shape dry-run unit) —
      PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
          --batch 4 --prompt-len 32 --gen 16
  * the continuous-batching service loop with hot weight swap
    (`--slots N`): requests flow through launch/batching.py, weights are
    `ServingWeights` flat buckets, and `--watch DIR` subscribes to
    checkpoints a trainer publishes there (launch/weights.py).  `--swap-demo`
    publishes fresh weights mid-decode and `--audit` writes the swap-epoch
    audit trail — per-token checkpoint attribution — as JSON:
      PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
          --slots 2 --batch 3 --gen 8 --swap-demo --audit swap_audit.json

CPU-runnable at smoke scale; the same prefill/decode_step programs are what
the dry-run lowers at decode_32k / long_500k shapes.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, param as pm


def generate(cfg, params, prompts: jax.Array, *, gen_len: int,
             max_len: int | None = None, window_override: int = 0,
             temperature: float = 0.0, seed: int = 0, extra: dict | None = None):
    """prompts [B, P] int32 -> tokens [B, P+gen_len].

    Sampling (temperature > 0) splits one stream per decode step over the
    whole batch: deterministic under a fixed (seed, batch shape), but unlike
    the ContinuousBatcher's per-request streams, a row's samples depend on
    its batch index.
    """
    mod = api.get_module(cfg)
    b, plen = prompts.shape
    # the bidirectional prefix (VLM image tokens) occupies cache positions
    # before the prompt, so it must count toward the default cache length —
    # without it decode positions overrun the cache and JAX's clamping
    # dynamic_update_slice silently corrupts the last rows
    prefix_len = cfg.n_img_tokens if cfg.family == "vlm" else 0
    max_len = max_len or (plen + prefix_len + gen_len)
    cache = mod.init_cache(cfg, b, max_len, dtype=jnp.float32,
                           window_override=window_override)
    kv_len = None
    for k in ("k", "attn_k"):
        if isinstance(cache, dict) and k in cache:
            kv_len = cache[k].shape[2]
    ring = window_override > 0 and kv_len is not None and kv_len < max_len
    if not ring and kv_len is not None and plen + prefix_len + gen_len > kv_len:
        raise ValueError(
            f"prompt ({plen}) + prefix ({prefix_len}) + gen_len ({gen_len}) "
            f"= {plen + prefix_len + gen_len} tokens exceed the KV cache "
            f"length {kv_len}; raise max_len or serve with a ring window")

    extra = extra or {}
    logits, cache = mod.prefill(cfg, params, prompts, cache, **extra)

    decode = jax.jit(
        lambda p, tok, c, pos: mod.decode_step(cfg, p, tok, c, pos,
                                               prefix_len=prefix_len,
                                               ring=ring))
    out = [prompts]
    rng = jax.random.PRNGKey(seed)
    tok = None
    for i in range(gen_len):
        if temperature > 0:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        out.append(tok[:, None])
        pos = jnp.asarray(plen + prefix_len + i, jnp.int32)
        logits, cache = decode(params, tok, cache, pos)
    return jnp.concatenate(out, axis=1)


def run_service(cfg, weights, prompts, *, slots: int, max_new: int,
                max_len: int | None = None, temperature: float = 0.0,
                seed: int = 0, subscriber=None, hooks=(),
                max_steps: int = 100_000):
    """Drive the continuous-batching service loop to completion.

    prompts: list of [P] int32 arrays, one request each.  hooks: iterable of
    (step_index, fn(batcher)) one-shot callbacks fired after that many
    decode steps — the CLI's --swap-demo uses one to publish new weights
    mid-decode.  Returns (requests, audit dict)."""
    from repro.launch.batching import ContinuousBatcher, Request
    max_len = max_len or (max(len(p) for p in prompts) + max_new)
    batcher = ContinuousBatcher(cfg, weights, slots=slots, max_len=max_len,
                                temperature=temperature, seed=seed,
                                subscriber=subscriber)
    reqs = [Request(rid=i, prompt=np.asarray(p, np.int32), max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        batcher.submit(r)
    pending = sorted(hooks, key=lambda h: h[0])
    steps = 0
    while steps < max_steps:
        n = batcher.step()
        steps += 1
        while pending and pending[0][0] <= steps:
            pending.pop(0)[1](batcher)
        if n == 0 and not batcher.queue and not pending:
            break
    audit = {
        "arch": cfg.name,
        "family": cfg.family,
        "slots": slots,
        "decode_steps": steps,
        "tokens_emitted": batcher.tokens_emitted,
        "swaps": batcher.swaps,
        "swap_epochs": batcher.weights.audit(),
        "requests": [{"rid": r.rid, "prompt_len": len(r.prompt),
                      "tokens": len(r.out), "epochs": r.epochs}
                     for r in reqs],
    }
    return reqs, audit


def main():
    from repro.launch import multihost
    multihost.initialize()  # no-op unless REPRO_COORDINATOR is set
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--window", type=int, default=0,
                    help="ring-buffer KV window (long-context serving)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=0,
                    help=">0: continuous-batching service loop with this "
                         "many decode slots (hot-swap capable)")
    ap.add_argument("--watch", default=None,
                    help="poll this dir for published serving checkpoints "
                         "and hot-swap them between decode steps")
    ap.add_argument("--audit", default=None,
                    help="write the swap-epoch audit JSON here")
    ap.add_argument("--swap-demo", action="store_true",
                    help="publish fresh weights mid-decode and hot-swap "
                         "them (exercises the full subscriber path)")
    args = ap.parse_args()

    from repro.configs import registry as R
    cfg = R.get_smoke_config(args.arch) if args.smoke else R.get_config(args.arch)
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(0),
                            jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)

    if args.slots > 0:
        _service_main(cfg, mod, params, prompts, args)
        return

    extra = {}
    if cfg.family == "vlm":
        extra["prefix_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_img_tokens, cfg.d_model))
    if cfg.family == "audio":
        extra["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (args.batch, cfg.enc_seq, cfg.d_model))

    t0 = time.time()
    toks = generate(cfg, params, prompts, gen_len=args.gen,
                    window_override=args.window,
                    temperature=args.temperature, seed=args.seed, extra=extra)
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch*args.gen/dt:.1f} tok/s)")
    print("sample:", np.asarray(toks[0])[:args.prompt_len + 8].tolist())


def _service_main(cfg, mod, params, prompts, args):
    """The --slots service-loop entry: hot-swap-capable continuous batching."""
    import tempfile
    from repro.launch import weights as W

    if cfg.family in ("vlm", "audio", "vision"):
        raise SystemExit(f"--slots serves decoder families; {cfg.family} "
                         "prompts need per-request extras the batcher does "
                         "not carry yet")
    weights = W.ServingWeights(cfg, params, step=0, source="init")
    sub = None
    watch = args.watch
    if watch or args.swap_demo:
        watch = watch or tempfile.mkdtemp(prefix="repro-serve-watch-")
        sub = W.WeightSubscriber(watch_dir=watch, like=W.params_like(cfg))
    hooks = []
    if args.swap_demo:
        fresh = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(17),
                               jnp.float32)
        # fire after the first requests have cleared slot-local prefill and
        # emitted a few tokens, so the swap lands mid-sequence and the audit
        # shows tokens on both sides of it
        trigger = args.prompt_len + max(2, args.gen // 2)
        hooks.append((trigger, lambda b: W.publish_weights(
            watch, fresh, step=1, extra={"demo": True})))

    t0 = time.time()
    reqs, audit = run_service(
        cfg, weights, [np.asarray(p) for p in prompts], slots=args.slots,
        max_new=args.gen, temperature=args.temperature, seed=args.seed,
        subscriber=sub, hooks=hooks)
    dt = time.time() - t0
    audit["wall_seconds"] = dt
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s) with {args.slots} slots; "
          f"swaps={audit['swaps']}")
    if args.swap_demo and audit["swaps"] < 1:
        raise SystemExit("--swap-demo: no swap happened (requests finished "
                         "before the publish hook fired)")
    for r in reqs[:2]:
        print(f"  rid={r.rid} tokens={r.out[:8]}... epochs={r.epochs[:8]}...")
    if args.audit:
        with open(args.audit, "w") as f:
            json.dump(audit, f, indent=2)
        print(f"swap-epoch audit -> {args.audit}")


if __name__ == "__main__":
    main()
