import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "8")).strip()
# ^ MUST run before any other import: jax locks the device count on first init.

"""Collective autotuner for the every-H-steps sync + the CI perf trajectory.

For one (mesh, policy) this module enumerates candidate sync *plans* —
    wire   ∈ {f32 (unquantized), int-codes (exact Σq in wire_dtype(W)),
              ring-int8 (re-quantizing ppermute ring)}
    sync   ∈ {blocking, overlap depth 1, overlap depth 2}
— and scores each on three measured axes:

  * bytes_on_wire — parsed from the optimized HLO of the lowered sync
    (launch/hlo_analysis), per wire: what one sync actually puts on the
    interconnect, including the payload dtype split that proves the ring is
    s8-only.
  * drift — the plan's sync EXECUTED for `drift_rounds` against the exact
    unquantized host mean on identical worker noise: max |param diff| at the
    end.  Measured, never assumed (the ring's per-hop requantization bound
    `ring_tolerance` disqualifies a plan that exceeds it).  Runs in a
    watchdog subprocess (`measure_drift_guarded`): XLA's in-process CPU
    collective rendezvous can rarely deadlock on an oversubscribed host, so
    a hung measurement is killed and retried instead of hanging the tuner.
  * s_per_round — full RoundEngine rounds (local steps + sync) timed on the
    mesh, the wall-clock axis that catches a plan whose byte win costs too
    many kernel launches.

The chosen plan minimizes (bytes_on_wire, s_per_round) lexicographically
among plans whose drift passes — bytes are what scale to the production
interconnect, wall-clock breaks ties between plans that move the same bytes
(e.g. ring+blocking vs ring+overlap).

The emitted record (BENCH_sync.json, schema "bench_sync/v1", README §Perf
trajectory) is the repo's perf trajectory point; `--append FILE` collects
points from several (mesh, policy) legs of one CI run into a single
trajectory file (schema "bench_sync_trajectory/v1": {"points": [rec, ...]})
— the CI `bench` job appends the dp 4x2 and fsdp 2x2x2 pod-mesh points.
`--baseline` gates a run against the committed
benchmarks/bench_sync_baseline.json:

  * bytes_on_wire of the chosen plan must not grow,
  * the chosen plan's s/round RATIO to the in-run f32+blocking reference
    must not regress more than --regress-frac (default 10%) vs the
    baseline's ratio — a ratio so a slower CI machine cannot fail the gate,
  * the ring's bytes reduction vs the exact int-codes wire must stay >= 2x
    (the acceptance floor).

Run as a module (subprocess-safe: the device pin above precedes jax init):

  PYTHONPATH=src python -m repro.launch.autotune --mesh 4x2 --policy dp \
      --out BENCH_sync.json --baseline benchmarks/bench_sync_baseline.json
"""
import argparse
import json
import sys
import time

import jax

from repro.configs.base import RunConfig
from repro.launch import hlo_analysis
from repro.launch.mesh import make_debug_mesh
from repro.launch.shapes import build_calib_case

SCHEMA = "bench_sync/v1"

# (name, quantize, sync_wire) — every candidate wire for the sync payload
WIRES = (("f32", False, "auto"),
         ("int-codes", True, "auto"),
         ("ring-int8", True, "ring-int8"))
# joint overlap-depth enumeration: depth 0 IS blocking (bitwise), deeper
# depths trade staleness for hidden gather time — the same frontier the
# adaptive controller (core/controller.py) rides at run time
SYNCS = (("blocking", 0), ("overlap", 1), ("overlap", 2))
TRAJECTORY_SCHEMA = "bench_sync_trajectory/v1"


def _wire_dtype_name(wire_name: str, w: int) -> str:
    from repro.core.sync import wire_dtype
    if wire_name == "f32":
        return "float32"
    if wire_name == "ring-int8":
        return "int8"
    return str(jax.numpy.dtype(wire_dtype(w)))


def _mesh_tuple(mesh: str):
    dims = [int(x) for x in mesh.split("x")]
    return ([0] + dims if len(dims) == 2 else dims)


def lower_wire(cfg, run_cfg, mesh, policy: str) -> dict:
    """Compile the flat_sharded sync for one wire and read the wire truth
    off the optimized HLO: total bytes, per-dtype payload split, op counts.
    Same payload/scale classification as launch/sync_compare."""
    case = build_calib_case(cfg, "train_4k", mesh, policy=policy,
                            run_cfg=run_cfg, fn_kind="sync",
                            layout="flat_sharded")
    with mesh:
        compiled = jax.jit(case.fn, in_shardings=case.in_shardings,
                           out_shardings=case.out_shardings
                           ).lower(*case.args).compile()
    hlo = compiled.as_text()
    counts = hlo_analysis.collective_counts(hlo)
    nbytes = hlo_analysis.collective_bytes(hlo)
    fold_limit = 4 * case.meta["n_leaves"] + 64
    payload = [op for op in hlo_analysis.collective_ops(hlo)
               if op["bytes_full"] > fold_limit]
    by_dtype = {}
    for op in payload:
        by_dtype[op["dtype"]] = by_dtype.get(op["dtype"], 0) + op["bytes_full"]
    return {
        "bytes_on_wire": sum(v for k, v in nbytes.items() if k != "dci"),
        "payload_bytes_by_dtype": by_dtype,
        "collective_counts": {k: v for k, v in counts.items() if v},
        "n_buckets": case.meta["n_buckets"],
    }


def measure_drift(cfg, run_cfg, mesh, policy: str, *, rounds: int = 3,
                  seed: int = 7) -> dict:
    """EXECUTE the plan's sync on the mesh for `rounds` and report the end
    divergence from the exact unquantized host worker-mean on identical
    noise — the measured cost of the wire compression.  Returns
    {drift, tol, within_tol}; tol is `ring_tolerance` of the observed noise
    amax (the analytic bound the ring must beat; exact wires get the f32
    mean-reassociation allowance instead)."""
    import numpy as np

    from repro.core import flat as F, local_update as LU
    from repro.core.sync import make_sync, ring_tolerance
    from repro.models import api, param as pm

    w = pm.worker_count(policy, mesh)
    waxes = pm.worker_mesh_axes(policy, mesh)
    saxes = tuple(a for a in mesh.axis_names if a not in waxes)
    sizes = pm.mesh_axis_sizes(mesh)
    shards = int(np.prod([sizes[a] for a in waxes + saxes]))

    params = pm.init_params(api.get_module(cfg).param_defs(cfg),
                            jax.random.PRNGKey(0))
    base = LU.init_state(cfg, run_cfg, params, w)
    base.pop("opt")
    rng = np.random.RandomState(seed)
    noises = [jax.tree.map(lambda x: (rng.randn(w, *np.shape(x)) * 0.01
                                      ).astype(np.float32), params)
              for _ in range(rounds)]

    def run(rc, with_mesh: bool):
        from jax.sharding import NamedSharding
        spec = (F.ShardedFlatSpace(params, shards, mesh=mesh,
                                   worker_axes=waxes, shard_axes=saxes)
                if with_mesh else F.ShardedFlatSpace(params, shards))
        st = {k: (spec.flatten(v, lead=1) if k == "params"
                  else spec.flatten(v))
              for k, v in base.items()
              if k == "params" or rc.sync_quantize or rc.outer_momentum > 0.0}
        if with_mesh:
            sspec = F.flat_state_specs(rc, waxes, spec)
            st = {k: {b: jax.device_put(v[b],
                                        NamedSharding(mesh, sspec[k][b]))
                      for b in v} for k, v in st.items()}
        sync = jax.jit(make_sync(rc, spec=spec))
        for noise in noises:
            nb = spec.flatten(noise, lead=1)
            st = dict(st, params={b: st["params"][b] + nb[b].astype(
                st["params"][b].dtype) for b in nb})
            if with_mesh:
                # drain the dispatch queue around the collective program: a
                # sync needs all n_devices executions in flight at once, and
                # the rendezvous is least likely to starve when they are the
                # only work pending.  This narrows the race but cannot close
                # it — measure_drift_guarded's watchdog is the actual guard.
                jax.block_until_ready(st)
            with mesh:
                st = sync(st)
            if with_mesh:
                jax.block_until_ready(st)
        return {k: (spec.unflatten(v, lead=1) if k == "params"
                    else spec.unflatten(v)) for k, v in st.items()}

    exact = run(RunConfig(sharding=policy), with_mesh=False)
    got = run(run_cfg, with_mesh=True)
    drift = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                    - np.asarray(b, np.float32))))
                if np.size(np.asarray(a)) else 0.0
                for a, b in zip(jax.tree.leaves(got["params"]),
                                jax.tree.leaves(exact["params"])))
    amax_d = max(float(np.max(np.abs(l)))
                 for noise in noises for l in jax.tree.leaves(noise))
    tol = ring_tolerance(w, amax_d, rounds)
    return {"drift": drift, "tol": tol, "within_tol": drift <= tol,
            "rounds": rounds}


def measure_drift_guarded(wname: str, *, arch: str, mesh: str, policy: str,
                          smoke: bool = True, rounds: int = 3,
                          timeout: float = 300.0, attempts: int = 3) -> dict:
    """measure_drift in a watchdog subprocess (`--drift-worker` mode).

    XLA's in-process CPU collective rendezvous can — rarely, and
    scheduling-dependently — deadlock when n_devices simulated devices
    contend for few cores: one participant's execution thread never gets
    scheduled while every other rank waits forever at the rendezvous.  The
    race cannot be closed from client code, so the guard is containment:
    run the measurement in a fresh process, kill it past `timeout`, retry.
    A healthy measurement takes well under a minute at smoke scale."""
    import subprocess
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.autotune",
           "--drift-worker", wname, "--arch", arch, "--mesh", mesh,
           "--policy", policy, "--drift-rounds", str(rounds)]
    if not smoke:
        cmd.append("--full")
    last = ""
    for attempt in range(1, attempts + 1):
        try:
            out = subprocess.run(cmd, capture_output=True, text=True,
                                 timeout=timeout, env=env)
        except subprocess.TimeoutExpired:
            last = f"attempt {attempt}: hung past {timeout:.0f}s (killed)"
            print(f"[autotune] drift worker {last}; retrying",
                  file=sys.stderr)
            continue
        if out.returncode == 0:
            return json.loads(out.stdout)
        last = f"attempt {attempt}: rc={out.returncode}: {out.stderr[-2000:]}"
        print(f"[autotune] drift worker failed; retrying\n{last}",
              file=sys.stderr)
    raise RuntimeError(
        f"drift measurement for wire={wname} failed after {attempts} "
        f"attempts: {last}")


def time_plan(cfg, run_cfg, mesh, policy: str, *, sync: str, depth: int,
              b_loc: int = 2, seq: int = 32, warmup: int = 1,
              rounds: int = 3, seed: int = 0) -> dict:
    """Wall-clock full engine rounds (h local steps + the plan's sync) on
    the mesh — the timing harness benchmarks/table4_walltime.py uses, with
    the state living on the real device mesh."""
    from repro.core import schedules
    from repro.core.engine import RoundEngine
    from repro.models import param as pm
    from repro.optim.lr import make_lr_fn

    w = pm.worker_count(policy, mesh)
    eng = RoundEngine(cfg, run_cfg, workers=w, b_loc=b_loc, seq=seq,
                      seed=seed, data="device", layout="flat_sharded",
                      sync=sync, overlap_depth=depth, mesh=mesh,
                      policy=policy)
    lr_fn = make_lr_fn(run_cfg)
    state = eng.init_state()
    t = 0
    # warmup compiles every round-program variant incl. the flush/apply, so
    # the timed window holds only steady-state rounds (table4_walltime's
    # protocol)
    for _ in range(warmup):
        h = schedules.get_h(run_cfg, t, lr_fn)
        state, _ = eng.run_round(state, t, h, lr_fn)
        t += h
    state = eng.flush(state)
    jax.block_until_ready(jax.tree.leaves(state))
    t0 = time.perf_counter()
    for _ in range(rounds):
        h = schedules.get_h(run_cfg, t, lr_fn)
        state, _ = eng.run_round(state, t, h, lr_fn)
        t += h
    jax.block_until_ready(jax.tree.leaves(state))
    dt = time.perf_counter() - t0
    eng.flush(state)
    return {"s_per_round": dt / rounds, "rounds": rounds,
            "h": run_cfg.h_base}


def autotune(arch: str = "starcoder2-3b", *, mesh: str = "4x2",
             policy: str = "dp", smoke: bool = True, drift_rounds: int = 3,
             time_rounds: int = 3, skip_timing: bool = False,
             verbose: bool = True) -> dict:
    """Enumerate, measure, choose.  Returns the BENCH_sync record."""
    from repro.configs import registry as R
    from repro.models import param as pm

    cfg = R.get_smoke_config(arch) if smoke else R.get_config(arch)
    pods, n_data, n_model = _mesh_tuple(mesh)
    jmesh = make_debug_mesh(n_data, n_model, pods=pods)
    w = pm.worker_count(policy, jmesh)

    def rc(quantize, wire, h=4, steps=10 ** 6):
        return RunConfig(sharding=policy, sync_quantize=quantize,
                         sync_wire=wire, schedule="constant", h_base=h,
                         total_steps=steps, remat=False)

    log = (lambda *a: print(*a, file=sys.stderr)) if verbose else \
        (lambda *a: None)
    wires, plans = {}, []
    for wname, quantize, swire in WIRES:
        log(f"[autotune] lowering wire={wname}")
        wrec = lower_wire(cfg, rc(quantize, swire), jmesh, policy)
        log(f"[autotune] drift wire={wname}")
        wrec["drift"] = measure_drift_guarded(wname, arch=arch, mesh=mesh,
                                              policy=policy, smoke=smoke,
                                              rounds=drift_rounds)
        wrec["wire_dtype"] = _wire_dtype_name(wname, w)
        wires[wname] = wrec
        for sync, depth in SYNCS:
            plan = {"plan": f"{wname}+{sync}{depth}", "wire": wname,
                    "sync": sync, "overlap_depth": depth,
                    "quantize": quantize, "sync_wire": swire,
                    "wire_dtype": wrec["wire_dtype"],
                    "bytes_on_wire": wrec["bytes_on_wire"],
                    "payload_bytes_by_dtype": wrec["payload_bytes_by_dtype"],
                    "drift": wrec["drift"]["drift"],
                    "drift_tol": wrec["drift"]["tol"],
                    "drift_ok": wrec["drift"]["within_tol"]}
            if not skip_timing:
                log(f"[autotune] timing plan={plan['plan']}")
                plan.update(time_plan(cfg, rc(quantize, swire), jmesh,
                                      policy, sync=sync, depth=depth,
                                      rounds=time_rounds))
            plans.append(plan)

    eligible = [p for p in plans if p["drift_ok"]]
    key = lambda p: (p["bytes_on_wire"], p.get("s_per_round", 0.0))
    chosen = min(eligible or plans, key=key)
    ref = next(p for p in plans if p["plan"] == "f32+blocking0")
    rec = {
        "schema": SCHEMA, "arch": arch, "smoke": smoke, "mesh": mesh,
        "policy": policy, "layout": "flat_sharded", "workers": w,
        "n_devices": jmesh.devices.size,
        "plans": plans,
        "wires": {k: {kk: vv for kk, vv in v.items() if kk != "drift"}
                  for k, v in wires.items()},
        "chosen": chosen["plan"],
        "chosen_bytes_on_wire": chosen["bytes_on_wire"],
        "chosen_drift": chosen["drift"],
        "reference_plan": ref["plan"],
        "ring_vs_auto_bytes_ratio": (
            wires["int-codes"]["bytes_on_wire"]
            / max(wires["ring-int8"]["bytes_on_wire"], 1)),
    }
    if not skip_timing:
        rec["chosen_s_per_round"] = chosen["s_per_round"]
        rec["speed_ratio_chosen_vs_reference"] = (
            chosen["s_per_round"] / ref["s_per_round"])
    return rec


def gate(rec: dict, baseline: dict, *, regress_frac: float = 0.10) -> list:
    """Compare a fresh trajectory point against the committed baseline.
    Returns the list of violations (empty = pass).  Speed gates on the
    chosen/reference RATIO, never absolute seconds — CI machines vary;
    their ratio between two plans timed in the same run does not."""
    fails = []
    if rec["chosen_bytes_on_wire"] > baseline["chosen_bytes_on_wire"]:
        fails.append(
            f"bytes-on-wire grew: {rec['chosen_bytes_on_wire']} > baseline "
            f"{baseline['chosen_bytes_on_wire']}")
    if rec["ring_vs_auto_bytes_ratio"] < 2.0:
        fails.append(
            "ring byte reduction fell below the 2x acceptance floor: "
            f"{rec['ring_vs_auto_bytes_ratio']:.2f}x")
    r, b = (rec.get("speed_ratio_chosen_vs_reference"),
            baseline.get("speed_ratio_chosen_vs_reference"))
    if r is not None and b is not None and r > b * (1.0 + regress_frac):
        fails.append(
            f"s/round ratio regressed >{regress_frac:.0%}: {r:.3f} vs "
            f"baseline {b:.3f} (chosen plan vs in-run f32+blocking)")
    if not rec["plans"]:
        fails.append("no plans measured")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--full", action="store_true",
                    help="production config (default: smoke, CPU-runnable)")
    ap.add_argument("--mesh", default="4x2",
                    help="debug mesh data x model or pod x data x model")
    ap.add_argument("--policy", default="dp", choices=["dp", "fsdp"])
    ap.add_argument("--drift-rounds", type=int, default=3)
    ap.add_argument("--time-rounds", type=int, default=3)
    ap.add_argument("--skip-timing", action="store_true",
                    help="lowering + drift only (fast smoke of the "
                         "enumeration; the record then carries no s/round "
                         "and the speed gate is skipped)")
    ap.add_argument("--out", default=None,
                    help="write the BENCH_sync.json record here")
    ap.add_argument("--append", default=None,
                    help="append this run's record as a point to a "
                         "trajectory file (schema bench_sync_trajectory/v1; "
                         "created if missing, a bare bench_sync/v1 record "
                         "is promoted to a one-point trajectory)")
    ap.add_argument("--baseline", default=None,
                    help="gate this run against a committed baseline "
                         "record; non-zero exit on violation")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the fresh record over --baseline instead "
                         "of gating")
    ap.add_argument("--regress-frac", type=float, default=0.10)
    # internal: measure_drift_guarded's watchdog child — measure one wire's
    # drift and print the JSON record on stdout
    ap.add_argument("--drift-worker", default=None, choices=[w[0]
                    for w in WIRES], help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.drift_worker:
        from repro.configs import registry as R
        _, quantize, swire = next(x for x in WIRES
                                  if x[0] == args.drift_worker)
        cfg = (R.get_config(args.arch) if args.full
               else R.get_smoke_config(args.arch))
        pods, n_data, n_model = _mesh_tuple(args.mesh)
        jmesh = make_debug_mesh(n_data, n_model, pods=pods)
        run_cfg = RunConfig(sharding=args.policy, sync_quantize=quantize,
                            sync_wire=swire, schedule="constant", h_base=4,
                            total_steps=10 ** 6, remat=False)
        print(json.dumps(measure_drift(cfg, run_cfg, jmesh, args.policy,
                                       rounds=args.drift_rounds)))
        return

    rec = autotune(args.arch, mesh=args.mesh, policy=args.policy,
                   smoke=not args.full, drift_rounds=args.drift_rounds,
                   time_rounds=args.time_rounds,
                   skip_timing=args.skip_timing)
    text = json.dumps(rec, indent=1)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    if args.append:
        traj = {"schema": TRAJECTORY_SCHEMA, "points": []}
        if os.path.exists(args.append):
            with open(args.append) as f:
                prev = json.load(f)
            if prev.get("schema") == TRAJECTORY_SCHEMA:
                traj = prev
            elif prev.get("schema") == SCHEMA:
                traj["points"].append(prev)
        traj["points"].append(rec)
        with open(args.append, "w") as f:
            json.dump(traj, f, indent=1)
        print(f"trajectory: {len(traj['points'])} points -> {args.append}",
              file=sys.stderr)
    print(text)
    if args.baseline and args.update_baseline:
        with open(args.baseline, "w") as f:
            f.write(text)
        print(f"baseline updated: {args.baseline}", file=sys.stderr)
    elif args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        fails = gate(rec, base, regress_frac=args.regress_frac)
        for msg in fails:
            print(f"GATE FAIL: {msg}", file=sys.stderr)
        if fails:
            raise SystemExit(1)
        print("perf gate: PASS (vs baseline "
              f"{base.get('chosen', '?')}, bytes "
              f"{base.get('chosen_bytes_on_wire', '?')})", file=sys.stderr)


if __name__ == "__main__":
    main()
