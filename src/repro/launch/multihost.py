"""Multi-host process bootstrap + a REAL multi-process execution path.

Two jobs:

1. Production bootstrap (TPU pods).  On real TPU v5e, each host owns 4
   chips; a 16x16 pod is 64 hosts and the 2-pod job is 128.  `initialize()`
   wires `jax.distributed`, then `make_production_mesh()` (launch/mesh.py)
   builds the global mesh over `jax.devices()` exactly as the dry-run does
   over placeholder devices — the same `train_round` / `serve_step` programs
   run unchanged.

2. CPU multi-process execution (the thing this module can actually *run*
   anywhere): `run()` executes the sharded sync — and full RoundEngine
   rounds — across N real `jax.distributed` CPU processes with gloo
   collectives.  Every process holds 1/N of the devices of the same global
   mesh the single-process debug runs use; the explicit reduce_scatter /
   all_gather legs of the flat_sharded sync (core/sync.py) then cross true
   process boundaries.  Quantized sync is asserted BITWISE against the
   process-local host path: the worker mean runs over integer codes, so no
   collective ordering — in-process XLA or gloo — can change a bit.  The
   pytest harness (tests/test_multihost.py) spawns the processes and
   additionally checks the multi-process digests against a single-process
   8-simulated-device run of this same module.

   `--wire ring-int8` swaps the one-shot reduce_scatter for the W-hop
   re-quantizing int8 ppermute ring (core/sync.py §ring).  The ring is
   deliberately beyond-exact: per-hop requantization makes the mesh path
   differ from the host reference (and, at the engine's overlap seam, XLA's
   refusion across the program boundary can flip a requant code), so ring
   runs are asserted within `ring_tolerance` — never bitwise.  The shard
   hashes stay exact across PROCESS SPLITS though: the ring has no
   cross-device reductions at all (each hop's arithmetic is device-local and
   ppermute moves int8 bytes verbatim), so a 1-process and an N-process run
   of the same mesh still hash identically shard for shard.

3. Elastic fault tolerance (README §Elastic training): `--chaos` drives a
   fault-injection controller across worker GENERATIONS.  `jax.distributed`
   cannot resize a live process group — a dead gloo member deadlocks every
   collective — so each worker set is one OS-process generation (one engine
   MembershipEpoch), and the manifest checkpoint (checkpoint/io.py
   save_sharded) is the currency between generations.  Inside a generation,
   workers run `--sync partial` engine rounds in lockstep, exchanging
   heartbeat files at every round boundary BEFORE entering the round's
   collectives; a worker that died cannot announce, so the survivors detect
   the loss with a bounded timeout instead of deadlocking, exit with a
   membership verdict (rc 3), and the controller respawns the surviving
   lanes from the last round-boundary manifest:

     --chaos kill:worker=2,round=1   kill 1 of 4 mid-run; survivors redo
                                     the round on the reduced mesh, proven
                                     BITWISE (integer-code domain) against
                                     a single-process 3-worker reference
     --chaos preempt-restore         ...then rejoin the worker from the
                                     manifest checkpoint (restore under a
                                     different process count; the rejoined
                                     lane re-anchors to consensus) and
                                     prove the 4-worker continuation
                                     bitwise the same way

Spawn it yourself (the multihost CPU runbook, README §Multihost):

  PYTHONPATH=src python -m repro.launch.multihost \
      --spawn 2 --total-devices 8 --mesh 2x2x2 --policy fsdp --quantize

Worker environment (set by --spawn, scripts/launch_v5e_pod.sh, or you):
  REPRO_COORDINATOR   host:port of process 0
  REPRO_NUM_PROCESSES total process count
  REPRO_PROCESS_ID    this process's index

NOTE: jax is imported lazily everywhere in this module so `main()` can pin
the per-process simulated-device count (XLA_FLAGS) before jax initializes.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import re
import socket
import subprocess
import sys
import tempfile
import time

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))


class TopologyError(RuntimeError):
    """The device topology does not match the requested production mesh."""


def initialize(*, retries: int = 3, backoff: float = 0.5) -> bool:
    """Wire `jax.distributed` from the REPRO_* environment; no-op (returns
    False) when REPRO_COORDINATOR is unset (single-process dev / dry-run).
    On the CPU backend, cross-process collectives need the gloo
    implementation — selected here; the option is scoped to the CPU client,
    so setting it is harmless on TPU.

    Bounded retry + exponential backoff: the coordinator bind races with
    spawn order (a worker can dial before process 0 is listening, or the
    probed port can be lost to another server between probe and bind), and
    both surface as an initialize() failure that a short backoff resolves.
    After `retries` failures the last error propagates — an elastic
    controller treats that worker as never having joined the epoch."""
    coord = os.environ.get("REPRO_COORDINATOR")
    if not coord:
        return False
    import jax
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass  # option absent/renamed in this jax: rely on its default
    last = None
    for attempt in range(max(1, retries)):
        try:
            jax.distributed.initialize(
                coordinator_address=coord,
                num_processes=int(os.environ["REPRO_NUM_PROCESSES"]),
                process_id=int(os.environ["REPRO_PROCESS_ID"]),
            )
            return True
        except Exception as e:   # noqa: BLE001 — retrying the whole wire-up
            last = e
            if attempt + 1 < retries:
                time.sleep(backoff * (2 ** attempt))
    raise last


def runtime_info() -> dict:
    import jax
    return {
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
        "local_devices": len(jax.local_devices()),
        "global_devices": len(jax.devices()),
        "platform": jax.devices()[0].platform,
    }


def assert_production_topology(*, multi_pod: bool) -> None:
    """Raise TopologyError unless the device count matches the production
    mesh.  A real exception, not `assert`: launch scripts run under
    `python -O`, which strips asserts — a silently wrong topology would
    train on a misshapen mesh."""
    import jax
    want = 512 if multi_pod else 256
    got = len(jax.devices())
    if got != want:
        raise TopologyError(
            f"expected {want} chips for the "
            f"{'2x16x16' if multi_pod else '16x16'} mesh, found {got}")


# --------------------------------------------------------------------------
# The executable path: sharded sync / engine rounds across real processes
# --------------------------------------------------------------------------

def _parse_mesh(mesh: str):
    dims = tuple(int(x) for x in mesh.split("x"))
    axes = ("pod", "data", "model") if len(dims) == 3 else ("data", "model")
    return dims, axes


def _demo_params(seed: int = 0):
    """A small mixed-dtype params pytree for the sync harness: two dtype
    buckets, sizes chosen so the W*S chunking actually pads."""
    import jax.numpy as jnp
    import numpy as np
    rng = np.random.RandomState(seed)
    mk = lambda *s: jnp.asarray(rng.randn(*s).astype(np.float32))
    return {
        "w_in": mk(13, 24), "w_attn": mk(24, 24), "bias": mk(17),
        "w_out": mk(24, 13), "gate": mk(3, 5, 7),
        "h_bf16": mk(9, 11).astype(jnp.bfloat16),
        "e_bf16": mk(21).astype(jnp.bfloat16),
    }


def _digest(arrays) -> str:
    import numpy as np
    h = hashlib.sha256()
    for a in arrays:
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()


def _shard_hashes(tag: str, arr) -> dict:
    """{f"{tag}|{global index}": sha1(bytes)} over this process's shards —
    the cross-run comparison unit: a 1-process and an N-process run of the
    same program must produce identical hashes shard for shard."""
    import numpy as np
    out = {}
    for s in arr.addressable_shards:
        key = f"{tag}|{[(sl.start, sl.stop) for sl in s.index]}"
        out[key] = hashlib.sha1(
            np.ascontiguousarray(np.asarray(s.data)).tobytes()).hexdigest()
    return out


def run_sync(*, mesh: str = "2x2x2", policy: str = "fsdp",
             quantize: bool = True, momentum: float = 0.0,
             overlap: bool = False, rounds: int = 3, seed: int = 0,
             wire: str = "auto", membership: str = "") -> dict:
    """Execute `rounds` sharded syncs on the global mesh — across however
    many processes own its devices — and assert every addressable shard
    bitwise-equal to the process-local host-path reference (the mesh-less
    flat sync every test in tests/ anchors to).

    Each round perturbs worker params with seeded host noise (identical on
    every process) and syncs.  With `overlap`, the reduce (begin) is issued
    at the round boundary and the gather (apply) deferred to the next round
    — the RS leg's pending int16 code-sums then live across a program
    boundary, exactly the engine's `--sync overlap` seam.

    Bitwise holds for any mesh when `quantize` (integer-code mean) and for
    2-worker meshes unquantized (a single f32 addition has one order);
    callers pick configurations accordingly (tests/test_multihost.py).
    wire="ring-int8" relaxes the contract: the mesh ring and the host ring
    fold identical math through different XLA programs, so requant codes can
    flip — shards must land within `ring_tolerance` of the reference
    instead (the module docstring's beyond-exact semantics).

    `membership` ("1,1,0,1") switches both paths to the PARTIAL sync
    (core/sync.py §Partial participation): the mesh psum runs over all W
    lanes but masked deltas are zeroed pre-quantizer and the mean divides
    by |P| — asserted bitwise against the host partial reference, and
    (quantized) against a W'=|P| run over just the participant rows: the
    integer-code-domain exactness the elastic path rests on.  Partial
    composes with neither overlap (the pending would cross a membership
    boundary) nor the ring wire (W is baked into every hop)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import RunConfig
    from repro.core import flat as F
    from repro.core.sync import (make_sync, make_sync_apply, make_sync_begin,
                                 make_sync_partial, ring_tolerance)
    from repro.models import param as pm

    dims, axes = _parse_mesh(mesh)
    jmesh = jax.make_mesh(dims, axes)
    if membership and (overlap or wire == "ring-int8"):
        raise ValueError("--membership composes with neither --overlap nor "
                         "the ring wire (run_sync docstring)")
    run_cfg = RunConfig(sharding=policy, sync_quantize=quantize,
                        outer_momentum=momentum, sync_wire=wire)
    w = pm.worker_count(policy, jmesh)
    waxes = pm.worker_mesh_axes(policy, jmesh)
    saxes = tuple(a for a in jmesh.axis_names if a not in waxes)
    sizes = pm.mesh_axis_sizes(jmesh)
    shards = int(np.prod([sizes[a] for a in waxes + saxes]))

    params = _demo_params(seed)
    spec_m = F.ShardedFlatSpace(params, shards, mesh=jmesh,
                                worker_axes=waxes, shard_axes=saxes)
    spec_h = F.ShardedFlatSpace(params, shards)

    stacked = {k: jnp.broadcast_to(v[None], (w,) + v.shape)
               for k, v in params.items()}
    base = {"params": spec_h.flatten(stacked, lead=1)}
    if quantize or momentum > 0.0:
        base["anchor"] = spec_h.flatten(params)
    if momentum > 0.0:
        base["outer_mu"] = {b: jnp.zeros(spec_h.buffer_size(b), jnp.float32)
                            for b in spec_h.buckets}

    sspec = F.flat_state_specs(run_cfg, waxes, spec_m)
    put = lambda x, ps: F.make_global(x, jmesh, ps)

    st_m = {k: {b: put(v[b], sspec[k][b]) for b in v}
            for k, v in base.items()}
    st_h = dict(base)

    rng = np.random.RandomState(seed + 1)
    noises = [{k: (rng.randn(w, *v.shape) * 0.01).astype(np.float32)
               for k, v in params.items()} for _ in range(rounds)]

    def steps(state, spec, noise_bufs_put):
        return dict(state, params={
            b: state["params"][b] + noise_bufs_put[b].astype(
                state["params"][b].dtype)
            for b in state["params"]})

    mask = (np.asarray([float(x) for x in membership.split(",")], np.float32)
            if membership else None)
    if mask is not None and mask.shape != (w,):
        raise ValueError(f"--membership needs {w} entries, got {membership!r}")

    if overlap:
        begin_m = jax.jit(make_sync_begin(run_cfg, spec_m))
        apply_m = jax.jit(make_sync_apply(run_cfg, spec_m))
        begin_h = jax.jit(make_sync_begin(run_cfg, spec_h))
        apply_h = jax.jit(make_sync_apply(run_cfg, spec_h))
    elif mask is not None:
        part_m = jax.jit(make_sync_partial(run_cfg, spec_m))
        part_h = jax.jit(make_sync_partial(run_cfg, spec_h))
        sync_m = lambda st: part_m(st, jnp.asarray(mask))
        sync_h = lambda st: part_h(st, jnp.asarray(mask))
    else:
        sync_m = jax.jit(make_sync(run_cfg, spec_m))
        sync_h = jax.jit(make_sync(run_cfg, spec_h))

    pend_m = pend_h = None
    for noise in noises:
        nb = spec_h.flatten(
            {k: jnp.asarray(v) for k, v in noise.items()}, lead=1)
        nb_put = {b: put(nb[b], sspec["params"][b]) for b in nb}
        if overlap:
            if pend_m is not None:
                st_m = apply_m(st_m, pend_m)
                st_h = apply_h(st_h, pend_h)
            st_m, st_h = steps(st_m, spec_m, nb_put), steps(st_h, spec_h, nb)
            pend_m, pend_h = begin_m(st_m), begin_h(st_h)
        else:
            st_m, st_h = steps(st_m, spec_m, nb_put), steps(st_h, spec_h, nb)
            st_m, st_h = sync_m(st_m), sync_h(st_h)
    if overlap and pend_m is not None:
        st_m, st_h = apply_m(st_m, pend_m), apply_h(st_h, pend_h)

    # partial + quantized: the consensus must ALSO equal a W'=|P| run over
    # just the participant rows — Σ_{i∈P} q_i / |P| is the same integer sum
    # whether the absent lanes contribute zero codes or don't exist (the
    # integer-code-domain exactness claim; f32 sums reassociate, so the
    # unquantized form is covered by the mesh==host assert above only)
    participant_exact = None
    if mask is not None and quantize:
        rows = [i for i in range(w) if mask[i]]
        wp = len(rows)
        spec_p = F.ShardedFlatSpace(_demo_params(seed), wp)
        stacked_p = {k: jnp.stack([v] * wp) for k, v in params.items()}
        st_p = {"params": spec_p.flatten(stacked_p, lead=1),
                "anchor": spec_p.flatten(params)}
        if momentum > 0.0:
            st_p["outer_mu"] = {b: jnp.zeros(spec_p.buffer_size(b),
                                             jnp.float32)
                                for b in spec_p.buckets}
        part_p = jax.jit(make_sync_partial(run_cfg, spec_p))
        ones = jnp.ones(wp, jnp.float32)
        for noise in noises:
            nz = {k: jnp.asarray(v[rows]) for k, v in noise.items()}
            nb = spec_p.flatten(nz, lead=1)
            st_p = dict(st_p, params={
                b: st_p["params"][b] + nb[b].astype(st_p["params"][b].dtype)
                for b in st_p["params"]})
            st_p = part_p(st_p, ones)
        full = spec_h.unflatten(st_h["params"], lead=1)
        part = spec_p.unflatten(st_p["params"], lead=1)
        participant_exact = all(
            bool(jnp.all(full[k][0] == part[k][0])) for k in full)

    # every addressable shard of the distributed state must equal the
    # corresponding slice of the (fully-replicated) host reference.  For the
    # ring wire the comparison is tolerance-based AFTER a per-element cast
    # allowance |ref|*eps(dtype)*rounds: each round's anchor cast can put
    # the two paths one output-dtype quantum apart (a straddled bf16
    # rounding boundary), and that divergence re-enters the next round's
    # delta — up to one quantum PER ROUND on bf16 buckets.
    max_diff, excess, hashes = 0.0, 0.0, {}
    for k in sorted(st_h):
        for b in sorted(st_h[k]):
            ref = np.asarray(st_h[k][b], np.float32)
            eps = (2.0 ** -7 if "bfloat16" in str(st_h[k][b].dtype)
                   else 2.0 ** -23) * rounds
            for s in st_m[k][b].addressable_shards:
                got = np.asarray(s.data, np.float32)
                if got.size:
                    d = np.abs(got - ref[s.index])
                    max_diff = max(max_diff, float(np.max(d)))
                    excess = max(excess, float(
                        np.max(d - np.abs(ref[s.index]) * eps)))
            hashes.update(_shard_hashes(f"{k}/{b}", st_m[k][b]))

    info = runtime_info()
    if wire == "ring-int8":
        # every round's delta-from-anchor is exactly that round's noise
        # (post-sync params == anchor), so the noise amax bounds the ring's
        # per-round requantization error
        amax_d = max(float(np.max(np.abs(v)))
                     for nz in noises for v in nz.values())
        tol = ring_tolerance(w, amax_d, rounds)
        ok = excess <= tol
    else:
        tol = 0.0
        ok = max_diff == 0.0 and participant_exact is not False
    # the digest is over the host reference — meaningful ONLY because the
    # shard assertions above tie the distributed state to it (bitwise, or
    # within ring_tolerance for the ring wire), so gate it on `ok`: a broken
    # distributed path can never produce a matching digest
    digest = (_digest([st_h[k][b] for k in sorted(st_h)
                       for b in sorted(st_h[k])])
              if ok else f"MISMATCH:{max_diff:.3e}")
    return {
        "mode": "sync", "ok": ok, "max_abs_diff": max_diff,
        "digest": digest,
        "shard_hashes": hashes,
        "mesh": mesh, "policy": policy, "workers": w, "shards": shards,
        "quantize": quantize, "momentum": momentum, "overlap": overlap,
        "membership": membership, "participant_exact": participant_exact,
        "rounds": rounds, "wire": wire, "ring_tol": tol,
        "wire_dtype": ("int8" if wire == "ring-int8" else
                       "int16" if quantize and w * 127 < 2 ** 15 else
                       "int32" if quantize else "float32"),
        **info,
    }


def run_engine(*, mesh: str = "2x2x2", policy: str = "fsdp",
               quantize: bool = True, momentum: float = 0.0,
               rounds: int = 2, seed: int = 0,
               arch: str = "starcoder2-3b", sync: str = "blocking",
               overlap_depth: int = 0, wire: str = "auto") -> dict:
    """Execute full RoundEngine communication rounds (local steps + sharded
    sync) on the global mesh, across real process boundaries: the engine is
    built exactly as single-process — same config, same mesh axes — with
    `mesh=` handed through so init lays global arrays onto it.

    Cross-process invariant: the round program is SPMD, so every process
    must observe the identical replicated loss scalar, and a 1-process run
    of the same mesh produces bitwise-identical state shards when the sync
    is quantized (the only cross-worker reduction in a dp/fsdp round whose
    result feeds back into the state; integer codes make it
    order-independent).

    sync="overlap": the round programs thread the pending reduce across
    their boundaries (engine `--sync overlap` — `make_sync_begin` at each
    round's end, the gather/apply inside the next program), with the
    pending's worker-sharded payload living on the distributed devices
    between programs.  A blocking engine runs the same trajectory alongside
    as the in-process reference; at depth 0 the flushed overlap state must
    match it BITWISE, shard for shard, on any mesh/process split (identical
    op sequence, deterministic collectives — tests/test_sharded.py proves
    the host edition).  Depth > 0 is the correction form: finite and close,
    reported but not asserted bitwise.

    wire="ring-int8" weakens the depth-0 contract to tolerance: splitting
    begin/apply across the program boundary changes how XLA fuses the ring's
    f32 hop arithmetic, and a reassociated rounding can flip a requant code
    — one quantization level, bounded per round by `ring_tolerance` of the
    (h·lr)-bounded local-step delta."""
    import jax
    import numpy as np

    from repro.configs import registry as R
    from repro.configs.base import RunConfig
    from repro.core import schedules
    from repro.core.engine import RoundEngine
    from repro.core.sync import ring_tolerance
    from repro.optim.lr import make_lr_fn
    from repro.models import param as pm

    dims, axes = _parse_mesh(mesh)
    jmesh = jax.make_mesh(dims, axes)
    cfg = R.get_smoke_config(arch)
    run_cfg = RunConfig(schedule="qsr", optimizer="adamw",
                        total_steps=2 * rounds, peak_lr=3e-3, end_lr=1e-6,
                        warmup_steps=1, h_base=2, alpha=0.001, remat=False,
                        weight_decay=0.01, sync_quantize=quantize,
                        outer_momentum=momentum, sharding=policy,
                        sync_wire=wire)
    w = pm.worker_count(policy, jmesh)
    mk = lambda s, d: RoundEngine(cfg, run_cfg, workers=w, b_loc=2, seq=16,
                                  seed=seed, data="device",
                                  layout="flat_sharded", sync=s,
                                  overlap_depth=d, mesh=jmesh, policy=policy)
    eng = mk(sync, overlap_depth)
    ref = mk("blocking", 0) if sync == "overlap" else None
    lr_fn = make_lr_fn(run_cfg)
    state = eng.init_state()
    ref_state = ref.init_state() if ref else None
    losses, ref_losses = [], []
    tol = 0.0
    for t, h in schedules.rounds(run_cfg, lr_fn):
        state, m = eng.run_round(state, t, h, lr_fn)
        losses.append(float(m["loss"]))
        if wire == "ring-int8":
            # per-round delta amax bound: h AdamW steps of normalized-update
            # magnitude <= ~lr each, x4 headroom for bias-corrected early
            # steps + weight decay — feeds the per-round requant error bound
            tol += ring_tolerance(w, 4.0 * h * run_cfg.peak_lr, 1)
        if ref:
            ref_state, mr = ref.run_round(ref_state, t, h, lr_fn)
            ref_losses.append(float(mr["loss"]))
    state = eng.flush(state)

    def hash_state(st, tag=""):
        out = {}
        for k in ("params", "anchor"):
            if k in st:
                for b, arr in st[k].items():
                    out.update(_shard_hashes(f"{tag}{k}/{b}", arr))
        return out

    hashes = hash_state(state)
    ok = all(np.isfinite(losses))
    rec = {}
    if ref:
        max_diff, excess = 0.0, 0.0
        for k in ("params", "anchor"):
            if k in state:
                for b in state[k]:
                    eps = (2.0 ** -7 if "bfloat16" in str(state[k][b].dtype)
                           else 2.0 ** -23) * max(len(losses), 1)
                    for s, r in zip(state[k][b].addressable_shards,
                                    ref_state[k][b].addressable_shards):
                        a = np.asarray(s.data, np.float32)
                        bb = np.asarray(r.data, np.float32)
                        if a.size:
                            d = np.abs(a - bb)
                            max_diff = max(max_diff, float(np.max(d)))
                            # ring: allow one output-dtype quantum PER ROUND
                            # (straddled rounding boundaries re-enter the
                            # next round's delta) before testing the bound
                            excess = max(excess, float(
                                np.max(d - np.abs(bb) * eps)))
        matches = (excess <= tol if wire == "ring-int8"
                   else max_diff == 0.0)
        if overlap_depth == 0:
            ok = ok and matches
        rec = {"blocking_losses": ref_losses,
               "overlap_matches_blocking": matches,
               "max_abs_diff_vs_blocking": max_diff,
               "wire_tolerance": tol}
    info = runtime_info()
    return {
        "mode": "engine", "ok": ok, "losses": losses,
        "shard_hashes": hashes, "mesh": mesh, "policy": policy, "workers": w,
        "quantize": quantize, "momentum": momentum, "rounds": len(losses),
        "sync": sync, "overlap_depth": overlap_depth, "wire": wire,
        "arch": arch, **rec, **info,
    }


def probe() -> dict:
    """Cheapest possible cross-process collective: one psum over all
    devices.  tests/test_multihost.py runs this first and skips gracefully
    when the distributed CPU backend is unavailable."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    n = len(jax.devices())
    jmesh = jax.make_mesh((n,), ("x",))
    host = np.arange(n, dtype=np.float32)
    arr = jax.make_array_from_callback(
        (n,), NamedSharding(jmesh, P("x")), lambda idx: host[idx])
    total = float(jax.jit(jnp.sum)(arr))
    return {"mode": "probe", "ok": total == n * (n - 1) / 2,
            "devices": n, **runtime_info()}


# --------------------------------------------------------------------------
# Elastic fault tolerance (module docstring §3, README §Elastic training)
# --------------------------------------------------------------------------

class Heartbeat:
    """File-based liveness detector for lockstep round workers.

    Entering round r, every worker `announce(r)`s a heartbeat file, then
    `await_peers(r)` polls for all peers' files under a bounded timeout.
    A dead worker cannot announce, so the survivors learn of the loss
    BEFORE entering the round's collectives — the only safe moment: one
    dead gloo member deadlocks every collective, and there is no timeout
    inside them.  Workers are in lockstep (the previous round ended in a
    collective barrier), so a missing heartbeat after `timeout` means
    dead-or-hopelessly-straggling either way; the verdict is the same —
    leave the epoch and let the controller respawn the survivors."""

    def __init__(self, path: str, pid: int, nprocs: int, *,
                 timeout: float = 30.0, poll: float = 0.05):
        self.path, self.pid, self.n = path, pid, nprocs
        self.timeout, self.poll = timeout, poll
        os.makedirs(path, exist_ok=True)

    def _f(self, rnd: int, pid: int) -> str:
        return os.path.join(self.path, f"hb-{rnd:06d}-{pid:05d}")

    def announce(self, rnd: int) -> None:
        with open(self._f(rnd, self.pid), "w") as f:
            f.write(f"{time.time()}")

    def await_peers(self, rnd: int) -> list[int]:
        """Block until every peer announced round `rnd` or the timeout
        lapses; returns the pids still missing (empty = proceed)."""
        deadline = time.monotonic() + self.timeout
        missing = [p for p in range(self.n) if p != self.pid]
        while missing and time.monotonic() < deadline:
            missing = [p for p in missing
                       if not os.path.exists(self._f(rnd, p))]
            if missing:
                time.sleep(self.poll)
        return [p for p in missing if not os.path.exists(self._f(rnd, p))]


def _device_barrier() -> None:
    """Cross-process barrier for checkpoint manifests (all shard files
    durable before process 0 names them)."""
    from jax.experimental import multihost_utils
    multihost_utils.sync_global_devices("repro-manifest")


def _parse_chaos(spec: str):
    """'kill:worker=2,round=1' -> ('kill', {'worker': 2, 'round': 1})."""
    if not spec:
        return None, {}
    kind, _, rest = spec.partition(":")
    kv = {}
    for part in rest.split(","):
        if part:
            a, _, b = part.partition("=")
            kv[a.strip()] = int(b)
    return kind, kv


def _elastic_hashes(state) -> dict:
    """Shard hashes over the FULL flat state — params, anchor, AND the
    per-lane Adam moments / outer momentum: a restore or trajectory
    mismatch hiding in the moments would otherwise surface only as a
    slow parameter drift rounds later."""
    out = {}
    for tag, arr in _elastic_state_arrays(state):
        out.update(_shard_hashes(tag, arr))
    return out


def _elastic_state_arrays(state):
    for k in ("params", "anchor", "outer_mu"):
        if k in state:
            for b, arr in state[k].items():
                yield f"{k}/{b}", arr
    for k in ("m", "v", "mu"):
        for b, arr in (state.get("opt") or {}).get(k, {}).items():
            yield f"opt.{k}/{b}", arr


def _elastic_norms(state) -> dict:
    """{shard key: [l2, absmax]} in float64 over the same shard units as
    `_elastic_hashes` — the TOLERANCE comparison for legs where bitwise is
    not contractual (a regrown worker set compiles a different per-process
    XLA program, whose lane-local f32 math can drift by ulps across
    process layouts even though the sync itself stays integer-exact)."""
    import numpy as np
    out = {}
    for tag, arr in _elastic_state_arrays(state):
        for s in arr.addressable_shards:
            key = f"{tag}|{[(sl.start, sl.stop) for sl in s.index]}"
            x = np.asarray(s.data, dtype=np.float64)
            out[key] = [float(np.sqrt(np.sum(x * x))),
                        float(np.max(np.abs(x))) if x.size else 0.0]
    return out


def norms_close(a: dict, b: dict, *, rtol: float = 1e-5) -> bool:
    """Same shard keys, every [l2, absmax] pair within rtol (relative to
    the larger magnitude, floored at 1.0 so zero buckets compare sanely)."""
    if a is None or b is None or not a or set(a) != set(b):
        return False
    for k in a:
        for x, y in zip(a[k], b[k]):
            if abs(x - y) > rtol * max(abs(x), abs(y), 1.0):
                return False
    return True


def run_elastic_worker(*, rounds: int, start_round: int = 0, workdir: str,
                       chaos: str = "", quantize: bool = True,
                       momentum: float = 0.0, seed: int = 0,
                       arch: str = "starcoder2-3b",
                       heartbeat_timeout: float = 30.0) -> dict:
    """One worker of one elastic GENERATION: W = the global device count
    (one dp lane per device, mesh Wx1), engine rounds under `--sync
    partial` with a manifest checkpoint at every round boundary.

    start_round > 0 resumes from the workdir's manifest via the engine's
    `restore_elastic` — written under ANY previous worker count: a shrunk
    generation drops the dead lane, a regrown one clones the consensus
    into the rejoined lane (core/engine.py).  start_round == rounds runs
    zero rounds — the restore-and-hash probe the checkpoint matrix test
    uses to prove manifest restores under different process counts.

    chaos="kill:worker=k,round=r": worker k os._exit()s at the START of
    global round r, before announcing its heartbeat — the survivors'
    await_peers times out and each returns a membership verdict (the CLI
    exits rc 3) naming the missing pids and the resume point.  A
    single-process run of the same mesh is the bitwise reference for any
    multi-process generation (quantized sync: integer-code domain)."""
    import jax
    import numpy as np

    from repro.configs import registry as R
    from repro.configs.base import RunConfig
    from repro.core.engine import RoundEngine
    from repro.optim.lr import make_lr_fn

    workers = len(jax.devices())
    jmesh = jax.make_mesh((workers, 1), ("data", "model"))
    cfg = R.get_smoke_config(arch)
    run_cfg = RunConfig(schedule="constant", optimizer="adamw",
                        total_steps=2 * max(rounds, 1), peak_lr=3e-3,
                        warmup_steps=1, h_base=2, remat=False,
                        weight_decay=0.01, sync_quantize=quantize,
                        outer_momentum=momentum, sharding="dp")
    eng = RoundEngine(cfg, run_cfg, workers=workers, b_loc=2, seq=16,
                      seed=seed, data="device", layout="flat_sharded",
                      sync="partial", mesh=jmesh, policy="dp")
    lr_fn = make_lr_fn(run_cfg)
    state = eng.init_state()
    ckpt = os.path.join(workdir, "ckpt")
    if start_round > 0:
        state, step = eng.restore_elastic(ckpt, state)
        if step != 2 * start_round:
            raise RuntimeError(
                f"manifest at {ckpt} resumes at step {step}, this "
                f"generation starts at round {start_round} (step "
                f"{2 * start_round})")
    pid, nproc = jax.process_index(), jax.process_count()
    kind, kv = _parse_chaos(chaos)
    kill = ((kv.get("worker", -1), kv.get("round", -1))
            if kind == "kill" else None)
    # heartbeat dir is per-generation: stale announcements from a previous
    # epoch must not vouch for a pid that died in this one
    hb = Heartbeat(os.path.join(workdir, f"hb-e{start_round}x{nproc}"),
                   pid, nproc, timeout=heartbeat_timeout)
    barrier = _device_barrier if nproc > 1 else None
    losses = []
    for r in range(start_round, rounds):
        if kill == (pid, r):
            os._exit(7)       # the chaos monkey: no goodbye, no heartbeat
        hb.announce(r)
        missing = hb.await_peers(r)
        if missing:
            return {"mode": "elastic", "status": "membership-change",
                    "ok": True, "missing": missing, "resume_round": r,
                    "resume_step": 2 * r, "checkpoint": ckpt,
                    "rounds_done": r - start_round, **runtime_info()}
        state, m = eng.run_round(state, 2 * r, 2, lr_fn)
        losses.append(float(m["loss"]))
        eng.save_sharded(ckpt, state, step=2 * (r + 1), barrier=barrier)
        if nproc == 1:
            # the monolithic twin the manifest is proven shard-for-shard
            # bitwise against (tests/test_manifest_ckpt.py)
            eng.save(os.path.join(workdir, "ckpt-mono"), state,
                     step=2 * (r + 1))
    return {"mode": "elastic", "status": "complete",
            "ok": bool(np.all(np.isfinite(losses))) if losses else True,
            "losses": losses, "shard_hashes": _elastic_hashes(state),
            "shard_norms": _elastic_norms(state),
            "workers": workers, "rounds": rounds,
            "start_round": start_round, "checkpoint": ckpt,
            **runtime_info()}


# --------------------------------------------------------------------------
# Spawning
# --------------------------------------------------------------------------

def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def _port_bindable(port: int) -> bool:
    try:
        with socket.socket() as s:
            s.bind(("localhost", port))
        return True
    except OSError:
        return False


def _choose_coordinator_port(*, attempts: int = 5, backoff: float = 0.05,
                             candidates=None) -> int:
    """A coordinator port that is still bindable, retrying with backoff:
    the free-port probe inherently races with the eventual bind (another
    server can take the port in between), so losing one probe must cost a
    re-probe, not the whole spawn.  `candidates` injects the first picks —
    the port-collision test pre-binds one and watches the retry walk past
    it."""
    for i in range(attempts):
        port = (candidates[i] if candidates and i < len(candidates)
                else _free_port())
        if _port_bindable(port):
            return port
        time.sleep(backoff * (2 ** i))
    raise OSError(f"no bindable coordinator port after {attempts} attempts")


def _pin_device_count(flags: str, n: int) -> str:
    """Rewrite an XLA_FLAGS string so it pins exactly `n` simulated host
    devices (dropping any prior pin) — used identically for spawned workers
    and single-process runs so their meshes always agree."""
    base = re.sub(r"--xla_force_host_platform_device_count=\d+", "", flags)
    return (base + f" --xla_force_host_platform_device_count={n}").strip()


def spawn_workers(num_processes: int, *, total_devices: int = 8,
                  extra: tuple[str, ...] = (), timeout: int = 900,
                  port_candidates=None):
    """Launch N `python -m repro.launch.multihost` worker processes on this
    machine (localhost coordinator, `total_devices/N` simulated CPU devices
    each) and wait.  Returns [(returncode, stdout, stderr)] per process.
    The coordinator port is chosen with collision retry
    (`_choose_coordinator_port`) and each worker's `initialize()` retries
    with backoff, so neither a probe race nor a slow coordinator fails the
    spawn outright."""
    if total_devices % num_processes != 0:
        raise TopologyError(
            f"{total_devices} simulated devices not divisible over "
            f"{num_processes} processes")
    # a 1-process spawn needs no coordinator: it runs as a plain
    # single-process job (initialize() no-ops).  Wiring jax.distributed +
    # gloo around a single process that owns several devices deadlocks the
    # first eager cross-device gather (e.g. restore_elastic's lane remap
    # on a mesh-sharded state) — and the single-process BITWISE REFERENCE
    # runs are exactly that shape.
    port = (_choose_coordinator_port(candidates=port_candidates)
            if num_processes > 1 else None)
    procs = []
    for pid in range(num_processes):
        env = dict(os.environ)
        if port is not None:
            env["REPRO_COORDINATOR"] = f"localhost:{port}"
            env["REPRO_NUM_PROCESSES"] = str(num_processes)
            env["REPRO_PROCESS_ID"] = str(pid)
        else:
            env.pop("REPRO_COORDINATOR", None)
            env.pop("REPRO_NUM_PROCESSES", None)
            env.pop("REPRO_PROCESS_ID", None)
        env["REPRO_SPAWNED"] = "1"   # the spawner's XLA_FLAGS pin rules
        env["XLA_FLAGS"] = _pin_device_count(
            env.get("XLA_FLAGS", ""), total_devices // num_processes)
        env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.multihost", *extra],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True))
    out = []
    for p in procs:
        try:
            so, se = p.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            p.kill()
            so, se = p.communicate()
            se = (se or "") + "\n[spawn_workers] TIMEOUT"
        out.append((p.returncode, so, se))
    return out


def _epoch_results(results):
    """Parse one generation's per-process (rc, stdout, stderr): the last
    JSON line of each stdout, plus merged shard hashes/norms and rcs."""
    parsed, hashes, norms = [], {}, {}
    for rc, so, _ in results:
        rec = None
        for line in reversed((so or "").strip().splitlines()):
            try:
                rec = json.loads(line)
                break
            except (json.JSONDecodeError, ValueError):
                continue
        parsed.append(rec)
        if rec:
            hashes.update(rec.get("shard_hashes") or {})
            norms.update(rec.get("shard_norms") or {})
    return parsed, hashes, norms, [rc for rc, _, _ in results]


def run_elastic(num_workers: int, *, rounds: int = 3, chaos: str,
                seed: int = 0, arch: str = "starcoder2-3b",
                quantize: bool = True, momentum: float = 0.0,
                workdir: str | None = None,
                heartbeat_timeout: float = 30.0, timeout: int = 900,
                extra_rounds: int = 2) -> dict:
    """The fault-injection controller: drives worker GENERATIONS (each one
    engine MembershipEpoch — `jax.distributed` cannot resize in place)
    through a kill-and-recover story, proving each multi-process
    generation against a single-process run of the same mesh: the
    reduced-mesh CONSENSUS (params + anchor) bitwise in the quantized
    partial sync's integer-code domain, and the regrown rejoin
    generation within a tight norms/losses tolerance (lane-local f32
    math may drift by ulps across process layouts).

    --chaos kill:worker=k,round=r
      gen 0 (W workers):   rounds 0..r-1 complete; worker k dies at the
                           start of round r; survivors' heartbeat timeout
                           fires and they exit rc 3 with the verdict
      gen 1 (W-1 workers): resumes round r from the last round-boundary
                           manifest, completes the run on the reduced
                           mesh; consensus proven bitwise vs a 1-process
                           (W-1)-lane reference resuming the same
                           manifest, Adam moments within the norms
                           tolerance
    --chaos preempt-restore[:worker=k,round=r]
      ...then gen 2 (W workers again) rejoins the lost lane from gen 1's
      final manifest — a W-lane restore of a (W-1)-lane checkpoint under a
      different process count; the rejoined lane re-anchors to consensus —
      and runs `extra_rounds` more, proven within the tolerance bound
      (per-shard l2/absmax norms + per-round losses) vs a 1-process
      W-lane reference; the restore itself is bitwise (manifest matrix).

    Returns the recovery telemetry (the CI chaos job's JSON artifact):
    per-generation rcs/losses, the detection verdict, and the
    bitwise/tolerance verdicts."""
    kind, kv = _parse_chaos(chaos)
    if kind not in ("kill", "preempt-restore"):
        raise ValueError(f"unknown chaos spec {chaos!r}")
    k = kv.get("worker", num_workers // 2)
    r = kv.get("round", 1)
    if not (0 <= k < num_workers and 0 < r < rounds):
        raise ValueError(f"chaos worker={k}, round={r} out of range for "
                         f"{num_workers} workers x {rounds} rounds")
    workdir = workdir or tempfile.mkdtemp(prefix="repro-elastic-")
    os.makedirs(workdir, exist_ok=True)

    def fork(name: str) -> str:
        """A reference generation resumes the SAME manifest the live one
        does — but the live one then advances the rolling checkpoint, so
        the reference runs in a forked copy of the workdir."""
        import shutil
        dst = os.path.join(workdir, name)
        os.makedirs(dst, exist_ok=True)
        if os.path.isdir(os.path.join(workdir, "ckpt")):
            shutil.copytree(os.path.join(workdir, "ckpt"),
                            os.path.join(dst, "ckpt"), dirs_exist_ok=True)
        return dst

    def gen(lanes: int, total_rounds: int, start: int, *, procs=None,
            chaos_arg: str = "", wd: str | None = None):
        ex = ["--mode", "elastic", "--rounds", str(total_rounds),
              "--start-round", str(start), "--workdir", wd or workdir,
              "--momentum", str(momentum), "--seed", str(seed),
              "--arch", arch,
              "--heartbeat-timeout", str(heartbeat_timeout)]
        if quantize:
            ex.append("--quantize")
        if chaos_arg:
            ex += ["--chaos", chaos_arg]
        return _epoch_results(spawn_workers(
            procs or lanes, total_devices=lanes, extra=tuple(ex),
            timeout=timeout))

    out = {"mode": "elastic-controller", "chaos": chaos, "workers":
           num_workers, "rounds": rounds, "kill": {"worker": k, "round": r},
           "workdir": workdir, "generations": []}

    # generation 0: full worker set, chaos kill mid-run
    p0, _, _, rc0 = gen(num_workers, rounds, 0,
                        chaos_arg=f"kill:worker={k},round={r}")
    verdicts = [x for x in p0 if x and x.get("status") == "membership-change"]
    detect_ok = (
        rc0[k] == 7
        and all(rc == 3 for i, rc in enumerate(rc0) if i != k)
        and len(verdicts) == num_workers - 1
        and all(v["missing"] == [k] and v["resume_round"] == r
                for v in verdicts))
    out["generations"].append({"lanes": num_workers, "rcs": rc0,
                               "verdicts": verdicts, "detect_ok": detect_ok})
    if not detect_ok:
        out["ok"] = False
        return out

    # generation 1: survivors complete the run over the reduced mesh,
    # bitwise vs a single-process reference resuming the same manifest
    lanes1 = num_workers - 1
    ref1 = fork("ref1")
    p1, h1, n1, rc1 = gen(lanes1, rounds, r)
    pr, hr, nr, rcr = gen(lanes1, rounds, r, procs=1, wd=ref1)
    # the contractual BITWISE claim is the partial-mean consensus (params +
    # anchor: integer-code domain, exact under any process split); the
    # lane-local Adam moments are f32 trajectories compared within the
    # norms tolerance like gen 2 — XLA may fuse them differently per
    # process layout
    cons = lambda h: {k: v for k, v in h.items()
                      if not k.startswith("opt.")}
    recover_ok = (all(rc == 0 for rc in rc1 + rcr) and bool(h1)
                  and cons(h1) == cons(hr) and norms_close(n1, nr))
    out["generations"].append({
        "lanes": lanes1, "rcs": rc1, "reference_rcs": rcr,
        "rounds_redone": rounds - r,
        "losses": next((x.get("losses") for x in p1 if x), None),
        "reference_losses": next((x.get("losses") for x in pr if x), None),
        "bitwise_vs_single_process": cons(h1) == cons(hr),
        "moments_tolerance_ok": norms_close(n1, nr),
        "shards_compared": len(h1)})
    ok = detect_ok and recover_ok

    if kind == "preempt-restore" and ok:
        # generation 2: the lost lane rejoins from gen 1's final manifest.
        # The verdict here is the TOLERANCE bound, not bitwise: the manifest
        # RESTORE is proven bitwise under any process count (zero-round
        # probes; tests/test_manifest_ckpt.py), but a REGROWN worker set
        # compiles a different per-process XLA program whose lane-local f32
        # math can drift by ulps across process layouts — the sync stays
        # integer-exact, so live-vs-reference shard norms agree to ~1e-5
        # while a real restore/rejoin bug (wrong lane, zeroed moments)
        # lands orders of magnitude outside it.  Bitwise is still reported.
        total2 = rounds + extra_rounds
        ref2 = fork("ref2")
        p2, h2, n2, rc2 = gen(num_workers, total2, rounds)
        pr2, hr2, nr2, rcr2 = gen(num_workers, total2, rounds,
                                  procs=1, wd=ref2)
        l2 = next((x.get("losses") for x in p2 if x), None)
        lr2 = next((x.get("losses") for x in pr2 if x), None)
        losses_ok = (l2 is not None and lr2 is not None and len(l2) == len(lr2)
                     and all(abs(a - b) <= 1e-4 * max(abs(a), abs(b), 1.0)
                             for a, b in zip(l2, lr2)))
        rejoin_ok = (all(rc == 0 for rc in rc2 + rcr2)
                     and norms_close(n2, nr2) and losses_ok)
        out["generations"].append({
            "lanes": num_workers, "rcs": rc2, "reference_rcs": rcr2,
            "rejoined_from": "manifest", "extra_rounds": extra_rounds,
            "losses": l2, "reference_losses": lr2,
            "tolerance_vs_single_process": rejoin_ok,
            "bitwise_vs_single_process": h2 == hr2,
            "shards_compared": len(n2)})
        ok = ok and rejoin_ok

    out["ok"] = ok
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spawn", type=int, default=0,
                    help="launch N worker processes on this machine and "
                         "aggregate their JSON (0: run as a worker / "
                         "single process)")
    ap.add_argument("--total-devices", type=int, default=8,
                    help="global device count (split across --spawn "
                         "workers; pinned locally when single-process)")
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "engine", "probe", "elastic"])
    ap.add_argument("--chaos", default="",
                    help="fault injection: 'kill:worker=K,round=R' or "
                         "'preempt-restore[:worker=K,round=R]'.  With "
                         "--spawn this runs the elastic controller across "
                         "worker generations (module docstring §3); for a "
                         "worker it names its own death sentence")
    ap.add_argument("--membership", default="",
                    help="sync mode: comma mask ('1,1,0,1') switching both "
                         "paths to the partial sync — masked lanes are "
                         "excluded from the mean, which divides by |P|; "
                         "quantized runs also assert the consensus bitwise "
                         "vs a |P|-worker run (integer-code domain)")
    ap.add_argument("--workdir", default="",
                    help="elastic mode: checkpoint/heartbeat directory "
                         "shared by the worker generations (controller "
                         "default: a fresh temp dir)")
    ap.add_argument("--start-round", type=int, default=0,
                    help="elastic mode: first round of this generation "
                         "(resumes the workdir manifest when > 0)")
    ap.add_argument("--heartbeat-timeout", type=float, default=30.0,
                    help="elastic mode: seconds before a silent peer is "
                         "declared dead at a round boundary")
    ap.add_argument("--out", default="",
                    help="also write the result JSON here (the CI chaos "
                         "job uploads the controller's recovery telemetry)")
    ap.add_argument("--mesh", default="2x2x2",
                    help="data x model or pod x data x model; the product "
                         "must equal --total-devices")
    ap.add_argument("--policy", default="fsdp", choices=["dp", "fsdp"])
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--wire", default="auto", choices=["auto", "ring-int8"],
                    help="quantized payload wire mode: 'auto' = exact "
                         "int16/int32 code-sums (bitwise asserts); "
                         "'ring-int8' = re-quantizing int8 ppermute ring "
                         "(tolerance asserts; implies --quantize)")
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--overlap", action="store_true",
                    help="sync mode: split begin/apply across round "
                         "boundaries (the engine's --sync overlap seam)")
    ap.add_argument("--sync", default="blocking",
                    choices=["blocking", "overlap"],
                    help="engine mode: run the RoundEngine rounds with the "
                         "pending reduce threaded across program boundaries "
                         "(--sync overlap); a blocking engine runs alongside "
                         "as the in-process bitwise reference at depth 0")
    ap.add_argument("--overlap-depth", type=int, default=0,
                    help="engine mode: local steps run on stale params "
                         "before the deferred gather applies")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="starcoder2-3b")
    args = ap.parse_args()
    if args.wire == "ring-int8":
        args.quantize = True

    def emit(out: dict) -> None:
        if args.out:
            with open(args.out, "w") as f:
                json.dump(out, f, indent=2)
        print(json.dumps(out))

    if args.spawn and args.chaos:
        # the elastic controller: no jax in THIS process — it only spawns
        # worker generations and judges their verdicts/hashes
        out = run_elastic(args.spawn, rounds=args.rounds, chaos=args.chaos,
                          seed=args.seed, arch=args.arch,
                          quantize=args.quantize, momentum=args.momentum,
                          workdir=args.workdir or None,
                          heartbeat_timeout=args.heartbeat_timeout)
        emit(out)
        sys.exit(0 if out["ok"] else 1)

    if args.spawn:
        extra = ["--mode", args.mode, "--mesh", args.mesh,
                 "--policy", args.policy, "--momentum", str(args.momentum),
                 "--rounds", str(args.rounds), "--seed", str(args.seed),
                 "--arch", args.arch, "--sync", args.sync,
                 "--overlap-depth", str(args.overlap_depth),
                 "--wire", args.wire,
                 "--start-round", str(args.start_round),
                 "--heartbeat-timeout", str(args.heartbeat_timeout)]
        if args.quantize:
            extra.append("--quantize")
        if args.overlap:
            extra.append("--overlap")
        if args.membership:
            extra += ["--membership", args.membership]
        if args.workdir:
            extra += ["--workdir", args.workdir]
        results = spawn_workers(args.spawn, total_devices=args.total_devices,
                                extra=tuple(extra))
        ok = all(rc == 0 for rc, _, _ in results)
        for i, (rc, so, se) in enumerate(results):
            print(f"--- process {i} (rc={rc}) ---")
            print(so.strip())
            if rc != 0:
                print(se[-2000:], file=sys.stderr)
        sys.exit(0 if ok else 1)

    # worker (REPRO_COORDINATOR set by the spawner) or single-process run;
    # single-process: pin the simulated device count before jax wakes up —
    # unless a spawner already pinned it (REPRO_SPAWNED: a coordinator-less
    # 1-process spawn pins total_devices in XLA_FLAGS; re-pinning here
    # would override it with this CLI's --total-devices default)
    if ("REPRO_COORDINATOR" not in os.environ
            and "REPRO_SPAWNED" not in os.environ
            and "jax" not in sys.modules):
        os.environ["XLA_FLAGS"] = _pin_device_count(
            os.environ.get("XLA_FLAGS", ""), args.total_devices)
    initialize()
    if args.mode == "probe":
        out = probe()
    elif args.mode == "elastic":
        out = run_elastic_worker(
            rounds=args.rounds, start_round=args.start_round,
            workdir=args.workdir or tempfile.mkdtemp(prefix="repro-el-"),
            chaos=args.chaos, quantize=args.quantize,
            momentum=args.momentum, seed=args.seed, arch=args.arch,
            heartbeat_timeout=args.heartbeat_timeout)
        emit(out)
        if out.get("status") == "membership-change":
            # rc 3 = the membership verdict; os._exit skips jax.distributed
            # teardown, which can hang once a peer is dead
            sys.stdout.flush()
            os._exit(3)
        sys.exit(0 if out["ok"] else 1)
    elif args.mode == "engine":
        out = run_engine(mesh=args.mesh, policy=args.policy,
                         quantize=args.quantize, momentum=args.momentum,
                         rounds=args.rounds, seed=args.seed, arch=args.arch,
                         sync=args.sync, overlap_depth=args.overlap_depth,
                         wire=args.wire)
    else:
        out = run_sync(mesh=args.mesh, policy=args.policy,
                       quantize=args.quantize, momentum=args.momentum,
                       overlap=args.overlap, rounds=args.rounds,
                       seed=args.seed, wire=args.wire,
                       membership=args.membership)
    emit(out)
    sys.exit(0 if out["ok"] else 1)


if __name__ == "__main__":
    main()
