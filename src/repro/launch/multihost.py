"""Multi-host process bootstrap + a REAL multi-process execution path.

Two jobs:

1. Production bootstrap (TPU pods).  On real TPU v5e, each host owns 4
   chips; a 16x16 pod is 64 hosts and the 2-pod job is 128.  `initialize()`
   wires `jax.distributed`, then `make_production_mesh()` (launch/mesh.py)
   builds the global mesh over `jax.devices()` exactly as the dry-run does
   over placeholder devices — the same `train_round` / `serve_step` programs
   run unchanged.

2. CPU multi-process execution (the thing this module can actually *run*
   anywhere): `run()` executes the sharded sync — and full RoundEngine
   rounds — across N real `jax.distributed` CPU processes with gloo
   collectives.  Every process holds 1/N of the devices of the same global
   mesh the single-process debug runs use; the explicit reduce_scatter /
   all_gather legs of the flat_sharded sync (core/sync.py) then cross true
   process boundaries.  Quantized sync is asserted BITWISE against the
   process-local host path: the worker mean runs over integer codes, so no
   collective ordering — in-process XLA or gloo — can change a bit.  The
   pytest harness (tests/test_multihost.py) spawns the processes and
   additionally checks the multi-process digests against a single-process
   8-simulated-device run of this same module.

   `--wire ring-int8` swaps the one-shot reduce_scatter for the W-hop
   re-quantizing int8 ppermute ring (core/sync.py §ring).  The ring is
   deliberately beyond-exact: per-hop requantization makes the mesh path
   differ from the host reference (and, at the engine's overlap seam, XLA's
   refusion across the program boundary can flip a requant code), so ring
   runs are asserted within `ring_tolerance` — never bitwise.  The shard
   hashes stay exact across PROCESS SPLITS though: the ring has no
   cross-device reductions at all (each hop's arithmetic is device-local and
   ppermute moves int8 bytes verbatim), so a 1-process and an N-process run
   of the same mesh still hash identically shard for shard.

Spawn it yourself (the multihost CPU runbook, README §Multihost):

  PYTHONPATH=src python -m repro.launch.multihost \
      --spawn 2 --total-devices 8 --mesh 2x2x2 --policy fsdp --quantize

Worker environment (set by --spawn, scripts/launch_v5e_pod.sh, or you):
  REPRO_COORDINATOR   host:port of process 0
  REPRO_NUM_PROCESSES total process count
  REPRO_PROCESS_ID    this process's index

NOTE: jax is imported lazily everywhere in this module so `main()` can pin
the per-process simulated-device count (XLA_FLAGS) before jax initializes.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import re
import socket
import subprocess
import sys

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))


class TopologyError(RuntimeError):
    """The device topology does not match the requested production mesh."""


def initialize() -> bool:
    """Wire `jax.distributed` from the REPRO_* environment; no-op (returns
    False) when REPRO_COORDINATOR is unset (single-process dev / dry-run).
    On the CPU backend, cross-process collectives need the gloo
    implementation — selected here; the option is scoped to the CPU client,
    so setting it is harmless on TPU."""
    coord = os.environ.get("REPRO_COORDINATOR")
    if not coord:
        return False
    import jax
    try:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
    except Exception:
        pass  # option absent/renamed in this jax: rely on its default
    jax.distributed.initialize(
        coordinator_address=coord,
        num_processes=int(os.environ["REPRO_NUM_PROCESSES"]),
        process_id=int(os.environ["REPRO_PROCESS_ID"]),
    )
    return True


def runtime_info() -> dict:
    import jax
    return {
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
        "local_devices": len(jax.local_devices()),
        "global_devices": len(jax.devices()),
        "platform": jax.devices()[0].platform,
    }


def assert_production_topology(*, multi_pod: bool) -> None:
    """Raise TopologyError unless the device count matches the production
    mesh.  A real exception, not `assert`: launch scripts run under
    `python -O`, which strips asserts — a silently wrong topology would
    train on a misshapen mesh."""
    import jax
    want = 512 if multi_pod else 256
    got = len(jax.devices())
    if got != want:
        raise TopologyError(
            f"expected {want} chips for the "
            f"{'2x16x16' if multi_pod else '16x16'} mesh, found {got}")


# --------------------------------------------------------------------------
# The executable path: sharded sync / engine rounds across real processes
# --------------------------------------------------------------------------

def _parse_mesh(mesh: str):
    dims = tuple(int(x) for x in mesh.split("x"))
    axes = ("pod", "data", "model") if len(dims) == 3 else ("data", "model")
    return dims, axes


def _demo_params(seed: int = 0):
    """A small mixed-dtype params pytree for the sync harness: two dtype
    buckets, sizes chosen so the W*S chunking actually pads."""
    import jax.numpy as jnp
    import numpy as np
    rng = np.random.RandomState(seed)
    mk = lambda *s: jnp.asarray(rng.randn(*s).astype(np.float32))
    return {
        "w_in": mk(13, 24), "w_attn": mk(24, 24), "bias": mk(17),
        "w_out": mk(24, 13), "gate": mk(3, 5, 7),
        "h_bf16": mk(9, 11).astype(jnp.bfloat16),
        "e_bf16": mk(21).astype(jnp.bfloat16),
    }


def _digest(arrays) -> str:
    import numpy as np
    h = hashlib.sha256()
    for a in arrays:
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()


def _shard_hashes(tag: str, arr) -> dict:
    """{f"{tag}|{global index}": sha1(bytes)} over this process's shards —
    the cross-run comparison unit: a 1-process and an N-process run of the
    same program must produce identical hashes shard for shard."""
    import numpy as np
    out = {}
    for s in arr.addressable_shards:
        key = f"{tag}|{[(sl.start, sl.stop) for sl in s.index]}"
        out[key] = hashlib.sha1(
            np.ascontiguousarray(np.asarray(s.data)).tobytes()).hexdigest()
    return out


def run_sync(*, mesh: str = "2x2x2", policy: str = "fsdp",
             quantize: bool = True, momentum: float = 0.0,
             overlap: bool = False, rounds: int = 3, seed: int = 0,
             wire: str = "auto") -> dict:
    """Execute `rounds` sharded syncs on the global mesh — across however
    many processes own its devices — and assert every addressable shard
    bitwise-equal to the process-local host-path reference (the mesh-less
    flat sync every test in tests/ anchors to).

    Each round perturbs worker params with seeded host noise (identical on
    every process) and syncs.  With `overlap`, the reduce (begin) is issued
    at the round boundary and the gather (apply) deferred to the next round
    — the RS leg's pending int16 code-sums then live across a program
    boundary, exactly the engine's `--sync overlap` seam.

    Bitwise holds for any mesh when `quantize` (integer-code mean) and for
    2-worker meshes unquantized (a single f32 addition has one order);
    callers pick configurations accordingly (tests/test_multihost.py).
    wire="ring-int8" relaxes the contract: the mesh ring and the host ring
    fold identical math through different XLA programs, so requant codes can
    flip — shards must land within `ring_tolerance` of the reference
    instead (the module docstring's beyond-exact semantics)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import RunConfig
    from repro.core import flat as F
    from repro.core.sync import (make_sync, make_sync_apply, make_sync_begin,
                                 ring_tolerance)
    from repro.models import param as pm

    dims, axes = _parse_mesh(mesh)
    jmesh = jax.make_mesh(dims, axes)
    run_cfg = RunConfig(sharding=policy, sync_quantize=quantize,
                        outer_momentum=momentum, sync_wire=wire)
    w = pm.worker_count(policy, jmesh)
    waxes = pm.worker_mesh_axes(policy, jmesh)
    saxes = tuple(a for a in jmesh.axis_names if a not in waxes)
    sizes = pm.mesh_axis_sizes(jmesh)
    shards = int(np.prod([sizes[a] for a in waxes + saxes]))

    params = _demo_params(seed)
    spec_m = F.ShardedFlatSpace(params, shards, mesh=jmesh,
                                worker_axes=waxes, shard_axes=saxes)
    spec_h = F.ShardedFlatSpace(params, shards)

    stacked = {k: jnp.broadcast_to(v[None], (w,) + v.shape)
               for k, v in params.items()}
    base = {"params": spec_h.flatten(stacked, lead=1)}
    if quantize or momentum > 0.0:
        base["anchor"] = spec_h.flatten(params)
    if momentum > 0.0:
        base["outer_mu"] = {b: jnp.zeros(spec_h.buffer_size(b), jnp.float32)
                            for b in spec_h.buckets}

    sspec = F.flat_state_specs(run_cfg, waxes, spec_m)
    put = lambda x, ps: F.make_global(x, jmesh, ps)

    st_m = {k: {b: put(v[b], sspec[k][b]) for b in v}
            for k, v in base.items()}
    st_h = dict(base)

    rng = np.random.RandomState(seed + 1)
    noises = [{k: (rng.randn(w, *v.shape) * 0.01).astype(np.float32)
               for k, v in params.items()} for _ in range(rounds)]

    def steps(state, spec, noise_bufs_put):
        return dict(state, params={
            b: state["params"][b] + noise_bufs_put[b].astype(
                state["params"][b].dtype)
            for b in state["params"]})

    if overlap:
        begin_m = jax.jit(make_sync_begin(run_cfg, spec_m))
        apply_m = jax.jit(make_sync_apply(run_cfg, spec_m))
        begin_h = jax.jit(make_sync_begin(run_cfg, spec_h))
        apply_h = jax.jit(make_sync_apply(run_cfg, spec_h))
    else:
        sync_m = jax.jit(make_sync(run_cfg, spec_m))
        sync_h = jax.jit(make_sync(run_cfg, spec_h))

    pend_m = pend_h = None
    for noise in noises:
        nb = spec_h.flatten(
            {k: jnp.asarray(v) for k, v in noise.items()}, lead=1)
        nb_put = {b: put(nb[b], sspec["params"][b]) for b in nb}
        if overlap:
            if pend_m is not None:
                st_m = apply_m(st_m, pend_m)
                st_h = apply_h(st_h, pend_h)
            st_m, st_h = steps(st_m, spec_m, nb_put), steps(st_h, spec_h, nb)
            pend_m, pend_h = begin_m(st_m), begin_h(st_h)
        else:
            st_m, st_h = steps(st_m, spec_m, nb_put), steps(st_h, spec_h, nb)
            st_m, st_h = sync_m(st_m), sync_h(st_h)
    if overlap and pend_m is not None:
        st_m, st_h = apply_m(st_m, pend_m), apply_h(st_h, pend_h)

    # every addressable shard of the distributed state must equal the
    # corresponding slice of the (fully-replicated) host reference.  For the
    # ring wire the comparison is tolerance-based AFTER a per-element cast
    # allowance |ref|*eps(dtype)*rounds: each round's anchor cast can put
    # the two paths one output-dtype quantum apart (a straddled bf16
    # rounding boundary), and that divergence re-enters the next round's
    # delta — up to one quantum PER ROUND on bf16 buckets.
    max_diff, excess, hashes = 0.0, 0.0, {}
    for k in sorted(st_h):
        for b in sorted(st_h[k]):
            ref = np.asarray(st_h[k][b], np.float32)
            eps = (2.0 ** -7 if "bfloat16" in str(st_h[k][b].dtype)
                   else 2.0 ** -23) * rounds
            for s in st_m[k][b].addressable_shards:
                got = np.asarray(s.data, np.float32)
                if got.size:
                    d = np.abs(got - ref[s.index])
                    max_diff = max(max_diff, float(np.max(d)))
                    excess = max(excess, float(
                        np.max(d - np.abs(ref[s.index]) * eps)))
            hashes.update(_shard_hashes(f"{k}/{b}", st_m[k][b]))

    info = runtime_info()
    if wire == "ring-int8":
        # every round's delta-from-anchor is exactly that round's noise
        # (post-sync params == anchor), so the noise amax bounds the ring's
        # per-round requantization error
        amax_d = max(float(np.max(np.abs(v)))
                     for nz in noises for v in nz.values())
        tol = ring_tolerance(w, amax_d, rounds)
        ok = excess <= tol
    else:
        tol = 0.0
        ok = max_diff == 0.0
    # the digest is over the host reference — meaningful ONLY because the
    # shard assertions above tie the distributed state to it (bitwise, or
    # within ring_tolerance for the ring wire), so gate it on `ok`: a broken
    # distributed path can never produce a matching digest
    digest = (_digest([st_h[k][b] for k in sorted(st_h)
                       for b in sorted(st_h[k])])
              if ok else f"MISMATCH:{max_diff:.3e}")
    return {
        "mode": "sync", "ok": ok, "max_abs_diff": max_diff,
        "digest": digest,
        "shard_hashes": hashes,
        "mesh": mesh, "policy": policy, "workers": w, "shards": shards,
        "quantize": quantize, "momentum": momentum, "overlap": overlap,
        "rounds": rounds, "wire": wire, "ring_tol": tol,
        "wire_dtype": ("int8" if wire == "ring-int8" else
                       "int16" if quantize and w * 127 < 2 ** 15 else
                       "int32" if quantize else "float32"),
        **info,
    }


def run_engine(*, mesh: str = "2x2x2", policy: str = "fsdp",
               quantize: bool = True, momentum: float = 0.0,
               rounds: int = 2, seed: int = 0,
               arch: str = "starcoder2-3b", sync: str = "blocking",
               overlap_depth: int = 0, wire: str = "auto") -> dict:
    """Execute full RoundEngine communication rounds (local steps + sharded
    sync) on the global mesh, across real process boundaries: the engine is
    built exactly as single-process — same config, same mesh axes — with
    `mesh=` handed through so init lays global arrays onto it.

    Cross-process invariant: the round program is SPMD, so every process
    must observe the identical replicated loss scalar, and a 1-process run
    of the same mesh produces bitwise-identical state shards when the sync
    is quantized (the only cross-worker reduction in a dp/fsdp round whose
    result feeds back into the state; integer codes make it
    order-independent).

    sync="overlap": the round programs thread the pending reduce across
    their boundaries (engine `--sync overlap` — `make_sync_begin` at each
    round's end, the gather/apply inside the next program), with the
    pending's worker-sharded payload living on the distributed devices
    between programs.  A blocking engine runs the same trajectory alongside
    as the in-process reference; at depth 0 the flushed overlap state must
    match it BITWISE, shard for shard, on any mesh/process split (identical
    op sequence, deterministic collectives — tests/test_sharded.py proves
    the host edition).  Depth > 0 is the correction form: finite and close,
    reported but not asserted bitwise.

    wire="ring-int8" weakens the depth-0 contract to tolerance: splitting
    begin/apply across the program boundary changes how XLA fuses the ring's
    f32 hop arithmetic, and a reassociated rounding can flip a requant code
    — one quantization level, bounded per round by `ring_tolerance` of the
    (h·lr)-bounded local-step delta."""
    import jax
    import numpy as np

    from repro.configs import registry as R
    from repro.configs.base import RunConfig
    from repro.core import schedules
    from repro.core.engine import RoundEngine
    from repro.core.sync import ring_tolerance
    from repro.optim.lr import make_lr_fn
    from repro.models import param as pm

    dims, axes = _parse_mesh(mesh)
    jmesh = jax.make_mesh(dims, axes)
    cfg = R.get_smoke_config(arch)
    run_cfg = RunConfig(schedule="qsr", optimizer="adamw",
                        total_steps=2 * rounds, peak_lr=3e-3, end_lr=1e-6,
                        warmup_steps=1, h_base=2, alpha=0.001, remat=False,
                        weight_decay=0.01, sync_quantize=quantize,
                        outer_momentum=momentum, sharding=policy,
                        sync_wire=wire)
    w = pm.worker_count(policy, jmesh)
    mk = lambda s, d: RoundEngine(cfg, run_cfg, workers=w, b_loc=2, seq=16,
                                  seed=seed, data="device",
                                  layout="flat_sharded", sync=s,
                                  overlap_depth=d, mesh=jmesh, policy=policy)
    eng = mk(sync, overlap_depth)
    ref = mk("blocking", 0) if sync == "overlap" else None
    lr_fn = make_lr_fn(run_cfg)
    state = eng.init_state()
    ref_state = ref.init_state() if ref else None
    losses, ref_losses = [], []
    tol = 0.0
    for t, h in schedules.rounds(run_cfg, lr_fn):
        state, m = eng.run_round(state, t, h, lr_fn)
        losses.append(float(m["loss"]))
        if wire == "ring-int8":
            # per-round delta amax bound: h AdamW steps of normalized-update
            # magnitude <= ~lr each, x4 headroom for bias-corrected early
            # steps + weight decay — feeds the per-round requant error bound
            tol += ring_tolerance(w, 4.0 * h * run_cfg.peak_lr, 1)
        if ref:
            ref_state, mr = ref.run_round(ref_state, t, h, lr_fn)
            ref_losses.append(float(mr["loss"]))
    state = eng.flush(state)

    def hash_state(st, tag=""):
        out = {}
        for k in ("params", "anchor"):
            if k in st:
                for b, arr in st[k].items():
                    out.update(_shard_hashes(f"{tag}{k}/{b}", arr))
        return out

    hashes = hash_state(state)
    ok = all(np.isfinite(losses))
    rec = {}
    if ref:
        max_diff, excess = 0.0, 0.0
        for k in ("params", "anchor"):
            if k in state:
                for b in state[k]:
                    eps = (2.0 ** -7 if "bfloat16" in str(state[k][b].dtype)
                           else 2.0 ** -23) * max(len(losses), 1)
                    for s, r in zip(state[k][b].addressable_shards,
                                    ref_state[k][b].addressable_shards):
                        a = np.asarray(s.data, np.float32)
                        bb = np.asarray(r.data, np.float32)
                        if a.size:
                            d = np.abs(a - bb)
                            max_diff = max(max_diff, float(np.max(d)))
                            # ring: allow one output-dtype quantum PER ROUND
                            # (straddled rounding boundaries re-enter the
                            # next round's delta) before testing the bound
                            excess = max(excess, float(
                                np.max(d - np.abs(bb) * eps)))
        matches = (excess <= tol if wire == "ring-int8"
                   else max_diff == 0.0)
        if overlap_depth == 0:
            ok = ok and matches
        rec = {"blocking_losses": ref_losses,
               "overlap_matches_blocking": matches,
               "max_abs_diff_vs_blocking": max_diff,
               "wire_tolerance": tol}
    info = runtime_info()
    return {
        "mode": "engine", "ok": ok, "losses": losses,
        "shard_hashes": hashes, "mesh": mesh, "policy": policy, "workers": w,
        "quantize": quantize, "momentum": momentum, "rounds": len(losses),
        "sync": sync, "overlap_depth": overlap_depth, "wire": wire,
        "arch": arch, **rec, **info,
    }


def probe() -> dict:
    """Cheapest possible cross-process collective: one psum over all
    devices.  tests/test_multihost.py runs this first and skips gracefully
    when the distributed CPU backend is unavailable."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    n = len(jax.devices())
    jmesh = jax.make_mesh((n,), ("x",))
    host = np.arange(n, dtype=np.float32)
    arr = jax.make_array_from_callback(
        (n,), NamedSharding(jmesh, P("x")), lambda idx: host[idx])
    total = float(jax.jit(jnp.sum)(arr))
    return {"mode": "probe", "ok": total == n * (n - 1) / 2,
            "devices": n, **runtime_info()}


# --------------------------------------------------------------------------
# Spawning
# --------------------------------------------------------------------------

def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def _pin_device_count(flags: str, n: int) -> str:
    """Rewrite an XLA_FLAGS string so it pins exactly `n` simulated host
    devices (dropping any prior pin) — used identically for spawned workers
    and single-process runs so their meshes always agree."""
    base = re.sub(r"--xla_force_host_platform_device_count=\d+", "", flags)
    return (base + f" --xla_force_host_platform_device_count={n}").strip()


def spawn_workers(num_processes: int, *, total_devices: int = 8,
                  extra: tuple[str, ...] = (), timeout: int = 900):
    """Launch N `python -m repro.launch.multihost` worker processes on this
    machine (localhost coordinator, `total_devices/N` simulated CPU devices
    each) and wait.  Returns [(returncode, stdout, stderr)] per process."""
    assert total_devices % num_processes == 0, (total_devices, num_processes)
    port = _free_port()
    procs = []
    for pid in range(num_processes):
        env = dict(os.environ)
        env["REPRO_COORDINATOR"] = f"localhost:{port}"
        env["REPRO_NUM_PROCESSES"] = str(num_processes)
        env["REPRO_PROCESS_ID"] = str(pid)
        env["XLA_FLAGS"] = _pin_device_count(
            env.get("XLA_FLAGS", ""), total_devices // num_processes)
        env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
        procs.append(subprocess.Popen(
            [sys.executable, "-m", "repro.launch.multihost", *extra],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True))
    out = []
    for p in procs:
        try:
            so, se = p.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            p.kill()
            so, se = p.communicate()
            se = (se or "") + "\n[spawn_workers] TIMEOUT"
        out.append((p.returncode, so, se))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spawn", type=int, default=0,
                    help="launch N worker processes on this machine and "
                         "aggregate their JSON (0: run as a worker / "
                         "single process)")
    ap.add_argument("--total-devices", type=int, default=8,
                    help="global device count (split across --spawn "
                         "workers; pinned locally when single-process)")
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "engine", "probe"])
    ap.add_argument("--mesh", default="2x2x2",
                    help="data x model or pod x data x model; the product "
                         "must equal --total-devices")
    ap.add_argument("--policy", default="fsdp", choices=["dp", "fsdp"])
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--wire", default="auto", choices=["auto", "ring-int8"],
                    help="quantized payload wire mode: 'auto' = exact "
                         "int16/int32 code-sums (bitwise asserts); "
                         "'ring-int8' = re-quantizing int8 ppermute ring "
                         "(tolerance asserts; implies --quantize)")
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--overlap", action="store_true",
                    help="sync mode: split begin/apply across round "
                         "boundaries (the engine's --sync overlap seam)")
    ap.add_argument("--sync", default="blocking",
                    choices=["blocking", "overlap"],
                    help="engine mode: run the RoundEngine rounds with the "
                         "pending reduce threaded across program boundaries "
                         "(--sync overlap); a blocking engine runs alongside "
                         "as the in-process bitwise reference at depth 0")
    ap.add_argument("--overlap-depth", type=int, default=0,
                    help="engine mode: local steps run on stale params "
                         "before the deferred gather applies")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--arch", default="starcoder2-3b")
    args = ap.parse_args()
    if args.wire == "ring-int8":
        args.quantize = True

    if args.spawn:
        extra = ["--mode", args.mode, "--mesh", args.mesh,
                 "--policy", args.policy, "--momentum", str(args.momentum),
                 "--rounds", str(args.rounds), "--seed", str(args.seed),
                 "--arch", args.arch, "--sync", args.sync,
                 "--overlap-depth", str(args.overlap_depth),
                 "--wire", args.wire]
        if args.quantize:
            extra.append("--quantize")
        if args.overlap:
            extra.append("--overlap")
        results = spawn_workers(args.spawn, total_devices=args.total_devices,
                                extra=tuple(extra))
        ok = all(rc == 0 for rc, _, _ in results)
        for i, (rc, so, se) in enumerate(results):
            print(f"--- process {i} (rc={rc}) ---")
            print(so.strip())
            if rc != 0:
                print(se[-2000:], file=sys.stderr)
        sys.exit(0 if ok else 1)

    # worker (REPRO_COORDINATOR set by the spawner) or single-process run;
    # single-process: pin the simulated device count before jax wakes up
    if "REPRO_COORDINATOR" not in os.environ and "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = _pin_device_count(
            os.environ.get("XLA_FLAGS", ""), args.total_devices)
    initialize()
    if args.mode == "probe":
        out = probe()
    elif args.mode == "engine":
        out = run_engine(mesh=args.mesh, policy=args.policy,
                         quantize=args.quantize, momentum=args.momentum,
                         rounds=args.rounds, seed=args.seed, arch=args.arch,
                         sync=args.sync, overlap_depth=args.overlap_depth,
                         wire=args.wire)
    else:
        out = run_sync(mesh=args.mesh, policy=args.policy,
                       quantize=args.quantize, momentum=args.momentum,
                       overlap=args.overlap, rounds=args.rounds,
                       seed=args.seed, wire=args.wire)
    print(json.dumps(out))
    sys.exit(0 if out["ok"] else 1)


if __name__ == "__main__":
    main()
