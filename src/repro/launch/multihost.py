"""Multi-host / multi-pod process bootstrap for the production mesh.

On real TPU v5e, each host owns 4 chips; a 16x16 pod is 64 hosts and the
2-pod job is 128. `initialize()` wires `jax.distributed`, then
`make_production_mesh()` (launch/mesh.py) builds the global mesh over
`jax.devices()` exactly as the dry-run does over placeholder devices —
the same `train_round` / `serve_step` programs run unchanged.

Environment (set by scripts/launch_v5e_pod.sh):
  REPRO_COORDINATOR   host:port of process 0
  REPRO_NUM_PROCESSES total process count
  REPRO_PROCESS_ID    this process's index
"""
from __future__ import annotations

import os

import jax


def initialize() -> None:
    coord = os.environ.get("REPRO_COORDINATOR")
    if not coord:
        return  # single-process (CPU dev / dry-run) — nothing to do
    jax.distributed.initialize(
        coordinator_address=coord,
        num_processes=int(os.environ["REPRO_NUM_PROCESSES"]),
        process_id=int(os.environ["REPRO_PROCESS_ID"]),
    )


def runtime_info() -> dict:
    return {
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
        "local_devices": len(jax.local_devices()),
        "global_devices": len(jax.devices()),
        "platform": jax.devices()[0].platform,
    }


def assert_production_topology(*, multi_pod: bool) -> None:
    want = 512 if multi_pod else 256
    got = len(jax.devices())
    assert got == want, (
        f"expected {want} chips for the "
        f"{'2x16x16' if multi_pod else '16x16'} mesh, found {got}")
