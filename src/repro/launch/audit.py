import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "8")).strip()
# ^ MUST run before any other import: jax locks the device count on first init.

"""Static program auditor CLI (README §Static audit).

Evaluates the declarative rule registry (repro.analysis.rules) against
the AOT-lowered HLO of every supported configuration — sync sub-programs
per (layout x wire x mesh), full round programs with donated state, and
the statically-enumerated compile-cache key space — plus the AST source
lint over src/repro/.  Nothing executes: every verdict lands at lower
time, before any collective runs.

  PYTHONPATH=src python -m repro.launch.audit --all --diff-baseline
  PYTHONPATH=src python -m repro.launch.audit --all --update-baseline
  PYTHONPATH=src python -m repro.launch.audit --config KEY [--config KEY]
  PYTHONPATH=src python -m repro.launch.audit --list | --rules
  PYTHONPATH=src python -m repro.launch.audit --lint
  PYTHONPATH=src python -m repro.launch.audit --self-test

Exit status is non-zero on any rule violation, baseline regression, lint
finding, or uncaught mutation — the CI `static` job gates on it.
"""
import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--all", action="store_true",
                    help="audit the full config matrix")
    ap.add_argument("--config", action="append", default=[],
                    help="audit only this matrix key (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="print the matrix keys and exit")
    ap.add_argument("--rules", action="store_true",
                    help="print the registered rules and exit")
    ap.add_argument("--lint", action="store_true",
                    help="run the AST source lint over src/repro/")
    ap.add_argument("--self-test", action="store_true",
                    help="mutation self-test: deliberately broken programs "
                         "must each trip their rule")
    ap.add_argument("--diff-baseline", action="store_true",
                    help="fail on any regression vs the committed baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the committed baseline from this audit")
    ap.add_argument("--baseline", default=None,
                    help="baseline path (default: the committed "
                         "analysis/audit_baseline.json)")
    ap.add_argument("--out", default=None,
                    help="also write the fingerprint JSON to this path "
                         "(the CI static job uploads it as an artifact)")
    args = ap.parse_args()

    from repro.analysis import audit as A
    from repro.analysis import rules as R
    from repro.analysis import source_lint as L

    status = 0

    if args.list:
        for key, cfg in sorted(A.matrix().items()):
            print(key)
        return 0
    if args.rules:
        for name, rule in sorted(R.RULES.items()):
            print(f"{name}: {rule.description}")
        return 0

    if args.lint:
        violations = L.lint_repo()
        for v in violations:
            print(v.render())
        print(f"source lint: {len(violations)} violation(s)")
        status |= bool(violations)

    if args.self_test:
        failures = A.self_test()
        for f in failures:
            print(f"SELF-TEST FAILURE: {f}")
        print(f"mutation self-test: {len(failures)} failure(s)")
        status |= bool(failures)

    if args.all or args.config:
        fresh = A.run_audit(args.config or None)
        if args.out:
            with open(args.out, "w", encoding="utf-8") as fh:
                json.dump(fresh, fh, indent=1, sort_keys=True)
        bad = {k: e["rules_failed"] for k, e in fresh["configs"].items()
               if e["rules_failed"]}
        for key, failed_rules in sorted(bad.items()):
            for rule in failed_rules:
                for viol in fresh["configs"][key]["rules"][rule]["violations"]:
                    print(f"RULE VIOLATION {key}: {rule}: {viol}")
        n = len(fresh["configs"])
        print(f"audited {n} config(s): "
              f"{n - len(bad)} clean, {len(bad)} violating")
        status |= bool(bad)

        if args.update_baseline:
            path = args.baseline or A.BASELINE_PATH
            with open(path, "w", encoding="utf-8") as fh:
                json.dump(fresh, fh, indent=1, sort_keys=True)
                fh.write("\n")
            print(f"baseline updated: {path}")
        elif args.diff_baseline:
            baseline = A.load_baseline(args.baseline)
            regressions, notes = A.diff_baseline(fresh, baseline)
            for r in regressions:
                print(f"REGRESSION vs baseline: {r}")
            for nline in notes:
                print(f"note: {nline}")
            print(f"baseline diff: {len(regressions)} regression(s), "
                  f"{len(notes)} note(s)")
            status |= bool(regressions)

    return status


if __name__ == "__main__":
    sys.exit(main())
