"""Continuous-batching serving scheduler (request queue -> prefill/decode).

The unit the decode-shape dry-runs lower is a fixed-batch `decode_step`; this
scheduler keeps that batch full: it admits queued requests into free slots
(prefilling their prompts into the shared cache at the slot's position) and
retires finished sequences, so the expensive decode program never runs below
capacity.  Single-sequence prefill writes into a batch slot via the same
`decode_step` program at prompt positions (slot-local prefill), keeping the
number of compiled programs at two.

CPU-runnable at smoke scale (tests/test_batching.py); the same structure is
what a production v5e server would run per model replica.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [P] int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Fixed `slots`-wide decode batch over a shared KV/SSM cache."""

    def __init__(self, cfg, params, *, slots: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg, self.params = cfg, params
        self.mod = api.get_module(cfg)
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self.cache = self.mod.init_cache(cfg, slots, max_len,
                                         dtype=jnp.float32)
        self.pos = np.zeros(slots, np.int32)       # next write position
        self.active: list[Request | None] = [None] * slots
        self.queue: deque[Request] = deque()
        self._decode = jax.jit(
            lambda p, tok, c, pos: self.mod.decode_step(cfg, p, tok, c, pos))

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # -- internals ----------------------------------------------------------

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            # slot-local prefill: prompt tokens stream through decode_step
            # at the slot's own (ragged) positions via a per-request cursor
            req._cursor = 0
            self.active[s] = req
            self.pos[s] = 0

    def _slot_token(self, s: int) -> int:
        req = self.active[s]
        if req is None:
            return 0
        if req._cursor < len(req.prompt):
            return int(req.prompt[req._cursor])
        return int(req.out[-1]) if req.out else int(req.prompt[-1])

    def step(self) -> int:
        """One decode step over all slots. Returns #active sequences."""
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        toks = jnp.asarray([self._slot_token(s) for s in range(self.slots)],
                           jnp.int32)
        # per-slot (ragged) positions: each slot writes/attends at its own
        # cursor — exactness verified vs per-sequence decode in the tests
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, toks, self.cache, pos)
        if self.temperature > 0:
            self.rng, sub = jax.random.split(self.rng)
            nxt = jax.random.categorical(sub, logits / self.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        nxt = np.asarray(nxt)
        n_active = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            n_active += 1
            self.pos[s] += 1
            if req._cursor < len(req.prompt) - 1:
                req._cursor += 1            # still prefilling this slot
                continue
            req._cursor += 1
            req.out.append(int(nxt[s]))
            if len(req.out) >= req.max_new or self.pos[s] >= self.max_len - 1:
                req.done = True
                self.active[s] = None       # retire; slot is reusable
        return n_active

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return
