"""Continuous-batching serving scheduler (request queue -> prefill/decode).

The unit the decode-shape dry-runs lower is a fixed-batch `decode_step`; this
scheduler keeps that batch full: it admits queued requests into free slots
(prefilling their prompts into the shared cache at the slot's position) and
retires finished sequences, so the expensive decode program never runs below
capacity.  Single-sequence prefill writes into a batch slot via the same
`decode_step` program at prompt positions (slot-local prefill), keeping the
number of compiled programs at two.

Weights live as `ServingWeights` flat dtype buckets (launch/weights.py): the
decode program takes the bucket buffers and unflattens inside the jit (pure
slices — bitwise the tree params), so a hot swap replaces one contiguous
buffer per dtype and never recompiles.  `maybe_swap()` is the swap point,
called between decode steps; the "refresh" policy replays every in-flight
sequence's known tokens through the slot-local prefill under the new weights,
which is what makes post-swap tokens bitwise-equal to a server restarted from
the swapped checkpoint (tests/test_serving.py).  Each emitted token is
stamped with the swap-epoch index active when it was sampled
(`Request.epochs`), so the token stream is fully attributable to checkpoint
steps.

Sampling (temperature > 0) is per-request: token t of request r is drawn
from fold_in(fold_in(key(seed), r.rid), t), a pure function of (seed, rid,
emitted-count) — a request's samples never depend on which other requests
happen to share the batch, and a post-swap replay rejoins the same stream.

CPU-runnable at smoke scale (tests/test_batching.py); the same structure is
what a production v5e server would run per model replica.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api
from repro.launch.weights import ServingWeights, WeightSubscriber


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [P] int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    epochs: list = dataclasses.field(default_factory=list)  # swap epoch per token
    done: bool = False


class ContinuousBatcher:
    """Fixed `slots`-wide decode batch over a shared KV/SSM cache."""

    def __init__(self, cfg, params, *, slots: int, max_len: int,
                 temperature: float = 0.0, seed: int = 0,
                 subscriber: WeightSubscriber | None = None):
        self.cfg = cfg
        self.mod = api.get_module(cfg)
        self.weights = (params if isinstance(params, ServingWeights)
                        else ServingWeights(cfg, params))
        self.subscriber = subscriber
        self.slots = slots
        self.max_len = max_len
        self.temperature = temperature
        self._base_key = jax.random.PRNGKey(seed)
        self.cache = self.mod.init_cache(cfg, slots, max_len,
                                         dtype=jnp.float32)
        self.pos = np.zeros(slots, np.int32)       # next write position
        self.active: list[Request | None] = [None] * slots
        self.queue: deque[Request] = deque()
        self.tokens_emitted = 0
        self.swaps = 0
        spec = self.weights.spec
        self._decode = jax.jit(
            lambda bufs, tok, c, pos: self.mod.decode_step(
                cfg, spec.unflatten(bufs), tok, c, pos))

    def submit(self, req: Request) -> None:
        if len(req.prompt) > self.max_len:
            # reject, don't silently truncate: a prompt longer than the
            # cache would wrap through JAX's clamping dynamic_update_slice
            # and corrupt the tail of the lane
            raise ValueError(
                f"prompt of request {req.rid} is {len(req.prompt)} tokens "
                f"but the cache holds max_len={self.max_len}")
        self.queue.append(req)

    # -- hot weight swap ----------------------------------------------------

    def maybe_swap(self) -> bool:
        """The swap point, between decode steps.  Pulls the newest published
        weights (if any) from the subscriber, swaps the flat buckets in
        place, and REFRESHES every in-flight sequence: cursor and cache lane
        reset so the known tokens replay through the slot-local prefill
        under the new weights.  Post-swap tokens are then bitwise what a
        server restarted from that checkpoint would emit — replay costs one
        decode step per replayed token, the price of exact attribution."""
        if self.subscriber is None:
            return False
        self.subscriber.poll()
        got = self.subscriber.take()
        if got is None:
            return False
        step, source, params = got
        if step <= self.weights.step:
            return False
        self.weights.swap(params, step=step, source=source,
                          tokens_before=self.tokens_emitted)
        self.swaps += 1
        live = [s for s, r in enumerate(self.active) if r is not None]
        for s in live:
            self.active[s]._cursor = 0
            self.pos[s] = 0
        if live:
            self.cache = api.zero_cache_slots(self.cache, live)
        return True

    # -- internals ----------------------------------------------------------

    def _admit(self) -> None:
        admitted = []
        for s in range(self.slots):
            if self.active[s] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            # slot-local prefill: prompt tokens stream through decode_step
            # at the slot's own (ragged) positions via a per-request cursor
            req._cursor = 0
            self.active[s] = req
            self.pos[s] = 0
            admitted.append(s)
        if admitted:
            # a recycled lane must be cleared: transformer KV survives dirty
            # lanes by accident (positional overwrite + causal mask), but
            # mamba2/zamba2 recurrent SSM/conv state would leak the previous
            # request into the new one
            self.cache = api.zero_cache_slots(self.cache, admitted)

    def _slot_token(self, s: int) -> int:
        """Sequence token at the slot's cursor: prompt, then emitted tokens
        (the replay form a post-swap refresh depends on)."""
        req = self.active[s]
        if req is None:
            return 0
        i = req._cursor
        if i < len(req.prompt):
            return int(req.prompt[i])
        return int(req.out[i - len(req.prompt)])

    def _next_tokens(self, logits: jax.Array) -> np.ndarray:
        if self.temperature <= 0:
            return np.asarray(jnp.argmax(logits, -1))
        keys = jnp.stack([
            jax.random.fold_in(jax.random.fold_in(self._base_key, r.rid),
                               len(r.out))
            if r is not None else self._base_key
            for r in self.active])
        samp = jax.vmap(lambda k, lg: jax.random.categorical(k, lg))(
            keys, logits / self.temperature)
        return np.asarray(samp)

    def step(self) -> int:
        """One decode step over all slots. Returns #active sequences."""
        self.maybe_swap()
        self._admit()
        if not any(r is not None for r in self.active):
            return 0
        toks = jnp.asarray([self._slot_token(s) for s in range(self.slots)],
                           jnp.int32)
        # per-slot (ragged) positions: each slot writes/attends at its own
        # cursor — exactness verified vs per-sequence decode in the tests
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.weights.bufs, toks, self.cache,
                                          pos)
        nxt = self._next_tokens(logits)
        n_active = 0
        for s, req in enumerate(self.active):
            if req is None:
                continue
            n_active += 1
            self.pos[s] += 1
            known = len(req.prompt) + len(req.out)
            if req._cursor < known - 1:
                req._cursor += 1    # prefilling (or post-swap replaying)
                continue
            req._cursor += 1
            req.out.append(int(nxt[s]))
            req.epochs.append(self.weights.epoch)
            self.tokens_emitted += 1
            # the last legal cache write is position max_len-1, whose decode
            # just produced one more sampled token — retire at pos==max_len,
            # not max_len-1, or the last cache slot is wasted
            if len(req.out) >= req.max_new or self.pos[s] >= self.max_len:
                req.done = True
                self.active[s] = None       # retire; slot is reusable
        return n_active

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                return
