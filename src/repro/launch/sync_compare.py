import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "8")).strip()
# ^ MUST run before any other import: jax locks the device count on first init.

"""Sync lowering compared across param layouts on a debug sharded mesh.

Compiles the every-H-steps sync under the tree / flat / flat_sharded param
layouts and reports, per layout, what the wire actually sees: collective op
counts per kind (hlo_analysis.collective_counts — the latency/launch axis),
full-tensor bytes per sync (collective_bytes — the bandwidth axis), and
per-leg landing bytes (collective_result_bytes — where the sharded layout's
scatter-leg ~W x drop shows).  This is the measurement behind the layout
acceptance claims: flat = one all-reduce per dtype bucket instead of one
per pytree leaf; flat_sharded = one reduce_scatter + one all_gather per
bucket instead of the full all-reduce, with the scatter leg landing 1/W of
the bucket per device.

Run as a module (subprocess-safe: the device-count pin above must precede
any jax init, so callers shell out rather than import):

  PYTHONPATH=src python -m repro.launch.sync_compare \
      --arch starcoder2-3b [--param-layout flat_sharded] [--policy fsdp] \
      [--mesh 4x2 | --mesh 2x2x2] [--smoke] [--quantize] [--momentum 0.9]

A three-field mesh (PxDxM) adds a pod axis — the fsdp policy's worker axis,
so `--mesh 2x2x2 --policy fsdp` exercises the multi-pod QSR configuration
where each pod is one worker and buckets chunk over (data, model).

Prints one JSON object; benchmarks/table1_comm.py, tests/test_flat.py and
tests/test_sharded.py consume it.
"""
import argparse
import json

import jax

from repro.configs.base import RunConfig
from repro.launch import hlo_analysis
from repro.launch.mesh import make_debug_mesh
from repro.launch.shapes import build_calib_case

LAYOUTS = ("tree", "flat", "flat_sharded")


def compare(arch: str = "starcoder2-3b", *, smoke: bool = True,
            quantize: bool = False, momentum: float = 0.0,
            n_data: int = 4, n_model: int = 2, pods: int = 0,
            policy: str = "dp",
            layouts: tuple[str, ...] = LAYOUTS) -> dict:
    """{layout: {collective_counts, collective_bytes, collective_leg_bytes,
    all_reduce_ops, reduce_scatter_ops, all_gather_ops, bytes_on_wire,
    scatter_leg_bytes, n_leaves, n_buckets}} for the policy's sync."""
    from repro.configs import registry as R

    cfg = R.get_smoke_config(arch) if smoke else R.get_config(arch)
    run_cfg = RunConfig(sharding=policy, sync_quantize=quantize,
                        outer_momentum=momentum)
    mesh = make_debug_mesh(n_data, n_model, pods=pods)
    out = {}
    for layout in layouts:
        case = build_calib_case(cfg, "train_4k", mesh, policy=policy,
                                run_cfg=run_cfg, fn_kind="sync",
                                layout=layout)
        with mesh:
            compiled = jax.jit(case.fn, in_shardings=case.in_shardings,
                               out_shardings=case.out_shardings
                               ).lower(*case.args).compile()
        hlo = compiled.as_text()
        counts = hlo_analysis.collective_counts(hlo)
        nbytes = hlo_analysis.collective_bytes(hlo)
        legs = hlo_analysis.collective_result_bytes(hlo)
        out[layout] = {
            "collective_counts": counts,
            "collective_bytes": {k: v for k, v in nbytes.items() if v},
            "collective_leg_bytes": {k: v for k, v in legs.items() if v},
            "all_reduce_ops": counts["all-reduce"],
            "reduce_scatter_ops": counts["reduce-scatter"],
            "all_gather_ops": counts["all-gather"],
            "bytes_on_wire": sum(v for k, v in nbytes.items() if k != "dci"),
            "scatter_leg_bytes": legs["reduce-scatter"],
            "n_leaves": case.meta["n_leaves"],
            "n_buckets": case.meta["n_buckets"],
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--full", action="store_true",
                    help="production config (default: smoke, CPU-runnable)")
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--policy", default="dp", choices=["dp", "fsdp"])
    ap.add_argument("--param-layout", default=None, choices=list(LAYOUTS),
                    help="compare only this layout (default: all three)")
    ap.add_argument("--mesh", default="4x2",
                    help="debug mesh data x model, or pod x data x model; "
                         "8x1 = pure dp, where tree/flat move identical "
                         "bytes and flat_sharded's scatter leg lands 1/W "
                         "per device (with model sharding, tree all-reduces "
                         "shard-local bytes)")
    args = ap.parse_args()
    dims = [int(x) for x in args.mesh.split("x")]
    pods, n_data, n_model = ([0] + dims if len(dims) == 2 else dims)
    layouts = (args.param_layout,) if args.param_layout else LAYOUTS
    print(json.dumps(compare(args.arch, smoke=not args.full,
                             quantize=args.quantize,
                             momentum=args.momentum,
                             n_data=n_data, n_model=n_model, pods=pods,
                             policy=args.policy, layouts=layouts)))


if __name__ == "__main__":
    main()
