import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "8")).strip()
# ^ MUST run before any other import: jax locks the device count on first init.

"""Tree-vs-flat sync lowering compared on a debug sharded mesh.

Compiles the every-H-steps sync under both param layouts and reports, per
layout, what the wire actually sees: collective op counts per kind
(hlo_analysis.collective_counts — the latency/launch axis) and bytes on
wire per sync (collective_bytes — the bandwidth axis).  This is the
measurement behind the flat layout's acceptance claim: one all-reduce per
dtype bucket instead of one per pytree leaf, same bytes.

Run as a module (subprocess-safe: the device-count pin above must precede
any jax init, so callers shell out rather than import):

  PYTHONPATH=src python -m repro.launch.sync_compare \
      --arch starcoder2-3b [--smoke] [--quantize] [--momentum 0.9]

Prints one JSON object; benchmarks/table1_comm.py and tests/test_flat.py
consume it.
"""
import argparse
import json

import jax

from repro.configs.base import RunConfig
from repro.launch import hlo_analysis
from repro.launch.mesh import make_debug_mesh
from repro.launch.shapes import build_calib_case


def compare(arch: str = "starcoder2-3b", *, smoke: bool = True,
            quantize: bool = False, momentum: float = 0.0,
            n_data: int = 4, n_model: int = 2) -> dict:
    """{layout: {collective_counts, collective_bytes, all_reduce_ops,
    bytes_on_wire, n_leaves, n_buckets}} for the dp-policy sync."""
    from repro.configs import registry as R

    cfg = R.get_smoke_config(arch) if smoke else R.get_config(arch)
    run_cfg = RunConfig(sharding="dp", sync_quantize=quantize,
                        outer_momentum=momentum)
    mesh = make_debug_mesh(n_data, n_model)
    out = {}
    for layout in ("tree", "flat"):
        case = build_calib_case(cfg, "train_4k", mesh, policy="dp",
                                run_cfg=run_cfg, fn_kind="sync",
                                layout=layout)
        with mesh:
            compiled = jax.jit(case.fn, in_shardings=case.in_shardings,
                               out_shardings=case.out_shardings
                               ).lower(*case.args).compile()
        hlo = compiled.as_text()
        counts = hlo_analysis.collective_counts(hlo)
        nbytes = hlo_analysis.collective_bytes(hlo)
        out[layout] = {
            "collective_counts": counts,
            "collective_bytes": {k: v for k, v in nbytes.items() if v},
            "all_reduce_ops": counts["all-reduce"],
            "bytes_on_wire": sum(v for k, v in nbytes.items() if k != "dci"),
            "n_leaves": case.meta["n_leaves"],
            "n_buckets": case.meta["n_buckets"],
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--full", action="store_true",
                    help="production config (default: smoke, CPU-runnable)")
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--mesh", default="4x2",
                    help="debug mesh data x model; 8x1 = pure dp, where the "
                         "two layouts move identical bytes (with model "
                         "sharding, tree all-reduces shard-local bytes)")
    args = ap.parse_args()
    n_data, n_model = (int(x) for x in args.mesh.split("x"))
    print(json.dumps(compare(args.arch, smoke=not args.full,
                             quantize=args.quantize,
                             momentum=args.momentum,
                             n_data=n_data, n_model=n_model)))


if __name__ == "__main__":
    main()
