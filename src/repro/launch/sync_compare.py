import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "8")).strip()
# ^ MUST run before any other import: jax locks the device count on first init.

"""Sync lowering compared across param layouts on a debug sharded mesh.

Compiles the every-H-steps sync under the tree / flat / flat_sharded param
layouts and reports, per layout, what the wire actually sees: collective op
counts per kind (hlo_analysis.collective_counts — the latency/launch axis),
full-tensor bytes per sync (collective_bytes — the bandwidth axis), and
per-leg landing bytes (collective_result_bytes — where the sharded layout's
scatter-leg ~W x drop shows).  This is the measurement behind the layout
acceptance claims: flat = one all-reduce per dtype bucket instead of one
per pytree leaf; flat_sharded = one reduce_scatter + one all_gather per
bucket instead of the full all-reduce, with the scatter leg landing 1/W of
the bucket per device.

Run as a module (subprocess-safe: the device-count pin above must precede
any jax init, so callers shell out rather than import):

  PYTHONPATH=src python -m repro.launch.sync_compare \
      --arch starcoder2-3b [--param-layout flat_sharded] [--policy fsdp] \
      [--mesh 4x2 | --mesh 2x2x2] [--smoke] [--quantize] [--momentum 0.9]

A three-field mesh (PxDxM) adds a pod axis — the fsdp policy's worker axis,
so `--mesh 2x2x2 --policy fsdp` exercises the multi-pod QSR configuration
where each pod is one worker and buckets chunk over (data, model).

Prints one JSON object; benchmarks/table1_comm.py, tests/test_flat.py and
tests/test_sharded.py consume it.
"""
import argparse
import json

import jax

from repro.analysis import rules
from repro.configs.base import RunConfig
from repro.errors import ConfigError
from repro.launch import hlo_analysis
from repro.launch.mesh import make_debug_mesh
from repro.launch.shapes import build_calib_case

LAYOUTS = ("tree", "flat", "flat_sharded")


def compare(arch: str = "starcoder2-3b", *, smoke: bool = True,
            quantize: bool = False, momentum: float = 0.0,
            wire: str = "auto",
            n_data: int = 4, n_model: int = 2, pods: int = 0,
            policy: str = "dp",
            layouts: tuple[str, ...] = LAYOUTS) -> dict:
    """{layout: {collective_counts, collective_bytes, collective_leg_bytes,
    all_reduce_ops, reduce_scatter_ops, all_gather_ops, bytes_on_wire,
    scatter_leg_bytes, n_leaves, n_buckets, payload_bytes_by_dtype, ...}}
    for the policy's sync.  wire="ring-int8" swaps the one-shot RS for the
    re-quantizing ppermute ring (flat layouts only; requires quantize)."""
    from repro.configs import registry as R

    cfg = R.get_smoke_config(arch) if smoke else R.get_config(arch)
    run_cfg = RunConfig(sharding=policy, sync_quantize=quantize,
                        outer_momentum=momentum, sync_wire=wire)
    mesh = make_debug_mesh(n_data, n_model, pods=pods)
    out = {"_config": {"arch": arch, "smoke": smoke, "quantize": quantize,
                       "momentum": momentum, "policy": policy, "wire": wire,
                       "mesh": [d for d in ((pods,) if pods else ())
                                + (n_data, n_model)]}}
    for layout in layouts:
        case = build_calib_case(cfg, "train_4k", mesh, policy=policy,
                                run_cfg=run_cfg, fn_kind="sync",
                                layout=layout)
        with mesh:
            compiled = jax.jit(case.fn, in_shardings=case.in_shardings,
                               out_shardings=case.out_shardings
                               ).lower(*case.args).compile()
        hlo = compiled.as_text()
        # the scale-vs-payload classification (the quantized sharded sync
        # is allowed ONE tiny amax-fold all-reduce — 4 bytes per model
        # tensor — and zero payload-sized ones; the ring's per-hop f32
        # scales are scalar-sized and classified with the same threshold)
        # lives in hlo_analysis.payload_profile, shared with the audit CLI
        rec = hlo_analysis.payload_profile(hlo, n_leaves=case.meta["n_leaves"])
        rec["n_buckets"] = case.meta["n_buckets"]
        rec["workers"] = case.meta["w"]
        rec["host_callback_lines"] = hlo_analysis.host_callbacks(hlo)
        rec["degenerate_collectives"] = hlo_analysis.degenerate_collectives(hlo)
        # attach the declarative rule verdicts: tests assert the layout
        # acceptance claims through this one registry (repro.analysis.rules)
        # instead of re-deriving counts per test file
        rule_cfg = {"kind": "sync", "layout": layout, "sync": "blocking",
                    "wire": wire, "quantize": quantize, "policy": policy,
                    "workers": case.meta["w"]}
        rec["rules"] = rules.evaluate(rule_cfg, rec)
        rec["rules_failed"] = rules.failed(rec["rules"])
        out[layout] = rec
    return out


def exec_compare(arch: str = "starcoder2-3b", *, smoke: bool = True,
                 quantize: bool = False, momentum: float = 0.0,
                 wire: str = "auto",
                 n_data: int = 4, n_model: int = 2, pods: int = 0,
                 policy: str = "dp", rounds: int = 3,
                 layouts: tuple[str, ...] = LAYOUTS) -> dict:
    """EXECUTE the sync under each layout on the debug mesh and compare the
    multi-round trajectories against the mesh-less flat path (the reference
    every bitwise test in tests/ anchors to).

    Each round perturbs every worker's params with the same host-generated
    noise and runs the layout's jitted sync.  Quantized, all layouts must
    agree BITWISE with the reference on any mesh: the worker mean runs over
    integer codes (core/sync.py RS-domain rule), so neither GSPMD's
    all-reduce ordering nor the explicit reduce_scatter changes a single
    bit.  Unquantized f32 means are only order-independent for 2 workers.

    wire="ring-int8" is the deliberate exception: per-hop requantization is
    chunking-dependent, so the mesh trajectories are asserted within
    `ring_tolerance` of the host reference (reported as `within_tol`), never
    bitwise — the drift is the price of int8-on-every-hop and is measured
    here and in benchmarks/sde_drift.py.
    """
    import numpy as np

    from repro.configs import registry as R
    from repro.core import flat as F, local_update as LU
    from repro.core.sync import make_sync, ring_tolerance
    from repro.models import api, param as pm

    cfg = R.get_smoke_config(arch) if smoke else R.get_config(arch)
    run_cfg = RunConfig(sharding=policy, sync_quantize=quantize,
                        outer_momentum=momentum, sync_wire=wire)
    mesh = make_debug_mesh(n_data, n_model, pods=pods)
    w = pm.worker_count(policy, mesh)
    waxes = pm.worker_mesh_axes(policy, mesh)
    saxes = tuple(a for a in mesh.axis_names if a not in waxes)
    sizes = pm.mesh_axis_sizes(mesh)
    shards = int(np.prod([sizes[a] for a in waxes + saxes]))

    params = pm.init_params(api.get_module(cfg).param_defs(cfg),
                            jax.random.PRNGKey(0))
    base = LU.init_state(cfg, run_cfg, params, w)
    base.pop("opt")          # the sync never touches optimizer state

    # per-round worker perturbations, shared by every layout (host numpy)
    rng = np.random.RandomState(7)
    noises = [jax.tree.map(lambda x: (rng.randn(w, *np.shape(x)) * 0.01
                                      ).astype(np.float32), params)
              for _ in range(rounds)]

    def run_layout(layout, with_mesh: bool):
        from jax.sharding import NamedSharding, PartitionSpec as P
        if layout == "tree":
            spec = None
        elif layout == "flat":
            spec = F.FlatParamSpace(params)
        else:
            spec = (F.ShardedFlatSpace(params, shards, mesh=mesh,
                                       worker_axes=waxes, shard_axes=saxes)
                    if with_mesh else F.ShardedFlatSpace(params, shards))
        if spec is None:
            state = dict(base)
        else:
            state = {k: (spec.flatten(v, lead=1) if k == "params"
                         else spec.flatten(v)) for k, v in base.items()}
        if with_mesh and spec is not None:
            sspec = F.flat_state_specs(run_cfg, waxes, spec)
            state = {k: {b: jax.device_put(v[b],
                                           NamedSharding(mesh, sspec[k][b]))
                         for b in v} for k, v in state.items()}
        sync = jax.jit(make_sync(run_cfg, spec=spec))
        for noise in noises:
            if spec is None:
                perturbed = jax.tree.map(
                    lambda p, n: (p + n.astype(p.dtype)), state["params"],
                    noise)
            else:
                nb = spec.flatten(noise, lead=1)
                perturbed = {b: state["params"][b] + nb[b].astype(
                    state["params"][b].dtype) for b in nb}
            state = dict(state, params=perturbed)
            with mesh:
                state = sync(state)
        if spec is None:
            return state
        return {k: (spec.unflatten(v, lead=1) if k == "params"
                    else spec.unflatten(v)) for k, v in state.items()}

    ref = run_layout("flat_sharded", with_mesh=False)   # host path reference
    out = {"rounds": rounds, "workers": w, "quantize": quantize,
           "momentum": momentum, "wire": wire,
           "reference": "flat_sharded(no mesh)"}
    if wire == "ring-int8":
        amax_d = max(float(np.max(np.abs(l)))
                     for noise in noises for l in jax.tree.leaves(noise))
        out["ring_tol"] = ring_tolerance(w, amax_d, rounds)
    for layout in layouts:
        got = run_layout(layout, with_mesh=True)
        diffs = [float(np.max(np.abs(np.asarray(a, np.float32)
                                     - np.asarray(b, np.float32))))
                 if np.size(np.asarray(a)) else 0.0
                 for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref))]
        md = max(diffs)
        out[layout] = {"max_abs_diff": md, "bitwise": md == 0.0}
        if wire == "ring-int8":
            out[layout]["within_tol"] = md <= out["ring_tol"]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--full", action="store_true",
                    help="production config (default: smoke, CPU-runnable)")
    ap.add_argument("--quantize", action="store_true")
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--policy", default="dp", choices=["dp", "fsdp"])
    ap.add_argument("--param-layout", default=None,
                    help="compare only these layouts, comma-separated "
                         "(default: all three)")
    ap.add_argument("--mesh", default="4x2",
                    help="debug mesh data x model, or pod x data x model; "
                         "8x1 = pure dp, where tree/flat move identical "
                         "bytes and flat_sharded's scatter leg lands 1/W "
                         "per device (with model sharding, tree all-reduces "
                         "shard-local bytes)")
    ap.add_argument("--exec", dest="exec_", action="store_true",
                    help="also EXECUTE the sync per layout on the mesh and "
                         "compare multi-round trajectories against the "
                         "mesh-less flat path (bitwise when --quantize: "
                         "the integer-code mean is order-independent)")
    ap.add_argument("--exec-rounds", type=int, default=3)
    ap.add_argument("--wire", default="auto", choices=["auto", "ring-int8"],
                    help="quantized payload wire mode: auto = exact Sq "
                         "contract in wire_dtype(W) (int16/int32); "
                         "ring-int8 = W-hop re-quantizing ppermute ring, "
                         "int8 on every hop, tolerance-based (not bitwise); "
                         "implies --quantize and flat layouts only")
    ap.add_argument("--out", default=None,
                    help="also write the JSON record to this path (the "
                         "multi-device CI matrix publishes these artifacts)")
    args = ap.parse_args()
    dims = [int(x) for x in args.mesh.split("x")]
    pods, n_data, n_model = ([0] + dims if len(dims) == 2 else dims)
    if args.wire == "ring-int8":
        args.quantize = True        # the ring carries int8 codes by definition
    if args.param_layout:
        layouts = tuple(args.param_layout.split(","))
        bad = [l for l in layouts if l not in LAYOUTS]
        if bad:
            raise ConfigError(f"unknown layouts {bad}; pick from {LAYOUTS}")
    else:
        layouts = LAYOUTS
    if args.wire == "ring-int8":
        layouts = tuple(l for l in layouts if l != "tree") or ("flat_sharded",)
    out = compare(args.arch, smoke=not args.full,
                  quantize=args.quantize,
                  momentum=args.momentum, wire=args.wire,
                  n_data=n_data, n_model=n_model, pods=pods,
                  policy=args.policy, layouts=layouts)
    if args.exec_:
        out["exec"] = exec_compare(args.arch, smoke=not args.full,
                                   quantize=args.quantize,
                                   momentum=args.momentum, wire=args.wire,
                                   n_data=n_data, n_model=n_model, pods=pods,
                                   policy=args.policy,
                                   rounds=args.exec_rounds, layouts=layouts)
    text = json.dumps(out)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
