"""Hot weight swap for the serving path: flat-bucket publish/subscribe.

The train-to-serve contract: a long QSR run continuously publishes its
consensus params (`engine.params_single(synced state)`) and a live endpoint
swaps them in **between decode steps** without restarting.  The pieces:

  * `publish_weights` — the producer side.  An `AsyncObserver` handler (or
    any host thread) writes a params-only checkpoint via `checkpoint.io.save`
    — atomic, durable, step-stamped — tagged ``serving_weights/v1``.
  * `WeightSubscriber` — the consumer side.  Latest-wins slot fed from two
    sources: `publish()` (in-process, called straight from the observer
    worker thread) and `poll()` (a `watch_dir` holding published
    checkpoints — the cross-process form).  Mirrors the AsyncObserver's
    double-buffer discipline: a superseded snapshot is dropped, the server
    only ever sees the newest weights.
  * `ServingWeights` — the in-place swap target.  Params live as
    `FlatParamSpace` dtype buckets, so `swap()` is ONE contiguous host→device
    copy per dtype bucket (the FlatParamSpace refactor's serving payoff);
    the decode program takes the bucket buffers and unflattens inside the
    jit, so a swap never recompiles.  Every swap appends a `SwapEpoch` audit
    record (the serving mirror of the engine's `BatchEpoch` /
    `MembershipEpoch`), which is what makes every emitted token attributable
    to a checkpoint step (`ContinuousBatcher` stamps each token with the
    epoch index active when it was sampled).

Swap policy for in-flight sequences is "refresh": the batcher replays each
live sequence's tokens through its slot-local prefill under the new weights
(launch/batching.py `maybe_swap`), so post-swap tokens are bitwise what a
server restarted from that checkpoint would emit — the proof tested in
tests/test_serving.py.  The cheap alternative (keep the stale cache, mixed
attribution) is documented in README §Serving.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

import jax
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.core import flat

WEIGHTS_KIND = "serving_weights/v1"


@dataclasses.dataclass(frozen=True)
class SwapEpoch:
    """One weight generation of a serving process (audit record).

    Mirror of the engine's BatchEpoch/MembershipEpoch: a frozen,
    JSON-able row appended at every swap, so the serving log is a total
    order of weight generations and `tokens_before` splits the token
    stream exactly at the swap point."""
    index: int            # 0 = the weights the server started with
    step: int             # producer checkpoint step of these weights
    source: str           # "init" | "publish" | "watch:<dir>" | ...
    tokens_before: int    # tokens emitted by this server before the swap
    wall_time: float


class ServingWeights:
    """Serving params as FlatParamSpace dtype buckets + swap-epoch audit.

    `bufs` is what the decode program consumes (unflatten fuses into
    slices inside the jit); `swap()` replaces the buckets in place — one
    contiguous device_put per dtype bucket — and bumps the epoch."""

    def __init__(self, cfg, params: Any, *, step: int = 0,
                 source: str = "init"):
        self.cfg = cfg
        self.spec = flat.FlatParamSpace(params)
        self.bufs = {b: jax.device_put(v)
                     for b, v in self.spec.flatten(params).items()}
        self.step = step
        self.epochs: list[SwapEpoch] = [
            SwapEpoch(0, step, source, 0, time.time())]

    @property
    def epoch(self) -> int:
        return self.epochs[-1].index

    def as_tree(self) -> Any:
        """Current weights as the model pytree (pure slices of the bufs)."""
        return self.spec.unflatten(self.bufs)

    def swap(self, params: Any, *, step: int, source: str = "publish",
             tokens_before: int = 0) -> SwapEpoch:
        """Replace the serving weights in place: one contiguous copy per
        dtype bucket.  `params` must match the spec's tree (same shapes and
        dtypes — a different architecture is a deploy, not a swap)."""
        bufs = self.spec.flatten(params)
        for b in self.spec.buckets:
            self.bufs[b] = jax.device_put(bufs[b])
        self.step = step
        ep = SwapEpoch(self.epoch + 1, step, source, tokens_before,
                       time.time())
        self.epochs.append(ep)
        return ep

    def audit(self) -> list[dict]:
        """The swap-epoch trail as JSON-able rows (CI uploads this)."""
        return [dataclasses.asdict(e) for e in self.epochs]


def params_like(cfg, dtype=None) -> Any:
    """Host zeros tree matching the model params — the `like` a
    WeightSubscriber needs to restore published checkpoints (real zero
    arrays, not ShapeDtypeStructs: `restore_with_meta` validates shape and
    casts dtype only against array-like targets)."""
    import jax.numpy as jnp
    from repro.models import api, param as pm
    mod = api.get_module(cfg)
    ab = pm.abstract_params(mod.param_defs(cfg),
                            jnp.float32 if dtype is None else dtype)
    return jax.tree.map(lambda s: np.zeros(s.shape, np.dtype(s.dtype)), ab)


def publish_weights(path: str, params: Any, *, step: int,
                    extra: dict | None = None) -> None:
    """Write a params-only serving checkpoint (atomic + durable via
    checkpoint.io).  The natural AsyncObserver handler body:

        AsyncObserver(lambda step, snap:
            publish_weights(d, snap["params"], step=step))
    """
    meta = {"kind": WEIGHTS_KIND, "published_at": time.time()}
    meta.update(extra or {})
    ckpt_io.save(path, params, step=step, extra=meta)


def load_weights(path: str, like: Any) -> tuple[Any, int, dict]:
    """Restore a published serving checkpoint. Returns (params, step, extra)."""
    tree, step, extra = ckpt_io.restore_with_meta(path, like)
    return tree, int(step or 0), extra


class WeightSubscriber:
    """Latest-wins weight feed for a serving process.

    Thread contract: `publish()` may be called from any thread (typically
    the AsyncObserver worker); `poll()`/`take()` belong to the serving
    thread.  The slot holds host-staged params so the producer's device
    buffers are never retained."""

    def __init__(self, *, watch_dir: str | None = None,
                 like: Any | None = None):
        self.watch_dir = watch_dir
        self._like = like
        self._lock = threading.Lock()
        self._latest: tuple[int, str, Any] | None = None
        self._seen_step: int | None = None
        self.superseded = 0           # snapshots dropped by latest-wins

    # -- producer side -----------------------------------------------------

    def publish(self, step: int, params: Any, *,
                source: str = "publish") -> None:
        """Offer new weights (in-process path). Stages to host numpy so the
        caller's buffers are released; latest-wins on `step`."""
        host = jax.tree.map(np.asarray, params)
        self._offer(int(step), source, host)

    # -- serving side ------------------------------------------------------

    def poll(self) -> None:
        """Check the watch_dir for a newer published checkpoint and load it
        into the slot.  Tolerates a racing writer: a missing or torn file
        is simply retried on the next poll (checkpoint.io writes are atomic,
        so a finished file is always wholly readable)."""
        if self.watch_dir is None:
            return
        meta = ckpt_io.try_read_meta(self.watch_dir)
        if meta is None:
            return
        step = meta[0]
        if step is None or (self._seen_step is not None
                            and int(step) <= self._seen_step):
            return
        if self._like is None:
            raise ValueError("WeightSubscriber with a watch_dir needs a "
                             "`like` tree to restore into (see params_like)")
        try:
            tree, got_step, _ = ckpt_io.restore_with_meta(self.watch_dir,
                                                          self._like)
        except (ckpt_io.CheckpointError, FileNotFoundError):
            return                     # mid-replace; next poll sees it whole
        got_step = int(got_step if got_step is not None else step)
        self._seen_step = got_step
        self._offer(got_step, f"watch:{self.watch_dir}", tree)

    def take(self) -> tuple[int, str, Any] | None:
        """Pop the newest offered weights, or None. The swap point calls
        this between decode steps (ContinuousBatcher.maybe_swap)."""
        with self._lock:
            got, self._latest = self._latest, None
        return got

    def _offer(self, step: int, source: str, tree: Any) -> None:
        with self._lock:
            if self._latest is not None:
                if step <= self._latest[0]:
                    return             # older than what's already queued
                self.superseded += 1
            self._latest = (step, source, tree)
