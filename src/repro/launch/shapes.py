"""Assigned input shapes + ShapeDtypeStruct input specs for every
(architecture x shape x mesh x policy) combination.

`build_case()` returns everything the dry-run needs: the function to lower,
abstract arguments, and in/out shardings — no device allocation (the
shannon/kernels pattern: weak-type-correct, shardable stand-ins).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig
from repro.core import local_update as LU
from repro.errors import ConfigError
from repro.models import api, param as pm

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str          # train | prefill | decode | long_decode


SHAPES: dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k":   InputShape("long_500k", 524_288, 1, "long_decode"),
}


def _ns(mesh, spec):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                        is_leaf=lambda x: isinstance(x, P))


def _state_specs(cfg, run_cfg, policy, mesh):
    """PartitionSpec tree for the local-gradient runtime state."""
    mod = api.get_module(cfg)
    defs = mod.param_defs(cfg)
    pspec = pm.param_specs(defs, policy, mesh, extra_leading=("worker",))
    if run_cfg.optimizer == "sgd":
        opt = {"mu": pspec, "step": P()}
    else:
        opt = {"m": pspec, "v": pspec, "step": P()}
    out = {"params": pspec, "opt": opt}
    single = pm.param_specs(defs, policy, mesh)    # anchor: no worker axis
    if run_cfg.sync_quantize or run_cfg.outer_momentum > 0.0:
        out["anchor"] = single
        if run_cfg.outer_momentum > 0.0:
            out["outer_mu"] = single
    return out


def _abstract_state(cfg, run_cfg, w: int, dtype):
    mod = api.get_module(cfg)
    defs = mod.param_defs(cfg)
    pabs = pm.abstract_params(defs, dtype)
    padd = jax.tree.map(lambda s: SDS((w,) + s.shape, s.dtype), pabs)
    f32 = lambda s: SDS(s.shape, jnp.float32)
    if run_cfg.optimizer == "sgd":
        opt = {"mu": jax.tree.map(f32, padd), "step": SDS((), jnp.int32)}
    else:
        opt = {"m": jax.tree.map(f32, padd), "v": jax.tree.map(f32, padd),
               "step": SDS((), jnp.int32)}
    out = {"params": padd, "opt": opt}
    if run_cfg.sync_quantize or run_cfg.outer_momentum > 0.0:
        out["anchor"] = pabs
        if run_cfg.outer_momentum > 0.0:
            out["outer_mu"] = jax.tree.map(f32, pabs)
    return out


def _flat_spec(cfg, dtype, *, mesh=None, policy=None, layout="flat"):
    """The conversion spec for a flat layout.  layout="flat_sharded" builds
    a mesh-carrying ShardedFlatSpace: buckets pad to W x S contiguous
    chunks (W workers x S flat-dim shards over the non-worker mesh axes) so
    both the storage sharding and the sync reduce_scatter land on whole
    elements, and the sync path emits its explicit collectives."""
    from repro.core.flat import FlatParamSpace, ShardedFlatSpace
    mod = api.get_module(cfg)
    pabs = pm.abstract_params(mod.param_defs(cfg), dtype)
    if layout != "flat_sharded":
        return FlatParamSpace(pabs)
    waxes = pm.worker_mesh_axes(policy, mesh)
    saxes = tuple(a for a in mesh.axis_names if a not in waxes)
    sizes = pm.mesh_axis_sizes(mesh)
    shards = math.prod(sizes[a] for a in waxes + saxes)
    return ShardedFlatSpace(pabs, shards, mesh=mesh, worker_axes=waxes,
                            shard_axes=saxes)


def _abstract_flat_state(cfg, run_cfg, w: int, dtype, spec):
    """Flat-layout runtime state: one [W, N] buffer per dtype bucket."""
    bufs = lambda lead, dt=None: {
        b: SDS(lead + (spec.buffer_size(b),), dt or jnp.dtype(b))
        for b in spec.buckets}
    if run_cfg.optimizer == "sgd":
        opt = {"mu": bufs((w,), jnp.float32), "step": SDS((), jnp.int32)}
    else:
        opt = {"m": bufs((w,), jnp.float32), "v": bufs((w,), jnp.float32),
               "step": SDS((), jnp.int32)}
    out = {"params": bufs((w,)), "opt": opt}
    if run_cfg.sync_quantize or run_cfg.outer_momentum > 0.0:
        out["anchor"] = bufs(())
        if run_cfg.outer_momentum > 0.0:
            out["outer_mu"] = bufs((), jnp.float32)
    return out


def _flat_state_specs(run_cfg, waxes, spec):
    """Shardings for the flat state — see core/flat.py flat_state_specs
    (shared with the RoundEngine's mesh-carrying init path)."""
    from repro.core.flat import flat_state_specs
    return flat_state_specs(run_cfg, waxes, spec)


def _batch_abstract(cfg, lead: tuple[int, ...], seq: int):
    """Per-family training batch with leading dims `lead` (e.g. (H, W, B))."""
    b = {"tokens": SDS(lead + (seq,), jnp.int32),
         "labels": SDS(lead + (seq,), jnp.int32)}
    if cfg.family == "vlm":
        b["prefix_embeds"] = SDS(lead + (cfg.n_img_tokens, cfg.d_model),
                                 jnp.bfloat16)
    if cfg.family == "audio":
        b["frames"] = SDS(lead + (cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return b


def _batch_specs(cfg, n_lead_extra: int, worker_axes, inner_data):
    """Sharding for batch leaves: [*, W, B_loc, ...]."""
    def spec(ndim_tail):
        dims = [None] * n_lead_extra + [worker_axes, inner_data]
        dims += [None] * ndim_tail
        return P(*dims)
    b = {"tokens": spec(1), "labels": spec(1)}
    if cfg.family == "vlm":
        b["prefix_embeds"] = spec(2)
    if cfg.family == "audio":
        b["frames"] = spec(2)
    return b


def _div(a: int, b: int) -> bool:
    return b > 0 and a % b == 0


@dataclasses.dataclass
class Case:
    fn: Any
    args: tuple
    in_shardings: Any
    out_shardings: Any
    meta: dict


def build_case(arch: str, shape_name: str, mesh, *, policy: str,
               run_cfg: RunConfig | None = None, h: int | None = None,
               parallel_baseline: bool = False,
               engine: str = "legacy", layout: str = "tree",
               sync: str = "blocking", overlap_depth: int = 0) -> Case:
    from repro.configs import registry as R

    cfg = R.get_config(arch)
    shape = SHAPES[shape_name]
    run_cfg = run_cfg or RunConfig(sharding=policy)
    dtype = jnp.bfloat16 if run_cfg.param_dtype == "bfloat16" else jnp.float32
    sizes = pm.mesh_axis_sizes(mesh)
    mod = api.get_module(cfg)

    if shape.mode == "train":
        if parallel_baseline:
            return _train_parallel_case(cfg, run_cfg, shape, mesh, policy,
                                        dtype, sizes)
        return _train_round_case(cfg, run_cfg, shape, mesh, policy, dtype,
                                 sizes, h or run_cfg.h_base, engine=engine,
                                 layout=layout, sync=sync,
                                 overlap_depth=overlap_depth)
    if shape.mode == "prefill":
        return _prefill_case(cfg, run_cfg, shape, mesh, policy, dtype, sizes)
    return _decode_case(cfg, run_cfg, shape, mesh, policy, dtype, sizes,
                        long=(shape.mode == "long_decode"))


# --------------------------------------------------------------------------
# Training cases
# --------------------------------------------------------------------------

def _train_round_case(cfg, run_cfg, shape, mesh, policy, dtype, sizes, h,
                      *, engine: str = "legacy", layout: str = "tree",
                      sync: str = "blocking", overlap_depth: int = 0):
    """engine="legacy": the seed's exact-H `train_round`.
    engine="bucketed": the RoundEngine's padded program — batches/lrs padded
    to the power-of-two bucket Hp plus a replicated [Hp] validity mask; the
    lowered unit is then exactly what production runs per round.
    layout="flat" (bucketed only): the state is FlatParamSpace dtype buckets
    — lowering this proves the per-sync all-reduce count is O(#buckets).
    layout="flat_sharded": ShardedFlatSpace chunks — state stored 1/S per
    device and the sync an explicit reduce_scatter + all_gather pair.
    sync="overlap" (bucketed only): the pending-threaded steady-state round
    — fn(state, pending, data, lrs, mask) -> (state, new_pending, metrics),
    exactly the program the RoundEngine runs every round after the first
    under `--sync overlap`.  The pending rides the signature at the sharding
    the reduce_scatter leg leaves it (core/sync.py `pending_specs`), so the
    lowering proves the deferred gather stays a per-bucket all_gather and
    the in-flight payload stays worker-sharded across the program boundary."""
    if layout not in ("tree", "flat", "flat_sharded"):
        raise ConfigError(f"unknown param layout {layout!r}")
    if layout != "tree" and engine != "bucketed":
        raise ConfigError(
            "the flat layouts run through the RoundEngine's bucketed program")
    # real errors, not asserts: the dryrun is a launch-script surface that
    # runs under `python -O` — a stripped guard would silently lower the
    # blocking program and report the overlap case as ok
    if sync not in ("blocking", "overlap"):
        raise ValueError(f"unknown sync mode {sync!r}")
    if sync == "overlap" and engine != "bucketed":
        raise ValueError("the overlap round is a bucketed-engine program: "
                         "pass engine='bucketed' with sync='overlap'")
    w = pm.worker_count(policy, mesh)
    waxes = pm.worker_mesh_axes(policy, mesh)
    waxes = waxes if len(waxes) > 1 else (waxes[0] if waxes else None)
    if shape.global_batch % max(w, 1) != 0:
        raise ConfigError(
            f"global batch {shape.global_batch} not divisible by {w} workers")
    b_loc = shape.global_batch // max(w, 1)
    inner_data = "data" if policy == "fsdp" and _div(b_loc, sizes.get("data", 1)) else None

    spec = (_flat_spec(cfg, dtype, mesh=mesh, policy=policy, layout=layout)
            if layout != "tree" else None)
    if layout != "tree":
        sspec = _flat_state_specs(run_cfg, waxes, spec)
        state = _abstract_flat_state(cfg, run_cfg, w, dtype, spec)
    else:
        sspec = _state_specs(cfg, run_cfg, policy, mesh)
        state = _abstract_state(cfg, run_cfg, w, dtype)
    bspec = _batch_specs(cfg, 1, waxes, inner_data)

    if engine == "bucketed":
        from repro.core.engine import (bucket_pow2, make_bucketed_round,
                                       make_overlap_round)
        hp = bucket_pow2(h)
        batches = _batch_abstract(cfg, (hp, w, b_loc), shape.seq_len)
        lrs = SDS((hp,), jnp.float32)
        mask = SDS((hp,), jnp.bool_)
        mspec = {"loss": P(), "grad_norm": P(), "divergence": P()}
        if sync == "overlap":
            from repro.core.sync import make_sync_begin, pending_specs
            round_fn = make_overlap_round(cfg, run_cfg, spec=spec,
                                          depth=overlap_depth,
                                          apply_pending=True)
            # the in-flight reduce: abstract shapes from the begin leg
            # itself, shardings as the reduce_scatter left them (None for
            # the non-collective layouts: GSPMD propagates)
            pending = jax.eval_shape(make_sync_begin(run_cfg, spec), state)
            pend_sh = (_ns(mesh, pending_specs(run_cfg, spec))
                       if getattr(spec, "mesh", None) is not None else None)
            in_sh = (_ns(mesh, sspec), pend_sh, _ns(mesh, bspec),
                     NamedSharding(mesh, P()), NamedSharding(mesh, P()))
            out_sh = (_ns(mesh, sspec), pend_sh, _ns(mesh, mspec))
            return Case(round_fn, (state, pending, batches, lrs, mask),
                        in_sh, out_sh,
                        meta={"cfg": cfg, "w": w, "b_loc": b_loc, "h": h,
                              "hp": hp, "fn_name": "train_round_overlap",
                              "layout": layout, "sync": sync,
                              "overlap_depth": overlap_depth,
                              "pending_leaves": len(jax.tree.leaves(pending)),
                              "steps_per_program": h})
        round_fn = make_bucketed_round(cfg, run_cfg, spec=spec)
        in_sh = (_ns(mesh, sspec), _ns(mesh, bspec), NamedSharding(mesh, P()),
                 NamedSharding(mesh, P()))
        out_sh = (_ns(mesh, sspec), _ns(mesh, mspec))
        # steps_per_program counts *real* (unmasked) steps so per-step cost
        # normalization stays comparable with the legacy case; the padded
        # scan length rides along as "hp"
        return Case(round_fn, (state, batches, lrs, mask), in_sh, out_sh,
                    meta={"cfg": cfg, "w": w, "b_loc": b_loc, "h": h,
                          "hp": hp, "fn_name": "train_round_bucketed",
                          "layout": layout, "steps_per_program": h})

    batches = _batch_abstract(cfg, (h, w, b_loc), shape.seq_len)
    lrs = SDS((h,), jnp.float32)
    round_fn = LU.make_train_round(cfg, run_cfg)
    in_sh = (_ns(mesh, sspec), _ns(mesh, bspec), NamedSharding(mesh, P()))
    out_sh = (_ns(mesh, sspec), NamedSharding(mesh, P()))
    return Case(round_fn, (state, batches, lrs), in_sh, out_sh,
                meta={"cfg": cfg, "w": w, "b_loc": b_loc, "h": h,
                      "fn_name": "train_round", "steps_per_program": h})


def _train_parallel_case(cfg, run_cfg, shape, mesh, policy, dtype, sizes):
    """Paper baseline ②: grad all-reduce every step (no worker axis)."""
    mod = api.get_module(cfg)
    defs = mod.param_defs(cfg)
    pabs = pm.abstract_params(defs, dtype)
    f32 = lambda s: SDS(s.shape, jnp.float32)
    if run_cfg.optimizer == "sgd":
        opt = {"mu": jax.tree.map(f32, pabs), "step": SDS((), jnp.int32)}
    else:
        opt = {"m": jax.tree.map(f32, pabs), "v": jax.tree.map(f32, pabs),
               "step": SDS((), jnp.int32)}
    state = {"params": pabs, "opt": opt}
    pspec = pm.param_specs(defs, policy, mesh)  # no worker axis
    sspec = {"params": pspec,
             "opt": ({"mu": pspec, "step": P()} if run_cfg.optimizer == "sgd"
                     else {"m": pspec, "v": pspec, "step": P()})}

    # batch over all data-parallel axes
    baxes = tuple(a for a in ("pod", "data") if a in sizes)
    baxes_s = baxes if len(baxes) > 1 else baxes[0]
    batch = _batch_abstract(cfg, (shape.global_batch,), shape.seq_len)
    bspec = {k: P(*((baxes_s,) + (None,) * (len(v.shape) - 1)))
             for k, v in batch.items()}

    step_fn = LU.make_parallel_step(cfg, run_cfg)
    in_sh = (_ns(mesh, sspec), _ns(mesh, bspec), None)
    out_sh = (_ns(mesh, sspec), NamedSharding(mesh, P()))
    lr = SDS((), jnp.float32)
    return Case(step_fn, (state, batch, lr), in_sh, out_sh,
                meta={"cfg": cfg, "w": 1, "b_loc": shape.global_batch, "h": 1,
                      "fn_name": "parallel_step", "steps_per_program": 1})


# --------------------------------------------------------------------------
# Serving cases
# --------------------------------------------------------------------------

def _serve_param_setup(cfg, mesh, policy, dtype):
    mod = api.get_module(cfg)
    defs = mod.param_defs(cfg)
    pabs = pm.abstract_params(defs, dtype)
    pspec = pm.param_specs(defs, policy, mesh)
    return mod, pabs, pspec


def _cache_sharding(cfg, cache_abs, mesh, sizes, batch, *,
                    layout: str = "batch"):
    """Shard caches.

    layout="batch":     batch dim over (pod,data) when divisible, else the
                        sequence dim over data (context-parallel long decode).
    layout="seq_model": additionally shard the KV-cache *sequence* dim over
                        'model' (flash-decode): attention reduces over the
                        sharded seq with a tiny per-layer psum, and no tensor
                        ever needs kv-head sharding — so GSPMD never reshards
                        the scan-carried cache (§Perf pair 2).
    """
    baxes = tuple(a for a in ("pod", "data") if a in sizes)
    nb = math.prod(sizes[a] for a in baxes)
    baxes_s = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    def one(sds):
        shp = sds.shape
        # find the batch dim: first dim equal to `batch` after the layer dim
        dims: list[Any] = [None] * len(shp)
        bdim = None
        for i, d in enumerate(shp):
            if d == batch and i > 0:
                bdim = i
                break
        if bdim is None and len(shp) >= 2 and shp[0] == batch:
            bdim = 0
        if bdim is not None and _div(batch, nb):
            dims[bdim] = baxes_s
        elif bdim is not None and len(shp) > bdim + 1 and \
                _div(shp[bdim + 1], sizes.get("data", 1)) and shp[bdim + 1] > 1024:
            dims[bdim + 1] = "data"  # context-parallel: shard the seq dim
        if (layout == "seq_model" and bdim is not None and len(shp) == 5
                and len(shp) > bdim + 1
                and _div(shp[bdim + 1], sizes.get("model", 1))
                and shp[bdim + 1] > 1024):
            dims[bdim + 1] = "model"   # KV seq dim, [L,B,S,kv,hd]
        return P(*dims)

    return jax.tree.map(one, cache_abs)


def _prefill_case(cfg, run_cfg, shape, mesh, policy, dtype, sizes):
    mod, pabs, pspec = _serve_param_setup(cfg, mesh, policy, dtype)
    b, s = shape.global_batch, shape.seq_len
    max_len = s + (cfg.n_img_tokens if cfg.family == "vlm" else 0)
    cache = mod.cache_spec(cfg, b, max_len, dtype)
    cache_spec_tree = _cache_sharding(cfg, cache, mesh, sizes, b,
                                      layout=getattr(run_cfg, "cache_layout",
                                                     "batch"))

    tokens = SDS((b, s), jnp.int32)
    baxes = tuple(a for a in ("pod", "data") if a in sizes)
    nb = math.prod(sizes[a] for a in baxes)
    baxes_s = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    tok_spec = P(baxes_s, None) if _div(b, nb) else P(None, None)

    kwargs_abs, kwargs_spec = {}, {}
    if cfg.family == "vlm":
        kwargs_abs["prefix_embeds"] = SDS((b, cfg.n_img_tokens, cfg.d_model), dtype)
        kwargs_spec["prefix_embeds"] = P(tok_spec[0], None, None)
    if cfg.family == "audio":
        kwargs_abs["frames"] = SDS((b, cfg.enc_seq, cfg.d_model), dtype)
        kwargs_spec["frames"] = P(tok_spec[0], None, None)

    def fn(params, tokens, cache, kw):
        return mod.prefill(cfg, params, tokens, cache, **kw)

    in_sh = (_ns(mesh, pspec), NamedSharding(mesh, tok_spec),
             _ns(mesh, cache_spec_tree), _ns(mesh, kwargs_spec))
    out_sh = (NamedSharding(mesh, P(tok_spec[0], None)),
              _ns(mesh, cache_spec_tree))
    return Case(fn, (pabs, tokens, cache, kwargs_abs), in_sh, out_sh,
                meta={"cfg": cfg, "fn_name": "prefill", "steps_per_program": 1,
                      "tokens_per_program": b * s})


def _decode_case(cfg, run_cfg, shape, mesh, policy, dtype, sizes, *, long):
    mod, pabs, pspec = _serve_param_setup(cfg, mesh, policy, dtype)
    b, s = shape.global_batch, shape.seq_len
    override = cfg.long_decode_window if (long and cfg.family not in
                                          ("ssm",)) else 0
    cache = mod.cache_spec(cfg, b, s, dtype, window_override=override)
    cache_spec_tree = _cache_sharding(cfg, cache, mesh, sizes, b,
                                      layout=getattr(run_cfg, "cache_layout",
                                                     "batch"))

    baxes = tuple(a for a in ("pod", "data") if a in sizes)
    nb = math.prod(sizes[a] for a in baxes)
    baxes_s = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)
    tok_spec = P(baxes_s) if _div(b, nb) else P(None)

    token = SDS((b,), jnp.int32)
    pos = SDS((), jnp.int32)
    if "k" in cache:
        kv_len = cache["k"].shape[2]
    elif "attn_k" in cache:
        kv_len = cache["attn_k"].shape[2]
    else:
        kv_len = 0  # pure SSM: O(1) state
    ring = bool(override) and bool(kv_len) and kv_len < s
    prefix_len = cfg.n_img_tokens if cfg.family == "vlm" else 0

    def fn(params, token, cache, pos):
        return mod.decode_step(cfg, params, token, cache, pos,
                               prefix_len=prefix_len, ring=ring)

    in_sh = (_ns(mesh, pspec), NamedSharding(mesh, tok_spec),
             _ns(mesh, cache_spec_tree), None)
    out_sh = (NamedSharding(mesh, P(tok_spec[0], None)),
              _ns(mesh, cache_spec_tree))
    return Case(fn, (pabs, token, cache, pos), in_sh, out_sh,
                meta={"cfg": cfg, "fn_name": "decode_step",
                      "steps_per_program": 1, "ring": ring,
                      "kv_len": kv_len, "tokens_per_program": b})


# --------------------------------------------------------------------------
# Cost-calibration support.
#
# XLA's cost_analysis() counts a while-loop body ONCE (verified in
# EXPERIMENTS.md §Dry-run), and fully unrolling production depths does not
# compile in reasonable time.  So the roofline pass compiles each program at
# two reduced depths with every scan UNROLLED (exact HLO costs), fits
# cost(L) = a*L + b, and extrapolates to the full depth.  The full-depth
# scan-mode compile still provides the lowering proof + memory analysis.
# --------------------------------------------------------------------------

def calib_sizes(cfg) -> tuple[int, int, float]:
    """(L1, L2, full_layers): reduced depths preserving the layer pattern.
    All three are in LAYERS; the extrapolation in roofline_run divides by L1
    to fit per-pattern-block costs."""
    if cfg.family == "hybrid":  # zamba2: block = one shared-attn group
        p = cfg.shared_attn_period
        return p, 2 * p, float(cfg.n_layers)
    if cfg.window_pattern > 0:  # gemma3: preserve the local:global pattern
        p = cfg.window_pattern
        return p, 2 * p, float(cfg.n_layers)
    return 2, 4, float(cfg.n_layers)


def with_depth(cfg, n_layers: int):
    kw = {"n_layers": n_layers}
    if cfg.family == "audio":
        kw["n_enc_layers"] = max(1, round(cfg.n_enc_layers * n_layers / cfg.n_layers))
    return dataclasses.replace(cfg, **kw)


def build_calib_case(cfg, shape_name: str, mesh, *, policy: str,
                     run_cfg: RunConfig | None = None, fn_kind: str,
                     layout: str = "tree", sync: str = "blocking") -> Case:
    """Like build_case but for an explicitly-resized cfg and a specific
    sub-program: local_step | sync | parallel_step | prefill | decode.

    fn_kind="sync" selects the sync sub-program via `sync`: "blocking"
    (the composed whole-sync), "partial" (mask-carrying), or the overlap
    halves "begin"/"apply" — the lowering matrix the static audit
    (launch/audit.py) evaluates the rule registry against."""
    shape = SHAPES[shape_name]
    run_cfg = run_cfg or RunConfig(sharding=policy)
    dtype = jnp.bfloat16 if run_cfg.param_dtype == "bfloat16" else jnp.float32
    sizes = pm.mesh_axis_sizes(mesh)

    if fn_kind in ("local_step", "sync"):
        w = pm.worker_count(policy, mesh)
        waxes = pm.worker_mesh_axes(policy, mesh)
        waxes = waxes if len(waxes) > 1 else (waxes[0] if waxes else None)
        b_loc = shape.global_batch // max(w, 1)
        inner_data = ("data" if policy == "fsdp"
                      and _div(b_loc, sizes.get("data", 1)) else None)
        spec = (_flat_spec(cfg, dtype, mesh=mesh, policy=policy,
                           layout=layout) if layout != "tree" else None)
        if layout != "tree":
            state = _abstract_flat_state(cfg, run_cfg, w, dtype, spec)
            sspec = _flat_state_specs(run_cfg, waxes, spec)
        else:
            state = _abstract_state(cfg, run_cfg, w, dtype)
            sspec = _state_specs(cfg, run_cfg, policy, mesh)
        if fn_kind == "sync":
            from repro.core.sync import (SYNC_PROGRAMS, make_sync_begin,
                                         pending_specs, sync_program)
            if sync not in SYNC_PROGRAMS:
                raise ConfigError(
                    f"unknown sync program {sync!r}; pick from {SYNC_PROGRAMS}")
            fn = sync_program(run_cfg, spec=spec, program=sync)
            meta = {"cfg": cfg, "fn_name": f"sync_{sync}", "w": w,
                    "layout": layout, "sync": sync,
                    "n_leaves": (spec.n_leaves if spec else
                                 len(jax.tree.leaves(state["params"]))),
                    "n_buckets": (len(spec.buckets) if spec else None)}
            ssh = _ns(mesh, sspec)
            mesh_carrying = getattr(spec, "mesh", None) is not None
            if sync == "blocking":
                return Case(fn, (state,), (ssh,), ssh, meta=meta)
            if sync == "partial":
                mask = SDS((w,), jnp.float32)
                msh = NamedSharding(mesh, P()) if mesh_carrying else None
                return Case(fn, (state, mask), (ssh, msh), ssh, meta=meta)
            # the overlap halves: `begin` produces the in-flight pending at
            # the sharding the reduce_scatter leaves it; `apply` consumes it
            pending = jax.eval_shape(make_sync_begin(run_cfg, spec), state)
            pend_sh = (_ns(mesh, pending_specs(run_cfg, spec))
                       if mesh_carrying else None)
            if sync == "begin":
                return Case(fn, (state,), (ssh,), pend_sh, meta=meta)
            return Case(fn, (state, pending), (ssh, pend_sh), ssh, meta=meta)
        batch = _batch_abstract(cfg, (w, b_loc), shape.seq_len)
        bspec = _batch_specs(cfg, 0, waxes, inner_data)
        step = LU.make_local_step(cfg, run_cfg, spec=spec)
        in_sh = (_ns(mesh, sspec), _ns(mesh, bspec), None)
        out_sh = (_ns(mesh, sspec), NamedSharding(mesh, P()))
        lr = SDS((), jnp.float32)
        return Case(step, (state, batch, lr), in_sh, out_sh,
                    meta={"cfg": cfg, "fn_name": "local_step", "w": w,
                          "b_loc": b_loc, "layout": layout})
    if fn_kind == "parallel_step":
        return _train_parallel_case(cfg, run_cfg, shape, mesh, policy, dtype,
                                    sizes)
    if fn_kind == "prefill":
        return _prefill_case(cfg, run_cfg, shape, mesh, policy, dtype, sizes)
    if fn_kind == "decode":
        return _decode_case(cfg, run_cfg, shape, mesh, policy, dtype, sizes,
                            long=(shape.mode == "long_decode"))
    raise ValueError(fn_kind)


def build_round_case(cfg, mesh, *, policy: str, run_cfg: RunConfig,
                     h: int = 2, seq_len: int = 64, global_batch: int = 8,
                     layout: str = "tree", sync: str = "blocking",
                     overlap_depth: int = 0,
                     engine: str = "bucketed") -> Case:
    """A full round program for an explicit cfg at a small custom shape —
    the static audit's round-level lowering hook (donation-aliasing,
    no-host-callback, no-degenerate-replica-group run against exactly the
    program the RoundEngine caches).  Same plumbing as build_case's train
    path, without the SHAPES registry in the way."""
    shape = InputShape(f"audit_{seq_len}x{global_batch}", seq_len,
                       global_batch, "train")
    dtype = jnp.bfloat16 if run_cfg.param_dtype == "bfloat16" else jnp.float32
    sizes = pm.mesh_axis_sizes(mesh)
    return _train_round_case(cfg, run_cfg, shape, mesh, policy, dtype, sizes,
                             h, engine=engine, layout=layout, sync=sync,
                             overlap_depth=overlap_depth)
