"""Training driver: a thin host loop over `repro.core.engine.RoundEngine`.

The engine owns compilation (power-of-two H-bucketed compile cache —
O(log H_max) XLA programs for a full QSR schedule instead of one per
distinct H), buffer donation, in-graph telemetry (loss / grad norm / worker
divergence), and the data path (on-device fold_in batch synthesis by
default; `--data host` for the numpy stream).  This file only walks the
H-schedule: ask `schedules.get_h` for the next round's period, hand the
round to the engine, log, checkpoint.

Both of the paper's algorithms run through the same engine: Local OPT with
any H-schedule (Alg. 2) and the data-parallel baseline (Alg. 1 ==
`--schedule parallel`, i.e. H=1 every round).  `--engine legacy` is the
escape hatch back to one-compile-per-distinct-H exact rounds.

  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b --smoke \
      --schedule qsr --steps 200 --workers 4
"""
from __future__ import annotations

import argparse
import time

from repro.checkpoint import io as ckpt_io
from repro.configs.base import RunConfig
from repro.core import schedules
from repro.core.engine import RoundEngine
from repro.errors import ConfigError
from repro.optim.lr import make_lr_fn


def train(cfg, run_cfg: RunConfig, *, workers: int, b_loc: int, seq: int,
          seed: int = 0, ckpt_dir: str | None = None, log_every: int = 1,
          engine: str = "bucketed", data: str = "device",
          layout: str = "tree", sync: str = "blocking",
          overlap_depth: int = 0, eval_fn=None,
          async_observer: bool = False,
          eng: RoundEngine | None = None,
          controller_trace: str | None = None, frontier=None):
    """Run a full training run; returns (state, history).

    history rows are (t_end, h, loss, lr) — unchanged from the pre-engine
    driver so downstream plots/tests keep working.  Pass an `eng` to keep a
    handle on the engine (compile stats, H-trace) after the run; otherwise
    one is built from the `engine`/`data`/`layout`/`sync` mode flags.
    With sync="overlap" the in-flight reduce is flushed at checkpoints and
    before returning, so the returned state is always the synced consensus.

    schedule="adaptive" swaps the open-loop `schedules.get_h` walk for a
    core/controller.py AdaptiveController around every round: H gets a
    divergence correction on top of the QSR prior, the effective per-worker
    batch grows through zero-recompile `batch_epoch`s (engines built with
    `adaptive_batch=True` — automatic here under the bucketed engine), and
    with sync="overlap" + a `frontier` ({depth: s/round} dict or a
    table4_walltime JSON path) the overlap depth rides the walltime
    frontier.  `controller_trace` names a JSON file to persist the
    per-round decision stream (schema controller_trace/v1).

    async_observer=True moves eval and mid-run checkpoints off the round
    loop: the engine's synced_view (pure — the overlap pipeline is
    untouched) is submitted to a background AsyncObserver worker
    (core/observer.py) that device_gets and runs `eval_fn` / writes the
    checkpoint on a host thread, double-buffered so the training stream
    never blocks on observer I/O.  Mid-run checkpoints are then written
    from the consensus view WITHOUT forcing a sync point; the final
    checkpoint is still written synchronously after the run's flush.
    """
    adaptive = run_cfg.schedule == "adaptive"
    if eng is None:
        eng = RoundEngine(cfg, run_cfg, workers=workers, b_loc=b_loc,
                          seq=seq, seed=seed, mode=engine, data=data,
                          layout=layout, sync=sync,
                          overlap_depth=overlap_depth,
                          adaptive_batch=adaptive and engine == "bucketed")
    else:
        got = (eng.cfg, eng.run_cfg, eng.workers, eng.b_loc, eng.seq,
               eng.seed, eng.mode, eng.data, eng.layout, eng.sync_mode,
               eng.overlap_depth)
        want = (cfg, run_cfg, workers, b_loc, seq, seed, engine, data,
                layout, sync, overlap_depth)
        if got != want:
            raise ConfigError(
                "engine built with (cfg, run_cfg, workers, b_loc, seq, seed, "
                f"mode, data, layout, sync, overlap_depth)={got},\n"
                f"train() called with {want}")
    state = eng.init_state()
    lr_fn = make_lr_fn(run_cfg)

    ctrl = None
    if adaptive:
        from repro.core.controller import AdaptiveController, load_frontier
        if isinstance(frontier, str):
            frontier = load_frontier(frontier)
        ctrl = AdaptiveController(run_cfg, lr_fn, engine=eng,
                                  frontier=frontier)

    step0 = 0
    if ckpt_dir and ckpt_io.exists(ckpt_dir):
        state, step0 = eng.restore(ckpt_dir, state)
        print(f"restored checkpoint at round boundary {step0} "
              f"({len(eng.h_trace)} rounds done)")

    observer = None
    if async_observer and (eval_fn is not None or ckpt_dir):
        from repro.core.observer import AsyncObserver

        def handle(step, snap):
            # worker thread: snap is the staged (host) consensus view
            if eval_fn is not None:
                eval_fn(step, snap["state"])
            if snap.get("save"):
                ckpt_io.save(ckpt_dir, snap["state"], step=step,
                             extra=snap["extra"])
        # a superseded snapshot's checkpoint request rides the newer one
        # (the newer consensus is a strictly better checkpoint)
        observer = AsyncObserver(
            handle, merge=lambda old, new: ({**new, "save": True}
                                            if old.get("save") else new))

    history = []
    t_start = time.time()
    t = saved_at = step0
    while t < run_cfg.total_steps:
        h = (ctrl.begin_round(t) if ctrl is not None
             else schedules.get_h(run_cfg, t, lr_fn))
        state, m = eng.run_round(state, t, h, lr_fn)
        if ctrl is not None:
            ctrl.end_round(t, h, m)
        t += h
        loss = float(m["loss"])
        history.append((t, h, loss, lr_fn(t - 1)))
        if log_every and (len(history) % log_every == 0):
            cs = eng.compile_stats()
            print(f"step {t:6d}  H {h:4d}  lr {lr_fn(t-1):.5f}  "
                  f"loss {loss:.4f}  |g| {float(m['grad_norm']):.3f}  "
                  f"div {float(m['divergence']):.4f}  "
                  f"compiles {cs['compiles']} (hits {cs['cache_hits']})  "
                  f"({time.time()-t_start:.1f}s)")
        want_ckpt = bool(ckpt_dir) and \
            t % max(run_cfg.total_steps // 4, 1) == 0
        if observer is not None:
            if eval_fn is not None or want_ckpt:
                # overlap mode: observers see the synced consensus (pure
                # view; the in-flight pipeline is untouched), so eval curves
                # and checkpoints match blocking-sync runs — device_get and
                # I/O happen on the observer thread, not here
                snap = eng.synced_view(state)
                if snap is state and eng.donate:
                    # blocking sync: the view IS the live state, whose
                    # buffers the next round donates — give the observer
                    # its own copy (async device op, no host sync)
                    import jax
                    import jax.numpy as jnp
                    snap = jax.tree.map(jnp.copy, state)
                observer.submit(t, {"state": snap, "save": want_ckpt,
                                    "extra": eng.checkpoint_extra()})
                if want_ckpt:
                    saved_at = t
        else:
            if eval_fn is not None:
                eval_fn(t, eng.synced_view(state))
            if want_ckpt:
                # overlap mode: a checkpoint is a forced sync point — the
                # in-flight reduce is applied so the saved state is a round
                # boundary in the blocking sense
                state = eng.flush(state)
                eng.save(ckpt_dir, state, step=t)
                saved_at = t
    state = eng.flush(state)
    if observer is not None:
        observer.close()
    if ckpt_dir and saved_at != t:
        eng.save(ckpt_dir, state, step=t)
    if ctrl is not None and controller_trace:
        ctrl.write_trace(controller_trace)
        print(f"controller trace ({len(ctrl.trace)} rounds) -> "
              f"{controller_trace}")
    return state, history


def main():
    from repro.launch import multihost
    distributed = multihost.initialize()  # no-op without REPRO_COORDINATOR
    if distributed:
        print(f"multihost: {multihost.runtime_info()}")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    # choices derive from the schedules module so CLI and core cannot drift
    ap.add_argument("--schedule", default="qsr",
                    choices=list(schedules.SCHEDULE_KINDS))
    ap.add_argument("--engine", default="bucketed",
                    choices=["bucketed", "legacy"],
                    help="bucketed: pow2 compile cache; legacy: per-H jit")
    ap.add_argument("--data", default="device", choices=["device", "host"],
                    help="batch synthesis inside the jitted round vs numpy")
    ap.add_argument("--param-layout", default="tree",
                    choices=["tree", "flat", "flat_sharded"],
                    help="tree: state mirrors the model pytree (per-tensor "
                         "stats); flat: dtype-bucketed 1-D buffers — one "
                         "sync all-reduce and one optimizer kernel per "
                         "bucket (core/flat.py), bitwise-equal training; "
                         "flat_sharded: buckets padded into per-device "
                         "contiguous chunks (FSDP-style) — sync decomposes "
                         "into reduce_scatter + all_gather, bitwise-equal "
                         "too")
    ap.add_argument("--sync", default="blocking",
                    choices=["blocking", "overlap", "partial"],
                    help="blocking: each round ends fully synced (Alg. 1/2 "
                         "verbatim); overlap: the delta reduce is issued at "
                         "the round boundary and the gather/apply deferred "
                         "past the next round's first --overlap-depth local "
                         "steps (depth 0 keeps the blocking trajectory "
                         "bitwise); partial: elastic rounds averaging over "
                         "the engine's per-round membership mask only "
                         "(all-present == blocking; see README §Elastic "
                         "training)")
    ap.add_argument("--overlap-depth", type=int, default=0,
                    help="local steps the next round runs on stale params "
                         "before the deferred sync applies (--sync overlap)")
    ap.add_argument("--mesh", default=None,
                    help="run the rounds on a device mesh, e.g. 4x2 (data x "
                         "model) or 2x2x2 (pod x data x model): requires "
                         "--param-layout flat_sharded; the sync then "
                         "executes its explicit reduce_scatter/all_gather "
                         "collectives — across processes when launched "
                         "under jax.distributed (launch/multihost.py).  "
                         "--workers must equal the policy's worker count "
                         "on the mesh")
    ap.add_argument("--policy", default="dp", choices=["dp", "fsdp"],
                    help="sharding policy naming the mesh's worker axes "
                         "(dp: every data rank; fsdp: one worker per pod)")
    ap.add_argument("--async-observer", action="store_true",
                    help="run eval + mid-run checkpoints on a background "
                         "host thread fed by the engine's synced_view "
                         "(core/observer.py): device_get and checkpoint "
                         "I/O leave the round loop's critical path, "
                         "double-buffered so training never blocks")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--quantize", action="store_true",
                    help="int8-quantized sync deltas (README §Quantized "
                         "sync); implied by --wire ring-int8")
    ap.add_argument("--wire", default="auto", choices=["auto", "ring-int8"],
                    help="quantized payload wire mode (README §Wire modes): "
                         "auto = exact int16/int32 code-sums; ring-int8 = "
                         "re-quantizing int8 ppermute ring (needs "
                         "--param-layout flat|flat_sharded)")
    ap.add_argument("--controller-trace", default=None,
                    help="--schedule adaptive: JSON path for the per-round "
                         "controller decision stream (schema "
                         "controller_trace/v1; README §Adaptive controller)")
    ap.add_argument("--frontier", default=None,
                    help="--schedule adaptive + --sync overlap: "
                         "table4_walltime JSON whose measured s/round rows "
                         "give the overlap-depth walltime frontier the "
                         "controller chooses depth on")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8, help="per-worker batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    ap.add_argument("--alpha", type=float, default=0.002)
    ap.add_argument("--h-base", type=int, default=2)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    from repro.configs import registry as R
    cfg = R.get_smoke_config(args.arch) if args.smoke else R.get_config(args.arch)
    run_cfg = RunConfig(
        schedule=args.schedule, optimizer=args.optimizer, sharding=args.policy,
        total_steps=args.steps, peak_lr=args.peak_lr, alpha=args.alpha,
        h_base=args.h_base, warmup_steps=max(args.steps // 20, 1),
        remat=False,
        sync_quantize=args.quantize or args.wire == "ring-int8",
        sync_wire=args.wire)
    mesh = None
    if args.mesh:
        import jax
        dims, axes = multihost._parse_mesh(args.mesh)
        mesh = jax.make_mesh(dims, axes)
    eng = RoundEngine(cfg, run_cfg, workers=args.workers, b_loc=args.batch,
                      seq=args.seq, mode=args.engine, data=args.data,
                      layout=args.param_layout, sync=args.sync,
                      overlap_depth=args.overlap_depth,
                      mesh=mesh, policy=args.policy,
                      adaptive_batch=(args.schedule == "adaptive"
                                      and args.engine == "bucketed"))
    state, hist = train(cfg, run_cfg, workers=args.workers, b_loc=args.batch,
                        seq=args.seq, ckpt_dir=args.ckpt, engine=args.engine,
                        data=args.data, layout=args.param_layout,
                        sync=args.sync, overlap_depth=args.overlap_depth,
                        async_observer=args.async_observer, eng=eng,
                        controller_trace=args.controller_trace,
                        frontier=args.frontier)
    losses = [l for _, _, l, _ in hist]
    if not losses:
        print("nothing to do: checkpoint already at "
              f"step {run_cfg.total_steps}")
        return
    n_sync = len(hist)
    cs = eng.compile_stats()
    print(f"\nfinal loss {losses[-1]:.4f}  (first {losses[0]:.4f}); "
          f"{n_sync} communication rounds for {args.steps} steps "
          f"(comm volume {n_sync/args.steps:.1%} of data-parallel); "
          f"{cs['compiles']} XLA round programs "
          f"(buckets {cs['programs']}, {cs['cache_hits']} cache hits)")


if __name__ == "__main__":
    main()
