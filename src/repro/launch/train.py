"""Training driver: Local OPT with any H-schedule (paper Alg. 2) or the
data-parallel baseline (Alg. 1).

Runs end-to-end on CPU at smoke scale (examples/quickstart.py) and lowers
unchanged on the production mesh.  The host loop owns the H-schedule: each
communication round jit-executes `train_round` with that round's H.

  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b --smoke \
      --schedule qsr --steps 200 --workers 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.configs.base import RunConfig
from repro.core import local_update as LU
from repro.core import schedules
from repro.data.synthetic import TokenStream, make_train_batch
from repro.models import api, param as pm
from repro.optim.lr import make_lr_fn


def train(cfg, run_cfg: RunConfig, *, workers: int, b_loc: int, seq: int,
          seed: int = 0, ckpt_dir: str | None = None, log_every: int = 1,
          eval_fn=None):
    mod = api.get_module(cfg)
    params = pm.init_params(mod.param_defs(cfg), jax.random.PRNGKey(seed),
                            jnp.float32)
    state = LU.init_state(cfg, run_cfg, params, workers)
    lr_fn = make_lr_fn(run_cfg)
    stream = TokenStream(vocab=max(cfg.vocab, 2), seed=seed)

    step0 = 0
    if ckpt_dir and ckpt_io.exists(ckpt_dir):
        state, step0 = ckpt_io.restore(ckpt_dir, state)
        print(f"restored checkpoint at step {step0}")

    round_cache: dict[int, any] = {}

    def round_fn_for(h: int):
        if h not in round_cache:
            round_cache[h] = jax.jit(LU.make_train_round(cfg, run_cfg))
        return round_cache[h]

    history = []
    t_start = time.time()
    t = step0
    while t < run_cfg.total_steps:
        h = schedules.get_h(run_cfg, t, lr_fn)
        batches = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[make_train_batch(cfg, stream, t + i, workers, b_loc, seq)
              for i in range(h)])
        lrs = jnp.asarray([lr_fn(t + i) for i in range(h)], jnp.float32)
        state, loss = round_fn_for(h)(state, batches, lrs)
        t += h
        history.append((t, h, float(loss), lr_fn(t - 1)))
        if log_every and (len(history) % log_every == 0):
            print(f"step {t:6d}  H {h:4d}  lr {lr_fn(t-1):.5f}  "
                  f"loss {float(loss):.4f}  ({time.time()-t_start:.1f}s)")
        if ckpt_dir and t % max(run_cfg.total_steps // 4, 1) == 0:
            ckpt_io.save(ckpt_dir, state, step=t)
    if ckpt_dir:
        ckpt_io.save(ckpt_dir, state, step=t)
    return state, history


def main():
    from repro.launch import multihost
    multihost.initialize()  # no-op unless REPRO_COORDINATOR is set
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--schedule", default="qsr",
                    choices=["qsr", "constant", "inverse", "cubic",
                             "postlocal", "swap", "parallel"])
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8, help="per-worker batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--peak-lr", type=float, default=3e-3)
    ap.add_argument("--alpha", type=float, default=0.002)
    ap.add_argument("--h-base", type=int, default=2)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    from repro.configs import registry as R
    cfg = R.get_smoke_config(args.arch) if args.smoke else R.get_config(args.arch)
    run_cfg = RunConfig(
        schedule=args.schedule, optimizer=args.optimizer,
        total_steps=args.steps, peak_lr=args.peak_lr, alpha=args.alpha,
        h_base=args.h_base, warmup_steps=max(args.steps // 20, 1),
        remat=False)
    state, hist = train(cfg, run_cfg, workers=args.workers, b_loc=args.batch,
                        seq=args.seq, ckpt_dir=args.ckpt)
    losses = [l for _, _, l, _ in hist]
    n_sync = len(hist)
    print(f"\nfinal loss {losses[-1]:.4f}  (first {losses[0]:.4f}); "
          f"{n_sync} communication rounds for {args.steps} steps "
          f"(comm volume {n_sync/args.steps:.1%} of data-parallel)")


if __name__ == "__main__":
    main()
