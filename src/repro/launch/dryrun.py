import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()
# ^ MUST run before any other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on placeholder host devices and record roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-3b \
      --shape train_4k [--multi-pod] [--parallel-baseline] [--out FILE]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import RunConfig
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, build_case


def run_one(arch, shape, *, multi_pod, policy=None,
            parallel_baseline=False, run_cfg=None,
            engine="legacy", layout="tree", sync="blocking",
            overlap_depth=0, quantize=False, wire="auto", verbose=True):
    from repro.configs import registry as R

    policy = policy or R.get_policy(arch)
    if run_cfg is None and (quantize or wire != "auto"):
        run_cfg = RunConfig(sharding=policy, sync_wire=wire,
                            sync_quantize=quantize or wire == "ring-int8")
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    case = build_case(arch, shape, mesh, policy=policy,
                      run_cfg=run_cfg, parallel_baseline=parallel_baseline,
                      engine=engine, layout=layout, sync=sync,
                      overlap_depth=overlap_depth)
    t0 = time.time()
    with mesh:
        jitted = jax.jit(case.fn, in_shardings=case.in_shardings,
                         out_shardings=case.out_shardings)
        lowered = jitted.lower(*case.args)
        compiled = lowered.compile()
    t1 = time.time()
    stats = hlo_analysis.summarize(compiled, n_devices=n_dev)
    rec = {
        "arch": arch, "shape": shape, "policy": policy,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "fn": case.meta["fn_name"],
        "steps_per_program": case.meta.get("steps_per_program", 1),
        "workers": case.meta.get("w"),
        "h": case.meta.get("h"),
        "hp": case.meta.get("hp"),
        "layout": case.meta.get("layout", "tree"),
        "sync": case.meta.get("sync", "blocking"),
        "quantize": bool(run_cfg.sync_quantize) if run_cfg else False,
        "wire": getattr(run_cfg, "sync_wire", "auto") if run_cfg else "auto",
        "overlap_depth": case.meta.get("overlap_depth"),
        "pending_leaves": case.meta.get("pending_leaves"),
        "ring": case.meta.get("ring"),
        "kv_len": case.meta.get("kv_len"),
        "compile_s": round(t1 - t0, 1),
        **stats,
    }
    if verbose:
        mem = stats["per_device_memory"]
        print(f"[{arch} x {shape} x {rec['mesh']} {rec['fn']}] "
              f"compile {rec['compile_s']}s  "
              f"flops/dev {stats['flops']:.3e}  "
              f"bytes/dev {stats['bytes_accessed']:.3e}  "
              f"coll/dev {stats['collective_bytes_total']:.3e}  "
              f"arg {mem['argument_bytes']/2**30:.2f}GiB "
              f"temp {mem['temp_bytes']/2**30:.2f}GiB")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--policy", default=None, choices=["dp", "fsdp", None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--parallel-baseline", action="store_true")
    ap.add_argument("--engine", default="legacy",
                    choices=["legacy", "bucketed"],
                    help="train_round flavor to lower: the seed's exact-H "
                         "program or the RoundEngine's padded+masked bucket")
    ap.add_argument("--param-layout", default="tree",
                    choices=["tree", "flat", "flat_sharded"],
                    help="flat: lower the round over FlatParamSpace dtype "
                         "buckets (requires --engine bucketed; the sync "
                         "drops to one all-reduce per bucket — see "
                         "collective_counts in the record); flat_sharded: "
                         "ShardedFlatSpace chunks — state stored 1/S per "
                         "device, the sync one reduce_scatter + one "
                         "all_gather per bucket (collective_result_bytes "
                         "shows the scatter leg landing 1/W per device)")
    ap.add_argument("--sync", default="blocking",
                    choices=["blocking", "overlap"],
                    help="overlap (requires --engine bucketed): lower the "
                         "pending-threaded steady-state round — "
                         "fn(state, pending, ...) -> (state, new_pending, "
                         "metrics), the program the RoundEngine runs under "
                         "--sync overlap; the in-flight payload stays "
                         "worker-sharded across the program boundary")
    ap.add_argument("--overlap-depth", type=int, default=0,
                    help="local steps lowered before the deferred "
                         "gather/apply (--sync overlap)")
    ap.add_argument("--quantize", action="store_true",
                    help="lower the int8-quantized sync (integer-code "
                         "payloads on the RS/AG legs + one tiny amax pmax)")
    ap.add_argument("--wire", default="auto", choices=["auto", "ring-int8"],
                    help="quantized payload wire mode (README §Wire modes); "
                         "ring-int8 lowers the W-hop re-quantizing ppermute "
                         "ring — collective_counts shows the s8 "
                         "collective-permutes (implies --quantize; needs "
                         "--param-layout flat_sharded)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import registry as R

    archs = R.ASSIGNED if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    records, failures = [], []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    records.append(run_one(arch, shape, multi_pod=mp,
                                           policy=args.policy,
                                           parallel_baseline=args.parallel_baseline,
                                           engine=args.engine,
                                           layout=args.param_layout,
                                           sync=args.sync,
                                           overlap_depth=args.overlap_depth,
                                           quantize=args.quantize,
                                           wire=args.wire))
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    failures.append({"arch": arch, "shape": shape,
                                     "mesh": "2x16x16" if mp else "16x16",
                                     "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"records": records, "failures": failures}, f, indent=1)
    print(f"\n{len(records)} ok, {len(failures)} failed")
    if failures:
        for f_ in failures:
            print("FAIL:", f_)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
