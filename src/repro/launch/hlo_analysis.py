"""Extract roofline inputs from a compiled (AOT) executable.

 - FLOPs / bytes-accessed from compiled.cost_analysis()
 - per-device memory from compiled.memory_analysis()
 - collective bytes parsed from the optimized HLO text: operand sizes of
   all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
 - collective *counts* per kind (the latency axis): proves layout claims
   like "flat sync = one all-reduce per dtype bucket, not per leaf".
"""
from __future__ import annotations

import re

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_RG_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_RG_LIST = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")


def _crosses_pod(line: str, pod_size: int) -> bool | None:
    """True if any replica group spans devices from different pods
    (device id // pod_size differs).  None if no group info found."""
    m = _RG_IOTA.search(line)
    if m:
        g, n = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims)))
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.reshape(dims).transpose(perm).reshape(-1)
        groups = ids.reshape(g, n)
        pods = groups // pod_size
        return bool((pods != pods[:, :1]).any())
    m = _RG_LIST.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "").split(",") if x.strip()]
            if len({i // pod_size for i in ids}) > 1:
                return True
        return False
    return None


def _dtype_bytes_map(shapes) -> dict[str, int]:
    out: dict[str, int] = {}
    for dt, dims in shapes:
        out[dt] = out.get(dt, 0) + _shape_bytes(dt, dims)
    return out


def _iter_collectives(hlo_text: str):
    """Yield one dict per collective op in the optimized HLO, with
    start/done pairs reported once (on the -start line):

      {kind, line, bytes_full, bytes_result, dtype, dtypes}

    bytes_result sums the *result* type(s) only — for reduce-scatter that
    is the per-device owned chunk (the scatter leg); bytes_full takes the
    larger of (result, operands) — the full-tensor roofline size for
    gather/scatter ops.  `dtypes` maps element type -> bytes over the
    larger side, covering every operand of a variadic op: the wire payload
    classifier — how tests prove the ring sync keeps int8 on every
    collective-permute hop and that no f32 tensor rides a quantized wire.
    `dtype` (the first result element type) is kept for compatibility but
    blind to mixed-dtype tuples; classify with `dtypes`."""
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w\.\-]+\s*=\s*(.*)$", s)
        if m is None:
            continue
        rest = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rest):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rest:
            continue  # avoid double counting start/done pairs
        shapes = _SHAPE_RE.findall(rest)
        if not shapes:
            continue
        # result type(s) appear before the op name; operands may not carry
        # inline types in optimized HLO.
        head, _, tail = rest.partition(kind)
        rshapes = _SHAPE_RE.findall(head) or shapes
        oshapes = _SHAPE_RE.findall(tail)
        if (f"{kind}-start(" in rest and kind in ("all-gather", "reduce-scatter")
                and len(rshapes) >= 2 and len(rshapes) % 2 == 0):
            # async gather/scatter results are (operand..., result...) tuples;
            # keep only the result half so the operand copy isn't counted as
            # a second payload.
            half = len(rshapes) // 2
            if not oshapes or rshapes[:half] == oshapes:
                rshapes = rshapes[half:]
        nb = lambda sh: sum(_shape_bytes(dt, dims) for dt, dims in sh)
        res = nb(rshapes)
        full_shapes = rshapes if res >= nb(oshapes) else oshapes
        yield {
            "kind": kind,
            "line": line,
            "bytes_full": max(res, nb(oshapes)),
            "bytes_result": res,
            "dtype": rshapes[0][0],
            "dtypes": _dtype_bytes_map(full_shapes),
        }


def collective_bytes(hlo_text: str, pod_size: int = 0) -> dict[str, int]:
    """Sum full-tensor sizes of collective ops in the optimized HLO, per
    kind.

    For all-reduce / all-to-all / collective-permute, result size == operand
    size.  For all-gather the result is the gathered (full) tensor and for
    reduce-scatter the operand is the full tensor; in both cases the bytes
    that actually cross links per device are ~the full-tensor size x
    (n-1)/n, so the full-tensor size is the right roofline input.  We report
    the larger of (result, operands) per op.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["dci"] = 0  # pod-crossing bytes (multi-pod meshes only)
    for op in _iter_collectives(hlo_text):
        out[op["kind"]] += op["bytes_full"]
        if pod_size and _crosses_pod(op["line"], pod_size):
            out["dci"] += op["bytes_full"]
    return out


def collective_result_bytes(hlo_text: str) -> dict[str, int]:
    """Sum *result* sizes per kind — the per-device landing size of each
    leg.  This is where the sharded sync's scatter-leg win shows: a
    reduce-scatter's result is the owned 1/W chunk, ~W x smaller than the
    all-reduce result the flat layout pays per bucket; the matching
    all_gather (result: the full bucket) is the leg `--sync overlap` hides
    behind the next round's first local steps."""
    out = {k: 0 for k in _COLLECTIVES}
    for op in _iter_collectives(hlo_text):
        out[op["kind"]] += op["bytes_result"]
    return out


def collective_ops(hlo_text: str) -> list[dict]:
    """Per-op collective detail:
    [{kind, bytes_full, bytes_result, dtype, dtypes}] in HLO order.  This is
    the view that separates a *scale* collective from a *payload*
    collective: the quantized sharded sync's amax fold is one all-reduce of
    4 bytes per model tensor (`payload_profile` classifies any all-reduce
    at most that size as the fold; a bucket-sized all-reduce would be a
    lowering regression).  `dtypes` maps element type -> bytes across every
    operand of a variadic op — the ring sync's acceptance proof filters
    payload-sized ops and asserts every one is s8
    (`payload_profile` `payload_bytes_by_dtype`)."""
    return [{k: op[k] for k in
             ("kind", "bytes_full", "bytes_result", "dtype", "dtypes")}
            for op in _iter_collectives(hlo_text)]


def collective_counts(hlo_text: str) -> dict[str, int]:
    """Number of collective *ops* per kind (start/done pairs count once).

    This is the latency/launch-overhead axis the byte totals miss: a sync
    that moves the same bytes in one all-reduce per dtype bucket
    (--param-layout flat) instead of one per pytree leaf issues O(#dtypes)
    collectives instead of O(#leaves) — and the flat_sharded layout's sync
    must show exactly one reduce-scatter + one all-gather per bucket (the
    acceptance measures; see core/flat.py, tests/test_flat.py and
    tests/test_sharded.py).
    """
    out = {k: 0 for k in _COLLECTIVES}
    for op in _iter_collectives(hlo_text):
        out[op["kind"]] += 1
    return out


def fold_limit(n_leaves: int) -> int:
    """Max byte size of a *scale* collective: the quantized sync's amax
    fold is f32 per model tensor, all buckets concatenated — 4 bytes per
    leaf plus alignment slack.  Anything bigger is wire payload."""
    return 4 * n_leaves + 64


def payload_profile(hlo_text: str, *, n_leaves: int) -> dict:
    """Classify every collective in a sync program as *scale* (the amax
    fold and the ring's scalar per-hop scales — at most `fold_limit`
    bytes) or *payload* (bucket-sized: the bytes QSR actually saves), and
    report the wire picture the layout acceptance claims are written
    against.  Extracted from launch/sync_compare so the declarative rule
    registry (repro.analysis.rules), the audit CLI and the lowering tests
    all read the same record."""
    counts = collective_counts(hlo_text)
    nbytes = collective_bytes(hlo_text)
    legs = collective_result_bytes(hlo_text)
    limit = fold_limit(n_leaves)
    ops = collective_ops(hlo_text)
    ars = [op for op in ops if op["kind"] == "all-reduce"]
    fold = [op for op in ars if op["bytes_full"] <= limit]
    payload = [op for op in ops if op["bytes_full"] > limit]
    by_dtype_bytes: dict[str, int] = {}
    by_dtype_ops: dict[str, int] = {}
    for op in payload:
        # per-dtype over every operand of the (possibly variadic) op, so a
        # f32 tensor hiding in a mixed tuple cannot masquerade as the
        # first operand's dtype
        for dt, b in op["dtypes"].items():
            if b > limit:
                by_dtype_bytes[dt] = by_dtype_bytes.get(dt, 0) + b
                by_dtype_ops[dt] = by_dtype_ops.get(dt, 0) + 1
    return {
        "collective_counts": counts,
        "collective_bytes": {k: v for k, v in nbytes.items() if v},
        "collective_leg_bytes": {k: v for k, v in legs.items() if v},
        "all_reduce_ops": counts["all-reduce"],
        "amax_fold_ops": len(fold),
        "amax_fold_bytes": sum(op["bytes_full"] for op in fold),
        "payload_all_reduce_ops": len(ars) - len(fold),
        "reduce_scatter_ops": counts["reduce-scatter"],
        "all_gather_ops": counts["all-gather"],
        "bytes_on_wire": sum(v for k, v in nbytes.items() if k != "dci"),
        "scatter_leg_bytes": legs["reduce-scatter"],
        "rs_wire_bytes": nbytes["reduce-scatter"],
        "ag_wire_bytes": nbytes["all-gather"],
        "collective_permute_ops": counts["collective-permute"],
        "permute_wire_bytes": nbytes["collective-permute"],
        "payload_bytes_by_dtype": by_dtype_bytes,
        "payload_ops_by_dtype": by_dtype_ops,
        "n_leaves": n_leaves,
    }


_ALIAS_PAIR = re.compile(r"\{([0-9, ]*)\}:\s*\((\d+)\s*,\s*\{([0-9, ]*)\}")


def donation_aliases(hlo_text: str) -> list[tuple[tuple, int, tuple]]:
    """Parse the entry computation's `input_output_alias={...}` header into
    [(output_index, param_number, param_index)] pairs — the proof that a
    donated state buffer was actually reused for its output (silent
    donation loss doubles device memory; the donation-aliasing rule)."""
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return []
    # the header nests braces ({0}: (0, {}, may-alias), ...): scan to the
    # matching close by depth counting, then pull the pairs
    i = start + len("input_output_alias=")
    depth, j = 0, i
    for j in range(i, len(hlo_text)):
        if hlo_text[j] == "{":
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
            if depth == 0:
                break
    body = hlo_text[i + 1:j]
    out = []
    for om, pnum, pidx in _ALIAS_PAIR.findall(body):
        oi = tuple(int(x) for x in om.replace(" ", "").split(",") if x)
        pi = tuple(int(x) for x in pidx.replace(" ", "").split(",") if x)
        out.append((oi, int(pnum), pi))
    return out


def _group_sizes(line: str) -> list[int] | None:
    m = _RG_IOTA.search(line)
    if m:
        g, n = int(m.group(1)), int(m.group(2))
        return [n] * g
    m = _RG_LIST.search(line)
    if m:
        return [len([x for x in grp.replace("{", "").replace("}", "").split(",")
                     if x.strip()])
                for grp in m.group(1).split("},{")]
    return None


def degenerate_collectives(hlo_text: str) -> list[str]:
    """Lines of collective ops whose replica groups are all singletons —
    a collective that moves nothing between devices (a partitioner
    regression: pure launch overhead).  collective-permute is judged by
    its source_target_pairs instead and skipped here."""
    out = []
    for op in _iter_collectives(hlo_text):
        if op["kind"] == "collective-permute":
            continue
        sizes = _group_sizes(op["line"])
        if sizes is not None and all(s <= 1 for s in sizes):
            out.append(op["line"].strip())
    return out


_HOST_CALL = re.compile(
    r"custom_call_target=\"[^\"]*(callback|host)[^\"]*\"|\binfeed\(|\boutfeed\(")


def host_callbacks(hlo_text: str) -> list[str]:
    """Lines that round-trip through the host (python callbacks, infeed /
    outfeed) — forbidden inside round programs: one host hop per round
    serializes the overlap pipeline and breaks multi-process runs."""
    return [ln.strip() for ln in hlo_text.splitlines() if _HOST_CALL.search(ln)]


def summarize(compiled, *, n_devices: int) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    pod_size = 256 if n_devices > 256 else 0
    coll = collective_bytes(hlo, pod_size=pod_size)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "per_device_memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "collective_bytes": coll,
        "collective_result_bytes": collective_result_bytes(hlo),
        "collective_counts": collective_counts(hlo),
        "collective_bytes_total": sum(v for k, v in coll.items()
                                      if k != "dci"),
        "dci_bytes": coll["dci"],
        "n_devices": n_devices,
    }
