"""Extract roofline inputs from a compiled (AOT) executable.

 - FLOPs / bytes-accessed from compiled.cost_analysis()
 - per-device memory from compiled.memory_analysis()
 - collective bytes parsed from the optimized HLO text: operand sizes of
   all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
 - collective *counts* per kind (the latency axis): proves layout claims
   like "flat sync = one all-reduce per dtype bucket, not per leaf".
"""
from __future__ import annotations

import re

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_RG_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_RG_LIST = re.compile(r"replica_groups=\{\{([0-9,{} ]*)\}\}")


def _crosses_pod(line: str, pod_size: int) -> bool | None:
    """True if any replica group spans devices from different pods
    (device id // pod_size differs).  None if no group info found."""
    m = _RG_IOTA.search(line)
    if m:
        g, n = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims)))
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.reshape(dims).transpose(perm).reshape(-1)
        groups = ids.reshape(g, n)
        pods = groups // pod_size
        return bool((pods != pods[:, :1]).any())
    m = _RG_LIST.search(line)
    if m:
        for grp in m.group(1).split("},{"):
            ids = [int(x) for x in grp.replace("{", "").replace("}", "").split(",") if x.strip()]
            if len({i // pod_size for i in ids}) > 1:
                return True
        return False
    return None


def _iter_collectives(hlo_text: str):
    """Yield (kind, line, nbytes_full, nbytes_result, dtype) for every
    collective op in the optimized HLO, with start/done pairs reported once
    (on the -start line).  nbytes_result sums the *result* type(s) only —
    for reduce-scatter that is the per-device owned chunk (the scatter leg);
    nbytes_full takes the larger of (result, operands) — the full-tensor
    roofline size for gather/scatter ops.  `dtype` is the first result
    element type (s8/s16/f32/...): the wire payload classifier — how
    tests prove the ring sync keeps int8 on every collective-permute hop."""
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w\.\-]+\s*=\s*(.*)$", s)
        if m is None:
            continue
        rest = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            if re.search(rf"\b{k}(-start|-done)?\(", rest):
                kind = k
                break
        if kind is None:
            continue
        if f"{kind}-done(" in rest:
            continue  # avoid double counting start/done pairs
        shapes = _SHAPE_RE.findall(rest)
        if not shapes:
            continue
        # result type(s) appear before the op name; operands may not carry
        # inline types in optimized HLO.
        head, _, tail = rest.partition(kind)
        rshapes = _SHAPE_RE.findall(head) or shapes
        oshapes = _SHAPE_RE.findall(tail)
        nb = lambda sh: sum(_shape_bytes(dt, dims) for dt, dims in sh)
        res = nb(rshapes)
        yield kind, line, max(res, nb(oshapes)), res, rshapes[0][0]


def collective_bytes(hlo_text: str, pod_size: int = 0) -> dict[str, int]:
    """Sum full-tensor sizes of collective ops in the optimized HLO, per
    kind.

    For all-reduce / all-to-all / collective-permute, result size == operand
    size.  For all-gather the result is the gathered (full) tensor and for
    reduce-scatter the operand is the full tensor; in both cases the bytes
    that actually cross links per device are ~the full-tensor size x
    (n-1)/n, so the full-tensor size is the right roofline input.  We report
    the larger of (result, operands) per op.
    """
    out = {k: 0 for k in _COLLECTIVES}
    out["dci"] = 0  # pod-crossing bytes (multi-pod meshes only)
    for kind, line, nbytes, _, _ in _iter_collectives(hlo_text):
        out[kind] += nbytes
        if pod_size and _crosses_pod(line, pod_size):
            out["dci"] += nbytes
    return out


def collective_result_bytes(hlo_text: str) -> dict[str, int]:
    """Sum *result* sizes per kind — the per-device landing size of each
    leg.  This is where the sharded sync's scatter-leg win shows: a
    reduce-scatter's result is the owned 1/W chunk, ~W x smaller than the
    all-reduce result the flat layout pays per bucket; the matching
    all_gather (result: the full bucket) is the leg `--sync overlap` hides
    behind the next round's first local steps."""
    out = {k: 0 for k in _COLLECTIVES}
    for kind, _, _, res, _ in _iter_collectives(hlo_text):
        out[kind] += res
    return out


def collective_ops(hlo_text: str) -> list[dict]:
    """Per-op collective detail: [{kind, bytes_full, bytes_result, dtype}]
    in HLO order.  This is the view that separates a *scale* collective from
    a *payload* collective: the quantized sharded sync's amax fold is one
    all-reduce of 4 bytes per model tensor (launch/sync_compare classifies
    any all-reduce at most that size as the fold; a bucket-sized all-reduce
    would be a lowering regression).  `dtype` is the result element type —
    the ring sync's acceptance proof filters payload-sized ops and asserts
    every one is s8 (launch/sync_compare `payload_bytes_by_dtype`)."""
    return [{"kind": kind, "bytes_full": full, "bytes_result": res,
             "dtype": dtype}
            for kind, _, full, res, dtype in _iter_collectives(hlo_text)]


def collective_counts(hlo_text: str) -> dict[str, int]:
    """Number of collective *ops* per kind (start/done pairs count once).

    This is the latency/launch-overhead axis the byte totals miss: a sync
    that moves the same bytes in one all-reduce per dtype bucket
    (--param-layout flat) instead of one per pytree leaf issues O(#dtypes)
    collectives instead of O(#leaves) — and the flat_sharded layout's sync
    must show exactly one reduce-scatter + one all-gather per bucket (the
    acceptance measures; see core/flat.py, tests/test_flat.py and
    tests/test_sharded.py).
    """
    out = {k: 0 for k in _COLLECTIVES}
    for kind, _, _, _, _ in _iter_collectives(hlo_text):
        out[kind] += 1
    return out


def summarize(compiled, *, n_devices: int) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    pod_size = 256 if n_devices > 256 else 0
    coll = collective_bytes(hlo, pod_size=pod_size)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "per_device_memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "collective_bytes": coll,
        "collective_result_bytes": collective_result_bytes(hlo),
        "collective_counts": collective_counts(hlo),
        "collective_bytes_total": sum(v for k, v in coll.items()
                                      if k != "dci"),
        "dci_bytes": coll["dci"],
        "n_devices": n_devices,
    }
