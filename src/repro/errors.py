"""Typed error hierarchy for the repro library.

Library code must raise these (or the domain-specific subclasses that
live next to their subsystems: ``TopologyError``, ``PendingSyncError``,
``MembershipError``, ``CheckpointError``) instead of bare ``assert`` —
asserts vanish under ``python -O``, which turned real misconfigurations
into silent corruption three separate times before the source lint
(``repro.analysis.source_lint``) made the pattern unrepresentable.

Everything here subclasses ``ValueError`` so existing
``except ValueError`` call sites keep working.
"""


class ReproError(Exception):
    """Root of the repro error hierarchy."""


class ConfigError(ReproError, ValueError):
    """Invalid run/launch configuration (bad flag combination, unknown
    mode, mismatched engine reuse, ...)."""


class ShapeError(ReproError, ValueError):
    """A shape/dtype contract was violated (kernel operands, model
    inputs, parameter definitions)."""


class LayoutError(ReproError, ValueError):
    """Flat/sharded parameter-layout misuse (wrong treedef, non-divisible
    shard counts, empty param trees)."""
